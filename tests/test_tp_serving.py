"""Tensor-parallel serving (PR 9): the serve hot path on a ("model",)
mesh.

The invariant everything here leans on: tp is an execution detail, not
a semantics knob.  A tp=2 engine on the forced 2-device host mesh
(conftest sets --xla_force_host_platform_device_count=2 before jax
initializes) must produce byte-identical greedy streams to tp=1 — for
float AND int4 weights, through speculative decoding, and for
recurrent-state (arena) families — while page/lane bookkeeping stays
exact under random abort/fork/preempt interleavings on the sharded
pools.  Config validation must fail loudly (non-dividing dims, too few
devices), never silently degrade.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import SERVE_RULES, serve_mesh
from repro.models import DecoderLM, ModelConfig, init_params
from repro.models.config import SSMConfig
from repro.quant.qarray import dequant_counters, reset_dequant_counters
from repro.serve import (PagedServeEngine, SamplingParams, ServeConfig,
                         ServeRequest)


def _dense(seed=0, **kw):
    base = dict(name="s", family="dense", n_layers=2, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                head_dim=16, dtype="float32", remat=False)
    cfg = ModelConfig(**{**base, **kw})
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         dtype_override=jnp.float32)
    return model, params


def _xlstm():
    cfg = ModelConfig(name="x", family="xlstm", n_layers=4, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False,
                      ssm=SSMConfig(mlstm_heads=2, slstm_every=2))
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


def _prompts(vocab=64, n=6):
    rng = np.random.default_rng(0)
    return [rng.integers(0, vocab, int(k)).astype(np.int32)
            for k in rng.integers(4, 17, size=n)]


def _run(model, params, cfg, prompts, new=12, spec=None):
    eng = PagedServeEngine(model, params, cfg, spec=spec)
    reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=new, rid=i)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return [r.out_tokens for r in reqs], eng


# ----------------------------------------------------------------------------
# SERVE_RULES pspec units
# ----------------------------------------------------------------------------
def test_serve_rules_pspec_units():
    # only tensor-parallel-marked dims shard; batch/page/seq axes stay
    # replicated so block tables and lane bookkeeping remain host-side
    # per-shard-identical
    assert SERVE_RULES.pspec(("tp",)) == P("model")
    assert SERVE_RULES.pspec(("expert",)) == P("model")
    assert SERVE_RULES.pspec(("batch", None, "tp")) == \
        P(None, None, "model")
    assert SERVE_RULES.pspec(("batch", "kv_seq", "tp", None)) == \
        P(None, None, "model", None)
    assert SERVE_RULES.pspec(("layers", "fsdp", "seq")) == P(None, None,
                                                            None)


# ----------------------------------------------------------------------------
# config / mesh validation: fail loudly, never silently degrade
# ----------------------------------------------------------------------------
def test_serveconfig_tp_validation():
    with pytest.raises(ValueError, match="tp must be >= 1"):
        ServeConfig(tp=0)
    with pytest.raises(ValueError, match="tp must be >= 1"):
        serve_mesh(0)
    # more shards than devices: the error names the count AND the
    # host-mesh escape hatch instead of an opaque mesh failure
    n = len(jax.devices())
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        serve_mesh(n + 1)


def test_engine_rejects_non_dividing_tp_dims():
    # 3 heads / d_ff=96 on tp=2: the engine must refuse with the dims
    # named rather than building a mesh that unevenly shards the pools
    model, params = _dense(n_heads=3, n_kv_heads=3, d_model=48, d_ff=96)
    with pytest.raises(ValueError, match="does not divide"):
        PagedServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=32, page_size=8,
                                     tp=2))
    with pytest.raises(ValueError, match="n_heads"):
        model.validate_tp(2)
    # tp=3 divides 3 heads/96 ffn but exceeds the 2-device host mesh
    with pytest.raises(ValueError, match="devices"):
        PagedServeEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=32, page_size=8,
                                     tp=3))


# ----------------------------------------------------------------------------
# the acceptance bar: tp=2 greedy == tp=1 greedy, byte for byte
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("precision", ["fp", "int4"])
def test_tp2_greedy_byte_identical(precision):
    model, params = _dense()
    prompts = _prompts()

    def cfg(tp):
        return ServeConfig(precision=precision, quant_group=16,
                           max_batch=4, max_seq=64, page_size=8, tp=tp)

    base, eng1 = _run(model, params, cfg(1), prompts)
    reset_dequant_counters()
    out, eng2 = _run(model, params, cfg(2), prompts)
    assert out == base, f"tp=2 diverged from tp=1 at precision={precision}"

    # weights are actually distributed, not silently replicated
    leaves = jax.tree_util.tree_leaves(eng2.params)
    assert any(len(l.sharding.device_set) == 2 for l in leaves), \
        "tp=2 engine left every param leaf on one device"
    # ... and so are the KV pools
    pool_leaves = jax.tree_util.tree_leaves(eng2.cache.pools)
    assert any(len(l.sharding.device_set) == 2 for l in pool_leaves), \
        "tp=2 engine left every KV pool leaf on one device"

    if precision == "int4":
        # residency guarantee survives sharding: no whole-weight float
        # materialization traced into the tp=2 graphs
        assert dequant_counters()["full_dequant"] == 0, \
            "tp=2 quantized hot path traced a full-weight dequant"

    # energy accounting: same token stream => same aggregate joules;
    # tp models aggregate bandwidth, so simulated wall time halves and
    # per-device keys carry each shard's slice
    s1, s2 = eng1.summary(), eng2.summary()
    assert s2["sim_tp"] == 2.0 and "sim_tp" not in s1
    np.testing.assert_allclose(s2["sim_energy_j"], s1["sim_energy_j"],
                               rtol=1e-9)
    np.testing.assert_allclose(s2["sim_time_s"], s1["sim_time_s"] / 2,
                               rtol=1e-9)
    np.testing.assert_allclose(s2["sim_energy_j_per_device"],
                               s2["sim_energy_j"] / 2, rtol=1e-9)


def test_tp2_spec_ngram_byte_identical():
    """Speculative decoding rides the sharded verify step: tp=2 with an
    n-gram drafter must still match plain tp=1 decode byte-for-byte
    (the engine rewraps `paged_verify_step` with the mesh-aware jit)."""
    from repro.spec import SpecConfig
    model, params = _dense()
    prompts = [np.array([1, 2, 3, 1, 2, 3, 1, 2], np.int32),
               np.array([7, 9, 11], np.int32),
               np.arange(10, 30, dtype=np.int32) % 64]

    def cfg(tp):
        return ServeConfig(max_batch=2, max_seq=64, page_size=8,
                           prefill_chunk=8, tp=tp)

    base, _ = _run(model, params, cfg(1), prompts)
    out, eng = _run(model, params, cfg(2), prompts,
                    spec=SpecConfig(k=4, drafter="ngram"))
    assert out == base
    assert eng.summary()["spec_drafted"] > 0
    assert eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages


def test_tp2_recurrent_arena_byte_identical():
    """StateArena lanes (xlstm mLSTM/sLSTM state) shard their TP cell
    dims; save/restore/reset are eager gather/scatters on the sharded
    leaves and must not perturb the stream."""
    model, params = _xlstm()
    prompts = _prompts(n=4)

    def cfg(tp):
        return ServeConfig(max_batch=2, max_seq=32, page_size=8, tp=tp)

    base, _ = _run(model, params, cfg(1), prompts, new=8)
    out, eng = _run(model, params, cfg(2), prompts, new=8)
    assert out == base, "tp=2 recurrent stream diverged from tp=1"
    assert eng.arena is not None
    arena_leaves = jax.tree_util.tree_leaves(eng.arena.state)
    assert any(len(l.sharding.device_set) == 2 for l in arena_leaves), \
        "tp=2 engine left every arena leaf on one device"


# ----------------------------------------------------------------------------
# page/lane conservation on sharded pools under abort/fork/preempt
# ----------------------------------------------------------------------------
def test_tp2_page_conservation_random_interleavings():
    """test_cancel's conservation property, on tp=2 sharded int4 pools:
    any interleaving of submits/aborts with fork children and
    preemptions ends with every page free and every lane empty.  Block
    tables and refcounts are host-side and per-shard-identical, so the
    invariant must hold exactly as at tp=1."""
    model, params = _dense()
    rng = np.random.default_rng(11)
    for trial in range(2):
        cfg = ServeConfig(precision="int4", quant_group=16, max_batch=2,
                          max_seq=32, page_size=4,
                          n_pages=int(rng.integers(10, 16)),
                          prefill_chunk=4, seed=trial, tp=2)
        eng = PagedServeEngine(model, params, cfg)
        n_pages = eng.cache.allocator.n_pages
        reqs, pending = [], []
        for i in range(int(rng.integers(5, 8))):
            prompt = rng.integers(0, 64, int(rng.integers(2, 12))
                                  ).astype(np.int32)
            r = ServeRequest(prompt=prompt, rid=i,
                             max_new_tokens=int(rng.integers(2, 8)),
                             sampling=SamplingParams(
                                 temperature=float(rng.choice([0., 1.]))))
            if reqs and rng.random() < 0.3:
                r.prompt = reqs[-1].prompt.copy()
                r.fork_from = reqs[-1]
            reqs.append(r)
            pending.append(r)
        for _ in range(300):
            if pending and (rng.random() < 0.4 or not eng.busy):
                eng.submit(pending.pop(0))
            elif eng.busy:
                eng.step()
            live = [r for r in reqs if r.eid >= 0 and not r.done]
            if live and rng.random() < 0.2:
                eng.cancel(live[int(rng.integers(0, len(live)))].eid)
            alloc = eng.cache.allocator
            held = {p for pages in alloc._held.values() for p in pages}
            assert alloc.n_free + len(held) == n_pages, \
                (trial, "pages leaked mid-flight on sharded pools")
            if not pending and not eng.busy:
                break
        while eng.busy:
            eng.step()
        assert (eng.cache.n_free_or_cached() == n_pages
                and all(r is None for r in eng.lanes)), trial
        # the sharded pools survived the churn with their canonical
        # shardings intact (out_shardings pins them step over step)
        pool_leaves = jax.tree_util.tree_leaves(eng.cache.pools)
        assert any(len(l.sharding.device_set) == 2 for l in pool_leaves)
