"""SLO layer: mergeable quantile sketches (rank error, merge algebra,
bounded memory, serialization), burn-rate alerting over synthetic
schedules, fleet percentile merging vs pooled samples, sim-vs-measured
drift audit, and 2-replica gateway e2e (induced page -> /healthz
degraded; induced decode slowdown -> CUSUM drift alarm)."""
import asyncio
import json
import math
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Gateway
from repro.fleet import FleetRouter, aggregate_summaries
from repro.models import DecoderLM, ModelConfig, init_params
from repro.obs import (BurnRatePolicy, DriftAuditor, QuantileDigest,
                       SLOMonitor, merge_digest_dicts, parse_slos)
from repro.obs.slo import DEFAULT_SLOS, SLOSpec
from repro.serve import PagedServeEngine


# ----------------------------------------------------------------------------
# sketch accuracy: relative value error vs np.percentile on adversarial
# distributions (DDSketch's guarantee is value-relative, not rank)
# ----------------------------------------------------------------------------
def _distributions(rng):
    lo = rng.lognormal(mean=-3.0, sigma=1.5, size=20_000)
    bimodal = np.concatenate([rng.normal(1e-3, 1e-4, size=6_000),
                              rng.normal(150.0, 5.0, size=14_000)])
    tiny = rng.uniform(2e-6, 5e-5, size=5_000)
    heavy = rng.pareto(1.5, size=20_000) + 1e-4
    return {"lognormal": np.abs(lo), "bimodal": np.abs(bimodal),
            "tiny": tiny, "pareto": heavy,
            "constant": np.full(1_000, 0.0375)}


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_digest_rank_error_vs_numpy(seed):
    rng = np.random.default_rng(seed)
    for name, samples in _distributions(rng).items():
        dig = QuantileDigest()
        dig.extend(samples)
        assert dig.count == len(samples)
        # p50 of the 30/70 bimodal sits in the upper mode, nowhere near
        # the inter-mode gap, so value-relative accuracy applies at
        # every tested percentile
        for p in (10.0, 50.0, 95.0, 99.0, 99.9):
            est = dig.quantile(p)
            true = float(np.percentile(samples, p))
            assert est is not None
            assert abs(est - true) / true < 0.025, \
                f"{name} p{p}: {est} vs {true}"


def test_digest_empty_and_edge_values():
    dig = QuantileDigest()
    assert dig.quantile(50) is None
    assert dig.count == 0
    assert math.isnan(dig.mean())
    # zero / sub-resolution values land in the zero bucket and come
    # back as 0.0, never negative or NaN
    dig.add(0.0)
    dig.add(1e-9)
    dig.add(2.0)
    assert dig.count == 3
    assert dig.quantile(0) == 0.0
    assert abs(dig.quantile(100) - 2.0) / 2.0 < 0.011
    assert dig.count_above(1.0) == 1
    # sub-resolution values sit in the zero bucket: "above 0" within
    # the sketch's resolution excludes them, negative thresholds don't
    assert dig.count_above(0.0) == 1
    assert dig.count_above(-1.0) == 3


def test_digest_merge_commutative_associative_and_linear():
    rng = np.random.default_rng(3)
    parts = [rng.lognormal(-2, 1, 4000), rng.uniform(0.5, 5, 3000),
             rng.pareto(2, 5000) + 1e-3]
    digs = []
    for part in parts:
        d = QuantileDigest()
        d.extend(part)
        digs.append(d)
    a, b, c = digs
    ab_c = a.copy().merge(b).merge(c)
    a_bc = a.copy().merge(b.copy().merge(c))
    cba = c.copy().merge(b).merge(a)

    def norm(d):
        # the running `sum` is float addition, so merge order moves it
        # by ulps; buckets/counts/extrema must be EXACTLY equal
        out = dict(d.to_dict())
        return out, out.pop("sum")

    d1, s1 = norm(ab_c)
    d2, s2 = norm(a_bc)
    d3, s3 = norm(cba)
    # merge is bucket-wise addition: any order yields the identical
    # sketch, not merely a similar one
    assert d1 == d2 == d3
    assert s1 == pytest.approx(s2) == pytest.approx(s3)
    # and it equals the sketch of the pooled stream (linearity)
    pooled = QuantileDigest()
    pooled.extend(np.concatenate(parts))
    dp, sp = norm(pooled)
    assert dp == d1 and sp == pytest.approx(s1)


def test_digest_merge_alpha_mismatch_rejected():
    a, b = QuantileDigest(alpha=0.01), QuantileDigest(alpha=0.02)
    a.add(1.0)
    b.add(1.0)
    with pytest.raises(ValueError):
        a.merge(b)


def test_digest_bounded_memory_at_1e6_inserts():
    rng = np.random.default_rng(11)
    dig = QuantileDigest()
    n = 1_000_000
    # 8 decades of dynamic range in 100k-sample slabs
    for _ in range(10):
        dig.extend(np.exp(rng.uniform(np.log(1e-5), np.log(1e3),
                                      size=n // 10)))
    assert dig.count == n
    assert dig.n_buckets <= 2048
    est, lo, hi = dig.quantile(50), 1e-5, 1e3
    assert lo <= est <= hi


def test_digest_serialization_roundtrip_and_dict_merge():
    rng = np.random.default_rng(5)
    dicts = []
    pooled = []
    for _ in range(3):
        s = rng.lognormal(-1, 1, 2000)
        pooled.append(s)
        d = QuantileDigest()
        d.extend(s)
        dicts.append(d.to_dict())
        # JSON round-trip (bucket keys become strings on the wire)
        wire = json.loads(json.dumps(d.to_dict()))
        back = QuantileDigest.from_dict(wire)
        assert back.to_dict() == d.to_dict()
        assert back.quantile(95) == d.quantile(95)
    merged = merge_digest_dicts(dicts + [None])   # absent replica ok
    true = float(np.percentile(np.concatenate(pooled), 95))
    assert abs(merged.quantile(95) - true) / true < 0.025
    assert merge_digest_dicts([None, None]) is None


# ----------------------------------------------------------------------------
# spec parsing
# ----------------------------------------------------------------------------
def test_slo_spec_parsing():
    s = SLOSpec.parse("ttft_p95_s < 0.5")
    assert (s.kind, s.metric, s.threshold) == ("latency", "ttft_s", 0.5)
    assert abs(s.budget - 0.05) < 1e-12
    e = SLOSpec.parse("error_rate < 0.01")
    assert (e.kind, e.budget) == ("error_rate", 0.01)
    g = SLOSpec.parse("goodput_tokens_per_s > 10")
    assert (g.kind, g.threshold) == ("goodput", 10.0)
    with pytest.raises(ValueError):
        SLOSpec.parse("ttft_p95_s > 0.5")     # latency must be '<'
    with pytest.raises(ValueError):
        SLOSpec.parse("nonsense_metric < 1")
    with pytest.raises(ValueError):
        parse_slos(["ttft_p95_s < 1", "ttft_p95_s < 2"])  # dup name


# ----------------------------------------------------------------------------
# burn-rate window math over synthetic schedules (manual clock)
# ----------------------------------------------------------------------------
def _latency_digest_dict(good, bad):
    """Serialized ttft sketch with `good` fast and `bad` slow samples
    (threshold in the tests sits between 0.01 and 10)."""
    d = QuantileDigest()
    d.extend([0.01] * good + [10.0] * bad if good + bad else [])
    return d.to_dict()


def test_burn_rate_pages_on_fast_burn_and_recovers():
    pol = BurnRatePolicy(timescale=1 / 600)   # page: 6s long, 0.5s short
    mon = SLOMonitor(["ttft_p95_s < 1.0"], policy=pol)
    seen = []
    mon.on_transition(seen.append)
    # all-bad stream, 10 ticks/s: burn = (1/1)/0.05 = 20 >= 14.4
    good, bad, t = 0, 0, 0.0
    for i in range(70):
        t = i * 0.1
        bad += 5
        mon.ingest("r0", digests={"ttft_s": _latency_digest_dict(good,
                                                                 bad)},
                   now=t)
        mon.evaluate(t)
    assert mon.worst_level() == "page"
    assert [ev["to"] for ev in seen] == ["page"], \
        "one clean ok->page transition, no flapping"
    assert seen[0]["scope"] == "r0" and seen[0]["kind"] == "slo_alert"
    # recovery: all-good stream until the 6s long window drains
    for i in range(70, 220):
        t = i * 0.1
        good += 5
        mon.ingest("r0", digests={"ttft_s": _latency_digest_dict(good,
                                                                 bad)},
                   now=t)
        mon.evaluate(t)
    assert mon.worst_level() == "ok"
    assert [ev["to"] for ev in seen][-1] == "ok"
    # de-escalation steps down through warn (warn windows are longer,
    # so they drain after page does), never jumps levels upward
    levels = [ev["to"] for ev in seen]
    assert levels[0] == "page" and levels[-1] == "ok"


def test_burn_rate_short_window_vetoes_stale_badness():
    """Long-window burn stays high after a historical bad burst, but the
    page rule requires BOTH windows burning — once the short window is
    clean again, no page fires."""
    pol = BurnRatePolicy(timescale=1 / 600)
    mon = SLOMonitor(["error_rate < 0.05"], policy=pol)
    total = bad = 0
    # 1s of pure errors (would page if sustained)...
    for i in range(10):
        t = i * 0.1
        total += 10
        bad += 10
        mon.ingest("r0", counters={"requests_total": total,
                                   "cancelled": bad}, now=t)
    # ...but evaluation only starts after 1s of light clean traffic
    # has flushed the 0.5s short window (light, so the long-window
    # fraction stays page-level: ~90 bad of ~100 total)
    for i in range(10, 20):
        t = i * 0.1
        total += 1
        mon.ingest("r0", counters={"requests_total": total,
                                   "cancelled": bad}, now=t)
    fired = mon.evaluate(2.0)
    st = mon.states[("r0", "error_rate")]
    assert st.burn["page_long"] >= 14.4, "long window still burning"
    assert st.burn["page_short"] < 14.4, "short window clean"
    # the page tier is vetoed; the slower warn tier (3s short window
    # still covering the burst) correctly holds the lower level
    assert mon.worst_level() == "warn"
    assert [ev["to"] for ev in fired] == ["warn"]


def test_burn_rate_goodput_floor_counts_slow_ticks():
    pol = BurnRatePolicy(timescale=1 / 600)
    mon = SLOMonitor(["goodput_tokens_per_s > 100"], policy=pol)
    tok = busy = 0.0
    for i in range(70):
        t = i * 0.1
        tok += 2.0          # 2 tokens per 0.1s of busy time = 20 tok/s
        busy += 0.1
        mon.ingest("r0", counters={"decode_tokens": tok,
                                   "decode_s": busy}, now=t)
        mon.evaluate(t)
    assert mon.worst_level() == "page"
    # idle ticks don't vote: a monitor fed a frozen counter never
    # accumulates events, so it stays ok rather than paging on silence
    mon2 = SLOMonitor(["goodput_tokens_per_s > 100"], policy=pol)
    for i in range(70):
        t = i * 0.1
        mon2.ingest("r0", counters={"decode_tokens": 5.0,
                                    "decode_s": 1.0}, now=t)
        mon2.evaluate(t)
    assert mon2.worst_level() == "ok"


def test_burn_policy_timescale_compresses_windows():
    pol = BurnRatePolicy(timescale=1 / 600)
    w = pol.windows()
    assert w["page"] == (6.0, 0.5, 14.4)
    assert w["warn"] == (36.0, 3.0, 6.0)
    assert pol.max_window_s == 36.0


# ----------------------------------------------------------------------------
# fleet percentile merge (the satellite-1 regression): merged-sketch
# p95 tracks the pooled-sample p95; averaging per-replica p95s does not
# ----------------------------------------------------------------------------
def test_fleet_merged_p95_matches_pooled_samples():
    rng = np.random.default_rng(21)
    # replica 0 fast with 97% of traffic, replica 1 an order of
    # magnitude slower with 3% — the regime where mean-of-p95s is
    # maximally wrong (pooled p95 sits in the fast tail; the naive
    # average is dragged toward the nearly-idle slow replica)
    fast = rng.lognormal(-4, 0.3, 9700)
    slow = rng.lognormal(-1.2, 0.3, 300)
    summaries, digests = [], []
    for samples in (fast, slow):
        d = QuantileDigest()
        d.extend(samples)
        p95 = float(np.percentile(samples, 95))
        summaries.append({"requests_total": float(len(samples)),
                          "ttft_p95_s": p95, "ttft_p50_s": p95 / 2})
        digests.append({"ttft_s": d.to_dict()})
    agg = aggregate_summaries(summaries, digests)
    pooled = np.concatenate([fast, slow])
    for p in (50, 95, 99):
        true = float(np.percentile(pooled, p))
        got = agg[f"ttft_p{p}_s"]
        assert abs(got - true) / true < 0.03, f"p{p}: {got} vs {true}"
    naive = float(np.mean([s["ttft_p95_s"] for s in summaries]))
    true95 = float(np.percentile(pooled, 95))
    assert abs(naive - true95) / true95 > 0.5, \
        "the fixture must be one where averaging is badly wrong"
    # replicas with NO samples for a metric neither poison nor appear
    agg2 = aggregate_summaries(summaries + [{"requests_total": 0.0}],
                               digests + [{}])
    assert abs(agg2["ttft_p95_s"] - agg["ttft_p95_s"]) < 1e-12


# ----------------------------------------------------------------------------
# drift auditor units: calibration, alarm direction, healthy quiet
# ----------------------------------------------------------------------------
def test_drift_auditor_alarms_on_slowdown_quiet_when_healthy():
    aud = DriftAuditor()
    rng = np.random.default_rng(9)
    meas = sim = 0.0
    events = []
    # calibration + healthy tracking at a fixed sim/measured factor
    # with ±5% noise: ratio pins near 1.0 (the absolute factor cancels)
    for i in range(30):
        meas += 0.010 * (1 + 0.05 * rng.standard_normal())
        sim += 0.004
        ev = aud.observe(float(i), meas, sim)
        assert ev is None
    assert aud.calibrated
    assert abs(aud.drift_ratio - 1.0) < 0.15
    assert aud.summary()["sim_drift_alarm"] == 0.0
    # measured decode degrades 3x -> two-sided CUSUM trips exactly once
    for i in range(30, 60):
        meas += 0.030
        sim += 0.004
        ev = aud.observe(float(i), meas, sim)
        if ev is not None:
            events.append(ev)
    assert len(events) == 1, "rising-edge alarm, not one per tick"
    assert events[0]["kind"] == "drift_alarm"
    assert events[0]["direction"] == "measured_degraded"
    s = aud.summary()
    assert s["sim_drift_alarm"] == 1.0 and s["sim_drift_alarms"] == 1.0
    assert s["sim_drift_ratio"] < 0.6


def test_drift_auditor_uncalibrated_is_nan_and_idle_ticks_skip():
    aud = DriftAuditor(calib_ticks=5)
    assert math.isnan(aud.drift_ratio)
    meas = sim = 0.0
    for i in range(3):
        meas += 0.01
        sim += 0.01
        aud.observe(float(i), meas, sim)
    # idle ticks (no decode progress) don't advance calibration
    for i in range(3, 20):
        aud.observe(float(i), meas, sim)
    assert not aud.calibrated and math.isnan(aud.drift_ratio)
    assert math.isnan(aud.summary()["sim_drift_ratio"])


# ----------------------------------------------------------------------------
# 2-replica gateway e2e
# ----------------------------------------------------------------------------
def _model():
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                        dtype_override=jnp.float32)
    return model, params


@pytest.fixture(scope="module")
def model_params():
    return _model()


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return PagedServeEngine(model, params, **kw)


async def _raw(host, port, method, path, body=None):
    reader, writer = await asyncio.open_connection(host, port)
    payload = json.dumps(body).encode() if body is not None else b""
    head = f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
    if payload:
        head += f"Content-Length: {len(payload)}\r\n"
    writer.write(head.encode() + b"\r\n" + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    status = int(data.split(b"\r\n", 1)[0].split()[1])
    return status, data.partition(b"\r\n\r\n")[2]


def test_gateway_slo_page_healthz_degraded_and_recorder(model_params):
    """An unmeetable latency objective under a compressed timescale
    must page within seconds: /debug/slo reports worst=page, /healthz
    stays 200 but flips `degraded`, Prometheus exports the level, the
    on_alert hook and every replica's flight recorder see the
    fleet-scope transition."""
    model, params = model_params
    alerts = []

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="least-loaded", max_pending=16)
        router.on_alert(alerts.append)
        gw = Gateway(router, slos=["ttft_p95_s < 0.000000001"],
                     slo_policy=BurnRatePolicy(timescale=1 / 600),
                     slo_poll_s=0.02)
        host, port = await gw.start()
        try:
            # traffic: every request's ttft violates a 1ns objective
            for i in range(6):
                st, _ = await _raw(host, port, "POST", "/v1/completions",
                                   {"prompt": [1 + i, 2, 3],
                                    "max_tokens": 4})
                assert st == 200
            doc = None
            for _ in range(400):            # page long window is 6s
                st, body = await _raw(host, port, "GET", "/debug/slo")
                assert st == 200
                doc = json.loads(body)
                if doc["worst"] == "page":
                    break
                await asyncio.sleep(0.025)
            st_h, body_h = await _raw(host, port, "GET", "/healthz")
            st_m, body_m = await _raw(host, port, "GET", "/metrics")
            _, prom = await _raw(host, port, "GET",
                                 "/metrics?format=prometheus")
            recs = [rep.engine.recorder.snapshot()
                    for rep in router.replicas]
        finally:
            await gw.stop()
        return doc, st_h, json.loads(body_h), json.loads(body_m), \
            prom.decode(), recs

    doc, st_h, health, metrics, prom, recs = asyncio.run(run())
    assert doc["worst"] == "page"
    paged = [s for s in doc["states"] if s["level"] == "page"]
    assert any(s["scope"] == "fleet" for s in paged)
    assert any(ev["to"] == "page" for ev in doc["transitions"])
    # burn rates in the paged state clear the canonical 14.4 factor
    assert all(s["burn"]["page_long"] >= 14.4 for s in paged)
    # /healthz: alive (engines still serve) but degraded
    assert st_h == 200
    assert health["ok"] is True and health["degraded"] is True
    assert health["slo_worst"] == "page"
    # /metrics JSON carries the slo section; Prometheus exports the
    # level gauge at 2 with scope/slo labels and no NaN anywhere
    assert metrics["slo"]["worst"] == "page"
    assert re.search(
        r'repro_slo_alert_level\{scope="fleet",slo="[^"]+"\} 2\b', prom)
    assert "NaN" not in prom
    # the hook fired and every replica's flight recorder can explain
    # the page post-mortem (fleet-scope events fan out to all rings)
    assert any(ev["kind"] == "slo_alert" and ev["to"] == "page"
               for ev in alerts)
    for snap in recs:
        assert any(ev["kind"] == "slo_alert" for ev in snap)


def test_gateway_drift_alarm_on_induced_decode_slowdown(model_params):
    """Digital-twin audit e2e: after calibrating on honest decode
    timings, inflating the measured decode clock 8x trips the CUSUM on
    every replica; the alarm reaches /debug/slo, Prometheus, on_alert,
    and the flight recorder."""
    model, params = model_params
    alerts = []

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="rr", max_pending=16)
        router.on_alert(alerts.append)
        # drift needs no SLO config: the auditor runs whenever the
        # gateway poll loop does
        gw = Gateway(router, slo_poll_s=0.02)
        host, port = await gw.start()
        try:
            async def traffic(n, tokens):
                for i in range(n):
                    st, _ = await _raw(host, port, "POST",
                                       "/v1/completions",
                                       {"prompt": [1 + i % 7, 2, 3],
                                        "max_tokens": tokens})
                    assert st == 200

            # phase 1: calibrate on honest timings
            for _ in range(200):
                await traffic(2, 8)
                if all(rep.drift.calibrated for rep in router.replicas):
                    break
            assert all(rep.drift.calibrated for rep in router.replicas)
            # phase 2: degrade the measured decode clock 8x (the sim
            # prediction is unchanged, so the twin must notice)
            for rep in router.replicas:
                orig = rep.engine._decode_phase

                def slow(orig=orig):
                    d, lanes = orig()
                    return d * 8.0, lanes

                rep.engine._decode_phase = slow
            doc = None
            for _ in range(300):
                await traffic(2, 8)
                st, body = await _raw(host, port, "GET", "/debug/slo")
                doc = json.loads(body)
                drift = doc["drift"]
                if all(d["sim_drift_alarm"] for d in drift.values()):
                    break
            _, prom = await _raw(host, port, "GET",
                                 "/metrics?format=prometheus")
            recs = [rep.engine.recorder.snapshot()
                    for rep in router.replicas]
        finally:
            await gw.stop()
        return doc, prom.decode(), recs

    doc, prom, recs = asyncio.run(run())
    drift = doc["drift"]
    assert len(drift) == 2
    for rid, d in drift.items():
        assert d["sim_drift_alarm"] == 1.0, f"replica {rid} quiet"
        assert d["sim_drift_ratio"] < 0.6, \
            "8x-slower measured decode must push the ratio well under 1"
        assert any(ev["direction"] == "measured_degraded"
                   for ev in d["events"])
    assert 'repro_replica_sim_drift_alarm{replica="0"} 1.0' in prom
    assert "repro_replica_sim_drift_alarms_total" in prom
    assert any(ev["kind"] == "drift_alarm" for ev in alerts)
    for snap in recs:
        assert any(ev["kind"] == "drift_alarm" for ev in snap)


def test_gateway_healthy_run_stays_ok_no_nan(model_params):
    """Under the shipped default SLOs at real timescale, a short healthy
    run never alerts, /healthz is not degraded, merged percentiles are
    finite, and absent metrics stay absent (no NaN) end to end."""
    model, params = model_params

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="least-loaded", max_pending=16)
        gw = Gateway(router, slos=list(DEFAULT_SLOS), slo_poll_s=0.02)
        host, port = await gw.start()
        try:
            for i in range(4):
                st, _ = await _raw(host, port, "POST", "/v1/completions",
                                   {"prompt": [1 + i, 2, 3],
                                    "max_tokens": 4})
                assert st == 200
            await asyncio.sleep(0.1)
            _, body = await _raw(host, port, "GET", "/debug/slo")
            _, body_h = await _raw(host, port, "GET", "/healthz")
            _, body_m = await _raw(host, port, "GET", "/metrics")
            _, prom = await _raw(host, port, "GET",
                                 "/metrics?format=prometheus")
        finally:
            await gw.stop()
        return (json.loads(body), json.loads(body_h),
                json.loads(body_m), prom.decode())

    doc, health, metrics, prom = asyncio.run(run())
    assert doc["worst"] == "ok" and doc["transitions"] == []
    assert health["degraded"] is False
    # aggregated percentiles come from merged sketches and are finite
    eng = metrics["engine"]
    assert eng["ttft_p95_s"] > 0
    assert eng["requests"] == 4.0       # counted once per request
    assert "NaN" not in prom
    # spec decoding is off, so its rate is ABSENT, not NaN
    assert "repro_engine_spec_acceptance_rate" not in prom
