"""Genetic-algorithm DSE: convergence, determinism, operators."""
import numpy as np

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import (EdgeCIMSimulator, GeneticDSE, HWConfig, Objective,
                        run_dse)
from repro.core.dse import decode, encode, polynomial_mutation, sbx_crossover


def test_encode_decode_roundtrip():
    h = HWConfig(c_v=3, c_h=4, t_act_v=5, t_act_h=2, m_mult=3, pe_count=25,
                 bus_ic=1024, bus_it=2048, bus_intra=512)
    assert decode(encode(h)) == h


def test_ga_beats_random_sampling():
    spec = PAPER_SLMS["llama3.2-3b"]
    res = run_dse(spec, alpha=0.5, w_bits=8, seed=0,
                  pop_size=10, generations=15)
    rng = np.random.default_rng(0)
    obj = Objective(spec=spec, alpha=0.5, w_bits=8)
    sim = EdgeCIMSimulator()
    random_costs = [obj(decode(rng.random(9)), sim) for _ in range(30)]
    assert res.best_cost <= min(random_costs) * 1.02


def test_ga_deterministic_given_seed():
    spec = PAPER_SLMS["qwen2.5-0.5b"]
    r1 = run_dse(spec, alpha=1.0, seed=7, pop_size=8, generations=5)
    r2 = run_dse(spec, alpha=1.0, seed=7, pop_size=8, generations=5)
    assert r1.best == r2.best and r1.best_cost == r2.best_cost


def test_ga_history_monotone():
    res = run_dse(PAPER_SLMS["qwen2.5-0.5b"], alpha=1.0, seed=1,
                  pop_size=8, generations=10)
    hist = res.history
    assert all(b <= a + 1e-12 for a, b in zip(hist, hist[1:]))


def test_sbx_children_in_bounds():
    rng = np.random.default_rng(0)
    for _ in range(50):
        c1, c2 = sbx_crossover(rng.random(9), rng.random(9), rng)
        assert (0 <= c1).all() and (c1 <= 1).all()
        assert (0 <= c2).all() and (c2 <= 1).all()


def test_mutation_in_bounds():
    rng = np.random.default_rng(0)
    for _ in range(50):
        m = polynomial_mutation(rng.random(9), rng, p_mut=1.0)
        assert (0 <= m).all() and (m <= 1).all()


def test_alpha_extremes_tradeoff():
    """alpha=1 minimizes latency, alpha=0 minimizes energy (Fig. 7)."""
    spec = PAPER_SLMS["llama3.2-1b"]
    r_lat = run_dse(spec, alpha=1.0, seed=3, pop_size=12, generations=12)
    r_en = run_dse(spec, alpha=0.0, seed=3, pop_size=12, generations=12)
    assert r_lat.best_report.latency_s <= r_en.best_report.latency_s * 1.05
    assert r_en.best_report.energy_j <= r_lat.best_report.energy_j * 1.05
