"""EdgeCIM analytical simulator: paper-number validation + invariants."""
import numpy as np
import pytest

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import (DEFAULT_TECH, EdgeCIMSimulator, HWConfig,
                        chip_area_mm2, peak_tops, search_space_size,
                        stream_bandwidth)
from repro.core.stages import stage_cost, stage_cost_vec

H_REF = HWConfig(c_v=2, c_h=3, t_act_v=4, t_act_h=2, m_mult=2, pe_count=16)
SIM = EdgeCIMSimulator()


def test_search_space_size_matches_paper():
    assert abs(search_space_size() - 3.1e6) / 3.1e6 < 0.02   # "~3.1e6"


def test_llama1b_int4_headline():
    rep = SIM.generate(PAPER_SLMS["llama3.2-1b"], H_REF, 128, 128, 4, 8)
    assert abs(rep.tokens_per_s - 400.0) / 400.0 < 0.10      # paper: 400
    assert abs(rep.tokens_per_j - 181.0) / 181.0 < 0.10      # paper: 181


def test_llama3b_int4_headline():
    rep = SIM.generate(PAPER_SLMS["llama3.2-3b"], H_REF, 128, 128, 4, 8)
    assert abs(rep.tokens_per_s - 139.3) / 139.3 < 0.10      # paper: 139.3


def test_suite_average_matches_paper():
    tps = [SIM.generate(s, H_REF, 128, 128, 4, 8).tokens_per_s
           for s in PAPER_SLMS.values()]
    tpj = [SIM.generate(s, H_REF, 128, 128, 4, 8).tokens_per_j
           for s in PAPER_SLMS.values()]
    assert abs(np.mean(tps) - 336.42) / 336.42 < 0.05        # paper: 336.42
    assert abs(np.mean(tpj) - 173.02) / 173.02 < 0.05        # paper: 173.02


def test_int4_doubles_int8_throughput():
    s = PAPER_SLMS["llama3.2-1b"]
    r4 = SIM.generate(s, H_REF, 128, 128, 4, 8)
    r8 = SIM.generate(s, H_REF, 128, 128, 8, 8)
    assert 1.7 < r4.tokens_per_s / r8.tokens_per_s < 2.1     # paper: ~2x


def test_decode_is_bandwidth_bound():
    """Sec. V: 'the 4096-bit bus is saturated, decoding is bandwidth-bound'
    — latency must track streamed bytes within small overhead."""
    s = PAPER_SLMS["llama3.2-3b"]
    rep = SIM.generate(s, H_REF, 128, 128, 4, 8)
    bw_floor = (s.active_params_per_token() * 0.5
                * 128 / stream_bandwidth(H_REF))
    assert bw_floor <= rep.latency_s < 1.25 * bw_floor


def test_active_tile_overlap_helps():
    """M>=2 (spare tiles prefetch) must never be slower than M=1."""
    s = PAPER_SLMS["llama3.2-1b"]
    h1 = HWConfig(c_v=2, c_h=3, t_act_v=4, t_act_h=2, m_mult=1, pe_count=4)
    h2 = HWConfig(c_v=2, c_h=3, t_act_v=4, t_act_h=2, m_mult=2, pe_count=4)
    r1 = SIM.generate(s, h1, 128, 128, 4, 8)
    r2 = SIM.generate(s, h2, 128, 128, 4, 8)
    assert r2.latency_s < r1.latency_s


def test_narrow_bus_hurts():
    s = PAPER_SLMS["llama3.2-1b"]
    wide = SIM.generate(s, H_REF, 128, 128, 4, 8)
    narrow_h = HWConfig(c_v=2, c_h=3, t_act_v=4, t_act_h=2, m_mult=2,
                        pe_count=16, bus_ic=512)
    narrow = SIM.generate(s, narrow_h, 128, 128, 4, 8)
    assert narrow.latency_s > 2 * wide.latency_s


def test_scalar_vs_vectorized_stage_cost_agree():
    s = PAPER_SLMS["qwen2.5-0.5b"]
    for st, _m in zip(s.decode_stages(256.0), s.layer_multiplicity()):
        sc = stage_cost(st, H_REF, 4, 8)
        sv, ev = stage_cost_vec(
            np.array([st.weight_elems]), np.array([st.kv_stream_elems]),
            np.array([st.macs]), np.array([st.vector_ops]),
            np.array([st.writeback_elems]), H_REF, 4, 8)
        assert abs(sc.seconds - sv[0]) < 1e-12
        assert abs(sc.joules - ev[0]) < 1e-15


def test_area_scales_with_pes():
    small = HWConfig(c_v=1, c_h=1, t_act_v=2, t_act_h=2, m_mult=1, pe_count=4)
    big = HWConfig(c_v=5, c_h=5, t_act_v=8, t_act_h=8, m_mult=8, pe_count=36)
    assert chip_area_mm2(big) > 10 * chip_area_mm2(small)
    assert peak_tops(big, 4) > peak_tops(small, 4)


def test_kv_cache_grows_latency_with_prefill():
    s = PAPER_SLMS["llama3.2-3b"]
    r_small = SIM.generate(s, H_REF, 128, 128, 4, 8)
    r_big = SIM.generate(s, H_REF, 4096, 128, 4, 8)
    assert r_big.latency_s > r_small.latency_s


# ----------------------------------------------------------------------------
# speculative-decoding factor (SpecKnob)
# ----------------------------------------------------------------------------
def test_spec_knob_tokens_per_step_formula():
    from repro.core import SpecKnob
    assert SpecKnob(k=4, accept_rate=0.0).tokens_per_step() == 1.0
    assert SpecKnob(k=4, accept_rate=1.0).tokens_per_step() == 5.0
    assert SpecKnob(k=4, accept_rate=0.5).tokens_per_step() == \
        pytest.approx((1 - 0.5 ** 5) / 0.5)
    assert SpecKnob(k=1, accept_rate=0.3).tokens_per_step() == \
        pytest.approx(1.3)


def test_spec_knob_pricing_bounds_and_monotonicity():
    from repro.core import SpecKnob
    spec = PAPER_SLMS["llama3.2-1b"]
    base = SIM.generate(spec, H_REF, 128, 128, 4, 8)

    # zero acceptance, free drafting: roughly break-even (pays the
    # (k+1)-wide verify compute, saves nothing)
    zero = SIM.generate(spec, H_REF, 128, 128, 4, 8,
                        spec_decode=SpecKnob(k=4, accept_rate=0.0))
    assert 0.8 * base.latency_s < zero.latency_s < 1.3 * base.latency_s

    # full acceptance: speedup approaches E = k + 1 (weight stream
    # amortized over the window; the extra compute costs a little)
    full = SIM.generate(spec, H_REF, 128, 128, 4, 8,
                        spec_decode=SpecKnob(k=4, accept_rate=1.0))
    assert 0.6 * 5 < base.latency_s / full.latency_s <= 5.0
    assert full.energy_j < base.energy_j

    # latency/energy decrease monotonically in accept_rate...
    lats = [SIM.generate(spec, H_REF, 128, 128, 4, 8,
                         spec_decode=SpecKnob(k=4, accept_rate=a)).latency_s
            for a in (0.0, 0.3, 0.6, 0.9)]
    assert all(a > b for a, b in zip(lats, lats[1:]))
    # ...and increase monotonically in draft_cost_ratio
    lats = [SIM.generate(spec, H_REF, 128, 128, 4, 8,
                         spec_decode=SpecKnob(k=4, accept_rate=0.7,
                                              draft_cost_ratio=r)).latency_s
            for r in (0.0, 0.1, 0.3)]
    assert all(a < b for a, b in zip(lats, lats[1:]))


def test_spec_knob_threads_through_objective():
    from repro.core import SpecKnob
    from repro.core.objective import Objective
    spec = PAPER_SLMS["llama3.2-1b"]
    plain = Objective(spec=spec)
    fast = Objective(spec=spec,
                     spec_decode=SpecKnob(k=4, accept_rate=0.8))
    assert fast(H_REF) < plain(H_REF)
    rep = fast.evaluate(H_REF)
    assert rep.spec_decode is not None and rep.spec_decode.k == 4
