"""INT4/INT8 serving hot path (PR 8): fused-dequant kernel refs,
quantized paged KV, and the unified ServeConfig precision API.

Covers the residency guarantee (no full-weight float materialization
traced into quantized decode graphs), numerical agreement of the fused
grouped contraction with the dequant oracle, quantized-KV kernels vs
their refs, page conservation under fork/COW/trim/preempt with scale
pages riding along, the legacy-kwarg deprecation shim, and the
quality/capacity acceptance bars (greedy divergence, logit MSE, lane
capacity vs f32 pools)."""
import asyncio
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import (ref_paged_decode, ref_paged_verify,
                               ref_qmatmul, ref_qmatmul_fused)
from repro.models import DecoderLM, ModelConfig, init_params
from repro.quant.ptq import quantize_params
from repro.quant.qarray import (QTensor, dequant_counters, quantize,
                                reset_dequant_counters)
from repro.serve import (PagedServeEngine, SamplingParams, ServeConfig,
                         ServeRequest)


# ----------------------------------------------------------------------------
# fused grouped contraction vs the dequant oracle
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [4, 8])
def test_fused_qmatmul_matches_dequant_oracle_2d(bits):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    qt = quantize(w, bits=bits, group=16, axis=0)
    ref = ref_qmatmul(x, qt, out_dtype=jnp.float32)
    out = ref_qmatmul_fused(x, qt, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_fused_qmatmul_matches_oracle_expert_stack_and_table():
    rng = np.random.default_rng(1)
    # (E, K, N) expert stack, x: (E, C, K)
    xe = jnp.asarray(rng.normal(size=(4, 5, 32)), jnp.float32)
    we = jnp.asarray(rng.normal(size=(4, 32, 24)), jnp.float32)
    qe = quantize(we, bits=4, group=16, axis=1)
    ref = jnp.einsum("ecd,edf->ecf", xe, qe.dequantize(jnp.float32))
    out = ref_qmatmul_fused(xe, qe, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)
    # (V, K) axis=-1 embedding table contracted over K (tied logits)
    h = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
    tab = jnp.asarray(rng.normal(size=(40, 32)), jnp.float32)
    qt = quantize(tab, bits=4, group=16, axis=1)
    ref = h @ qt.dequantize(jnp.float32).T
    out = ref_qmatmul_fused(h, qt, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_fused_qmatmul_ignores_stale_orig_shape_from_scan_slicing():
    """Under lax.scan a stacked QTensor's leaves are sliced per layer
    while the static orig_shape aux keeps the layer dim; the fused path
    must size itself from the data, not the aux (regression: reshape
    error inside the scanned serve step)."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(2, 32, 24)), jnp.float32)  # (L, K, N)
    qt = quantize(w, bits=4, group=16, axis=1)
    sliced = QTensor(data=qt.data[0], scales=qt.scales[0], bits=4,
                     group=16, axis=qt.axis, orig_shape=qt.orig_shape)
    x = jnp.asarray(rng.normal(size=(3, 32)), jnp.float32)
    ref = x @ qt.dequantize(jnp.float32)[0]
    out = ref_qmatmul_fused(x, sliced, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_dequant_counters_classify_paths():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    qt = quantize(w, bits=4, group=16, axis=0)
    x = jnp.asarray(rng.normal(size=(1, 32)), jnp.float32)
    reset_dequant_counters()
    ref_qmatmul_fused(x, qt)
    assert dequant_counters() == {"full_dequant": 0, "fused_dequant": 1}
    qt.dequantize()
    assert dequant_counters()["full_dequant"] == 1


# ----------------------------------------------------------------------------
# quantized paged KV: kernels vs refs (interpret mode)
# ----------------------------------------------------------------------------
def _quant_pools(rng, n_pages, ps, g, hd):
    k = rng.normal(size=(n_pages, ps, g, hd)).astype(np.float32)
    v = rng.normal(size=(n_pages, ps, g, hd)).astype(np.float32)

    def q(x):
        scale = (np.maximum(np.abs(x).max(-1), 1e-8) / 127.0
                 ).astype(np.float16)       # the STORED scale is f16
        qi = np.clip(np.round(x / scale[..., None].astype(np.float32)),
                     -127, 127)
        return (jnp.asarray(qi, jnp.int8),
                jnp.asarray(scale),
                jnp.asarray(qi * scale[..., None].astype(np.float32),
                            jnp.float32))

    kq, ks, kf = q(k)
    vq, vs, vf = q(v)
    return kq, ks, kf, vq, vs, vf


def test_paged_decode_kernel_quantized_kv_matches_ref():
    from repro.kernels.paged_flash_decode import paged_flash_decode
    rng = np.random.default_rng(4)
    b, g, qpk, hd, ps, n_pages = 2, 2, 2, 16, 4, 8
    tables = jnp.asarray(rng.integers(0, n_pages, (b, 4)), jnp.int32)
    lengths = jnp.asarray([9, 14], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, g, qpk, hd)), jnp.float32)
    kq, ks, kf, vq, vs, vf = _quant_pools(rng, n_pages, ps, g, hd)
    ref = ref_paged_decode(q, kq, vq, tables, lengths,
                           k_scales=ks, v_scales=vs)
    ref_float = ref_paged_decode(q, kf, vf, tables, lengths)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ref_float),
                               atol=1e-5)
    out = paged_flash_decode(q, kq, vq, tables, lengths,
                             k_scales=ks, v_scales=vs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_paged_verify_kernel_quantized_kv_matches_ref():
    from repro.kernels.paged_flash_decode import paged_flash_verify
    rng = np.random.default_rng(5)
    b, s, g, qpk, hd, ps, n_pages = 2, 3, 2, 2, 16, 4, 8
    tables = jnp.asarray(rng.integers(0, n_pages, (b, 4)), jnp.int32)
    lengths = jnp.asarray([5, 8], jnp.int32)       # EXCLUSIVE of window
    q = jnp.asarray(rng.normal(size=(b, s, g, qpk, hd)), jnp.float32)
    kq, ks, _, vq, vs, _ = _quant_pools(rng, n_pages, ps, g, hd)
    ref = ref_paged_verify(q, kq, vq, tables, lengths,
                           k_scales=ks, v_scales=vs)
    out = paged_flash_verify(q, kq, vq, tables, lengths,
                             k_scales=ks, v_scales=vs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------------
# ptq: _pick_group 0 sentinel falls back to unquantized, with a warning
# ----------------------------------------------------------------------------
def test_ptq_unquantizable_leaf_warns_and_stays_float():
    from repro.quant.ptq import _pick_group
    assert _pick_group(7, 128, 16) == 0          # prime K < 8: sentinel
    assert _pick_group(4, 128, 16) == 0          # K < smallest group
    assert _pick_group(13, 128, 16) == 13        # 13 >= 8 divides itself,
    # but odd K still skips int4 below (packing needs K % 2 == 0)
    params = {"blocks": {"wq": jnp.ones((2, 13, 8), jnp.float32)}}
    with pytest.warns(UserWarning, match="no valid group size"):
        out = quantize_params(params, bits=4, group=128)
    w = out["blocks"]["wq"]
    assert not isinstance(w, QTensor), "K=13 leaf must stay float"
    assert w.dtype == jnp.float32
    # eligible leaves still quantize in the same tree
    params["blocks"]["wk"] = jnp.ones((2, 16, 8), jnp.float32)
    with pytest.warns(UserWarning, match="wq"):
        out = quantize_params(params, bits=4, group=128)
    assert isinstance(out["blocks"]["wk"], QTensor)


# ----------------------------------------------------------------------------
# ServeConfig API + deprecation shim
# ----------------------------------------------------------------------------
def _model(vocab=64, d=32):
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=d,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=vocab,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


MODEL, PARAMS = _model()


def test_serve_config_validation_and_resolution():
    with pytest.raises(ValueError, match="precision"):
        ServeConfig(precision="fp8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeConfig(kv_dtype="f64")
    assert ServeConfig(precision="fp").resolved_kv_dtype() == jnp.bfloat16
    assert ServeConfig(precision="int4").resolved_kv_dtype() == jnp.int8
    assert ServeConfig(precision="int4",
                       kv_dtype="bf16").resolved_kv_dtype() == jnp.bfloat16
    d = ServeConfig(precision="int8").as_dict()
    assert d["kv_dtype_resolved"] == "int8" and d["weight_bits"] == 8
    assert ServeConfig(precision="fp").weight_bits() == 16


def test_legacy_kwargs_shim_warns_once_and_maps():
    import repro.serve.engine as engine_mod
    engine_mod._legacy_warned = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = PagedServeEngine(MODEL, PARAMS, max_batch=2, max_seq=64,
                               page_size=4, kv_dtype=jnp.float32)
        eng2 = PagedServeEngine(MODEL, PARAMS, max_batch=2, max_seq=64,
                                page_size=4)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1, "legacy kwargs warn once per process"
    assert eng.config.precision == "fp"
    assert eng.config.kv_dtype == "f32"
    assert eng.config.max_batch == 2 and eng.config.page_size == 4
    assert eng2.config.kv_dtype == "bf16"


def test_config_and_legacy_kwargs_together_is_an_error():
    with pytest.raises(ValueError, match="not both"):
        PagedServeEngine(MODEL, PARAMS, ServeConfig(), max_batch=2)


def test_engine_quantizes_float_params_when_config_says_so():
    eng = PagedServeEngine(MODEL, PARAMS,
                           ServeConfig(precision="int4", quant_group=16,
                                       max_batch=2, max_seq=64,
                                       page_size=4))
    leaves = jax.tree_util.tree_leaves(
        eng.params, is_leaf=lambda x: isinstance(x, QTensor))
    assert any(isinstance(l, QTensor) for l in leaves)
    assert eng.energy.w_bits == 4 and eng.energy.a_bits == 8
    # already-packed params are adopted as-is (replica sharing)
    eng2 = PagedServeEngine(MODEL, eng.params,
                            ServeConfig(precision="int4", quant_group=16,
                                        max_batch=2, max_seq=64,
                                        page_size=4))
    assert eng2.params is eng.params


# ----------------------------------------------------------------------------
# e2e: quantized serving quality + residency + quantized-KV conservation
# ----------------------------------------------------------------------------
def _run_greedy(model, params, cfg, prompt, tokens=12):
    eng = PagedServeEngine(model, params, cfg)
    req = ServeRequest(prompt=prompt, max_new_tokens=tokens, rid=0,
                       sampling=SamplingParams(temperature=0.0))
    eng.run([req])
    return eng, req


def test_quantized_precisions_serve_with_zero_full_dequants():
    prompt = np.arange(1, 9, dtype=np.int32)
    base = ServeConfig(max_batch=2, max_seq=64, page_size=4,
                       quant_group=16)
    _, fp = _run_greedy(MODEL, PARAMS,
                        dataclasses.replace(base, precision="fp"), prompt)
    for precision in ("int8", "int4"):
        cfg = dataclasses.replace(base, precision=precision)
        reset_dequant_counters()
        eng, req = _run_greedy(MODEL, PARAMS, cfg, prompt)
        dq = dequant_counters()
        assert dq["full_dequant"] == 0, \
            f"{precision} traced a full-weight float materialization"
        assert dq["fused_dequant"] > 0
        assert len(req.out_tokens) == len(fp.out_tokens)
        # greedy divergence: int8 must track fp for half the window.
        # The d=32 random-init test model has near-uniform logits, so
        # int4's ~8e-2 logit MSE flips the argmax immediately — its
        # divergence floor is enforced at bench scale by check_bench
        # (--quant-match-min on api_bench_quant), not here.
        if precision == "int8":
            match = 0
            for a, b in zip(fp.out_tokens, req.out_tokens):
                if a != b:
                    break
                match += 1
            assert match >= 6, (fp.out_tokens, req.out_tokens)
        s = eng.summary()
        assert s["weight_full_dequants"] == 0.0
        assert s["weight_fused_dequants"] > 0.0
        assert s["sim_w_bits"] == (8.0 if precision == "int8" else 4.0)


def test_quantized_logit_mse_bounded():
    x = {"tokens": jnp.asarray(np.arange(1, 9, dtype=np.int32)[None])}
    lf = MODEL.forward(PARAMS, x).astype(jnp.float32)
    for bits, ceiling in ((8, 1e-2), (4, 0.5)):
        qp = quantize_params(PARAMS, bits=bits, group=16)
        lq = MODEL.forward(qp, x).astype(jnp.float32)
        mse = float(jnp.mean((lf - lq) ** 2))
        assert mse < ceiling, (bits, mse)


def test_int8_kv_pools_halve_bytes_and_admit_2x_f32_lanes():
    def bytes_per_token(cfg):
        eng = PagedServeEngine(MODEL, PARAMS, cfg)
        total = sum(v.nbytes for v in
                    jax.tree_util.tree_leaves(eng.cache.pools))
        return total / (eng.cache.allocator.n_pages
                        * eng.cache.page_size)

    base = dict(max_batch=2, max_seq=64, page_size=4, quant_group=16)
    f32 = bytes_per_token(ServeConfig(precision="fp", kv_dtype="f32",
                                      **base))
    q = bytes_per_token(ServeConfig(precision="int4", **base))
    assert f32 / q >= 2.0, (f32, q)


def test_quantized_kv_logprobs_track_exact_model():
    """int8 KV pools only quantize the cache: with FLOAT weights, the
    logprob the serving path assigns each sampled token must track the
    exact (non-paged, f32) model's log-softmax for the same stream.
    This bounds the end-to-end int8-KV error without depending on
    argmax stability — the random-init test model's top-1 logit gap
    (~2e-3) is far below even bf16 noise, so greedy-stream equality is
    not a meaningful check at this scale."""
    prompt = np.arange(1, 9, dtype=np.int32)
    cfg = ServeConfig(precision="fp", kv_dtype="int8", max_batch=2,
                      max_seq=64, page_size=4)
    eng = PagedServeEngine(MODEL, PARAMS, cfg)
    req = ServeRequest(prompt=prompt, max_new_tokens=10, rid=0,
                       logprobs=True,
                       sampling=SamplingParams(temperature=1.0))
    eng.run([req])
    assert len(req.out_tokens) == 10
    toks = jnp.asarray(np.concatenate([prompt, req.out_tokens])[None])
    logits = MODEL.forward(PARAMS, {"tokens": toks}).astype(jnp.float32)
    lsm = jax.nn.log_softmax(logits[0], axis=-1)
    errs = [abs(lp - float(lsm[len(prompt) - 1 + i, t]))
            for i, (t, (lp, _)) in
            enumerate(zip(req.out_tokens, req.out_logprobs))]
    assert max(errs) < 0.05, errs


def test_quantized_kv_fork_cow_trim_preempt_conserve_pages():
    """test_cancel's conservation property, on int8 KV pools: any
    interleaving of submits/aborts with fork children and preemptions
    ends with every page free and the scale pages consistent (a fork
    child's greedy stream matches unshared serving, proving COW copied
    the scale pages alongside the int8 rows)."""
    rng = np.random.default_rng(11)
    for trial in range(3):
        cfg = ServeConfig(precision="int4", quant_group=16, max_batch=2,
                          max_seq=32, page_size=4,
                          n_pages=int(rng.integers(10, 16)),
                          prefill_chunk=4,
                          prefix_cache=bool(trial % 2), seed=trial)
        eng = PagedServeEngine(MODEL, PARAMS, cfg)
        n_pages = eng.cache.allocator.n_pages
        reqs, pending = [], []
        for i in range(int(rng.integers(5, 8))):
            prompt = rng.integers(0, 64, int(rng.integers(2, 12))
                                  ).astype(np.int32)
            r = ServeRequest(prompt=prompt, rid=i,
                             max_new_tokens=int(rng.integers(2, 8)),
                             sampling=SamplingParams(
                                 temperature=float(rng.choice([0., 1.]))))
            if reqs and rng.random() < 0.3:
                r.prompt = reqs[-1].prompt.copy()
                r.fork_from = reqs[-1]
            reqs.append(r)
            pending.append(r)
        for _ in range(300):
            if pending and (rng.random() < 0.4 or not eng.busy):
                eng.submit(pending.pop(0))
            elif eng.busy:
                eng.step()
            live = [r for r in reqs if r.eid >= 0 and not r.done]
            if live and rng.random() < 0.2:
                eng.cancel(live[int(rng.integers(0, len(live)))].eid)
            alloc = eng.cache.allocator
            held = {p for pages in alloc._held.values() for p in pages}
            assert alloc.n_free + len(held) == n_pages, \
                (trial, "pages leaked mid-flight")
            if not pending and not eng.busy:
                break
        while eng.busy:
            eng.step()
        assert (eng.cache.n_free_or_cached() == n_pages
                and all(r is None for r in eng.lanes)), trial

    # fork-COW correctness: greedy child == unshared greedy run.
    # Prompt length 10 on page_size 4 shares a PARTIAL tail page
    # (prefix 9 = 2 full pages + 1 token), so the parent's next write
    # must copy-on-write — scale pages ride along with the int8 rows.
    prompt = np.arange(1, 11, dtype=np.int32)
    cfg = ServeConfig(precision="int4", quant_group=16, max_batch=2,
                      max_seq=64, page_size=4)
    _, solo = _run_greedy(MODEL, PARAMS, cfg, prompt, tokens=6)
    eng = PagedServeEngine(MODEL, PARAMS, cfg)
    parent = ServeRequest(prompt=prompt.copy(), max_new_tokens=6, rid=0,
                          sampling=SamplingParams(temperature=0.0))
    child = ServeRequest(prompt=prompt.copy(), max_new_tokens=6, rid=1,
                         fork_from=parent,
                         sampling=SamplingParams(temperature=0.0))
    eng.run([parent, child])
    assert eng.cache.cow_copies > 0, "fork tail page must copy-on-write"
    assert child.out_tokens == solo.out_tokens
    assert parent.out_tokens == solo.out_tokens


def test_mla_rejects_int8_kv():
    from repro.models.config import MLAConfig
    cfg = ModelConfig(name="mla", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False,
                      attn_kind="mla",
                      mla=MLAConfig(kv_lora_rank=16, qk_nope_head_dim=8,
                                    qk_rope_head_dim=8, v_head_dim=16))
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    with pytest.raises(ValueError, match="MLA"):
        PagedServeEngine(model, params,
                         ServeConfig(precision="fp", kv_dtype="int8",
                                     max_batch=2, max_seq=64,
                                     page_size=4))
    # auto means "best supported": quantized weights on MLA degrade the
    # KV pools to bf16 instead of crashing, and the engine's config
    # reports the pinned resolution
    eng = PagedServeEngine(model, params,
                           ServeConfig(precision="int4", quant_group=16,
                                       max_batch=2, max_seq=64,
                                       page_size=4))
    assert eng.config.kv_dtype == "bf16"
    assert eng.config.as_dict()["kv_dtype_resolved"] == "bfloat16"


# ----------------------------------------------------------------------------
# /metrics reports the resolved config
# ----------------------------------------------------------------------------
def test_fleet_metrics_reports_resolved_config():
    from repro.fleet import FleetRouter
    cfg = ServeConfig(precision="int4", quant_group=16, max_batch=2,
                      max_seq=64, page_size=4, max_pending=5)
    eng = PagedServeEngine(MODEL, PARAMS, cfg)
    router = FleetRouter([eng]).start()
    try:
        payload = asyncio.run(router.fleet_metrics())
    finally:
        router.stop()
    c = payload["config"]
    assert c["precision"] == "int4"
    assert c["kv_dtype_resolved"] == "int8"
    assert c["weight_bits"] == 4
    assert router.replicas[0].max_pending == 5, \
        "router must adopt the config's per-replica cap"
