"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# CI/base images without hypothesis skip this module (triaged: the repro
# container pins its package set; see .github/workflows/ci.yml).
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import EdgeCIMSimulator, HWConfig
from repro.core.hw import (ACTIVE_TILE_CHOICES, BUS_WIDTH_CHOICES,
                           CLUSTER_CHOICES, PE_COUNT_CHOICES,
                           TILE_MULT_CHOICES)
from repro.core.pareto import is_dominated, pareto_front
from repro.dist.compress import compress_decompress_roundtrip
from repro.quant.qarray import dequantize, quantize

SIM = EdgeCIMSimulator()

hw_strategy = st.builds(
    HWConfig,
    c_v=st.sampled_from(CLUSTER_CHOICES),
    c_h=st.sampled_from(CLUSTER_CHOICES),
    t_act_v=st.sampled_from(ACTIVE_TILE_CHOICES),
    t_act_h=st.sampled_from(ACTIVE_TILE_CHOICES),
    m_mult=st.sampled_from(TILE_MULT_CHOICES),
    pe_count=st.sampled_from(PE_COUNT_CHOICES),
    bus_ic=st.sampled_from(BUS_WIDTH_CHOICES),
    bus_it=st.sampled_from(BUS_WIDTH_CHOICES),
    bus_intra=st.sampled_from(BUS_WIDTH_CHOICES),
)


@settings(max_examples=25, deadline=None)
@given(h=hw_strategy)
def test_sim_positive_and_finite(h):
    rep = SIM.generate(PAPER_SLMS["qwen2.5-0.5b"], h, 64, 32, 4, 8)
    assert rep.latency_s > 0 and np.isfinite(rep.latency_s)
    assert rep.energy_j > 0 and np.isfinite(rep.energy_j)
    assert rep.area_mm2 > 0


@settings(max_examples=15, deadline=None)
@given(h=hw_strategy, gen=st.integers(8, 64))
def test_sim_monotone_in_generated_tokens(h, gen):
    s = PAPER_SLMS["qwen2.5-0.5b"]
    r1 = SIM.generate(s, h, 64, gen, 4, 8)
    r2 = SIM.generate(s, h, 64, gen + 8, 4, 8)
    assert r2.latency_s > r1.latency_s
    assert r2.energy_j > r1.energy_j


@settings(max_examples=15, deadline=None)
@given(h=hw_strategy)
def test_sim_int8_never_faster_than_int4(h):
    s = PAPER_SLMS["llama3.2-1b"]
    r4 = SIM.generate(s, h, 64, 32, 4, 8)
    r8 = SIM.generate(s, h, 64, 32, 8, 8)
    assert r8.latency_s >= r4.latency_s * 0.999


@settings(max_examples=30, deadline=None)
@given(pts=st.lists(st.tuples(st.floats(0.1, 100), st.floats(0.1, 100)),
                    min_size=1, max_size=40))
def test_pareto_front_is_nondominated_and_covers(pts):
    front = pareto_front(pts)
    assert front, "front never empty"
    for i in front:
        assert not any(is_dominated(pts[i], pts[j])
                       for j in range(len(pts)) if j != i)
    for j in range(len(pts)):
        if j not in front:
            assert any(is_dominated(pts[j], pts[i]) for i in front) or \
                any(pts[i] == pts[j] for i in front)


@settings(max_examples=20, deadline=None)
@given(data=st.lists(st.floats(-100, 100), min_size=2, max_size=64),
       )
def test_int8_compression_error_bounded(data):
    x = jnp.asarray(np.array(data, np.float32))
    y = compress_decompress_roundtrip(x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(y - x))) <= 0.51 * scale + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       k=st.sampled_from([64, 128, 256]),
       bits=st.sampled_from([4, 8]))
def test_quant_preserves_zero_and_sign(seed, k, bits):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, 8), jnp.float32)
    w = w.at[0, :].set(0.0)
    deq = dequantize(quantize(w, bits=bits, group=min(64, k)), jnp.float32)
    assert float(jnp.max(jnp.abs(deq[0]))) < 1e-6          # exact zero
    big = jnp.abs(w) > 0.5 * jnp.max(jnp.abs(w))
    assert bool(jnp.all(jnp.sign(deq[big]) == jnp.sign(w[big])))


@settings(max_examples=10, deadline=None)
@given(idx=st.integers(0, 1000), shards=st.sampled_from([1, 2, 4]))
def test_data_sharding_partitions_global_batch(idx, shards):
    from repro.data import DataConfig, SyntheticLM
    data = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=8))
    full = [data.batch(idx, s, shards)["tokens"] for s in range(shards)]
    stacked = np.concatenate(full, 0)
    assert stacked.shape == (8, 16)
    # deterministic: same call twice identical
    again = np.concatenate(
        [data.batch(idx, s, shards)["tokens"] for s in range(shards)], 0)
    assert (stacked == again).all()
