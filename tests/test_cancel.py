"""Engine abort hardening: `PagedServeEngine.cancel` mid-queue,
mid-prefill, mid-decode, and under random abort interleavings — page
and lane conservation throughout (seeded-random property style, same as
tests/test_prefix_cache.py; hypothesis is not in the container)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DecoderLM, ModelConfig, init_params
from repro.serve import PagedServeEngine, SamplingParams, ServeRequest


def _model():
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


MODEL, PARAMS = _model()


def _engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 4)
    return PagedServeEngine(MODEL, PARAMS, **kw)


def _drained(eng):
    return (eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages
            and all(r is None for r in eng.lanes)
            and eng.scheduler.n_queued == 0)


def test_cancel_queued_request_never_runs():
    eng = _engine(max_batch=1)
    a = ServeRequest(prompt=np.array([1, 2, 3], np.int32),
                     max_new_tokens=4, rid=0)
    b = ServeRequest(prompt=np.array([4, 5, 6], np.int32),
                     max_new_tokens=4, rid=1)
    eng.submit(a)
    eng.submit(b)                   # queued behind a (one lane)
    assert eng.cancel(b.eid)
    while eng.busy:
        eng.step()
    assert a.done and len(a.out_tokens) == 4
    assert b.cancelled and b.out_tokens == []
    assert _drained(eng)
    assert eng.summary()["cancelled"] == 1.0


def test_cancel_mid_prefill_frees_pages_and_lane():
    eng = _engine(prefill_chunk=4)
    req = ServeRequest(prompt=np.arange(20, dtype=np.int32),
                       max_new_tokens=8, rid=0)
    eng.submit(req)
    eng.step()                      # admitted; one 4-token chunk done
    assert 0 < req.prefill_done < req.prompt_len, "mid-prefill"
    assert eng.cancel(req.eid)
    assert req.cancelled and not eng.busy
    assert _drained(eng)
    # engine still serves new traffic on the freed lane
    nxt = ServeRequest(prompt=np.array([7, 8, 9], np.int32),
                       max_new_tokens=3, rid=1)
    eng.run([nxt])
    assert nxt.done and len(nxt.out_tokens) == 3


def test_cancel_mid_decode_keeps_partial_output_and_frees_pages():
    eng = _engine()
    req = ServeRequest(prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=50, rid=0)
    eng.submit(req)
    for _ in range(4):
        eng.step()
    assert len(req.out_tokens) >= 2, "decoding started"
    got = list(req.out_tokens)
    assert eng.cancel(req.eid)
    assert req.out_tokens == got, "partial output stands"
    assert _drained(eng)
    assert not eng.cancel(req.eid), "double-cancel reports unknown"


def test_cancel_unknown_and_finished_ids_return_false():
    eng = _engine()
    req = ServeRequest(prompt=np.array([1, 2], np.int32),
                       max_new_tokens=2, rid=0)
    eng.run([req])
    assert not eng.cancel(req.eid), "finished request is not cancellable"
    assert not eng.cancel(12345)


def test_cancel_fork_parent_falls_back_children_complete():
    """Canceling the parent mid-prefill must not strand fork children:
    they fall back to plain admission and still finish."""
    eng = _engine(max_batch=4, prefill_chunk=4)
    prompt = np.arange(1, 18, dtype=np.int32)
    parent = ServeRequest(prompt=prompt.copy(), max_new_tokens=6, rid=0)
    kids = [ServeRequest(prompt=prompt.copy(), max_new_tokens=6, rid=i,
                         fork_from=parent) for i in (1, 2)]
    eng.submit(parent)
    for k in kids:
        eng.submit(k)
    eng.step()                      # parent admitted, mid-prefill
    assert eng.cancel(parent.eid)
    while eng.busy:
        eng.step()
    assert all(k.done and len(k.out_tokens) == 6 for k in kids)
    assert _drained(eng)


def test_random_abort_interleavings_conserve_pages_and_lanes():
    """The acceptance bar: any interleaving of submissions and aborts —
    queued, mid-prefill, mid-decode, preempted, fork parents and
    children, prefix cache on and off — ends with every page free or
    trie-reclaimable and every lane empty."""
    rng = np.random.default_rng(7)
    for trial in range(4):
        prefix_cache = bool(trial % 2)
        eng = _engine(max_batch=2, max_seq=32, page_size=4,
                      n_pages=int(rng.integers(10, 16)),
                      prefill_chunk=4, prefix_cache=prefix_cache,
                      seed=trial)
        n_pages = eng.cache.allocator.n_pages
        reqs, pending = [], []
        for i in range(int(rng.integers(6, 10))):
            prompt = rng.integers(0, 64, int(rng.integers(2, 14))
                                  ).astype(np.int32)
            r = ServeRequest(prompt=prompt, rid=i,
                             max_new_tokens=int(rng.integers(2, 10)),
                             sampling=SamplingParams(
                                 temperature=float(rng.choice([0.0, 1.0]))))
            if reqs and rng.random() < 0.3:
                r.prompt = reqs[-1].prompt.copy()    # forkable sibling
                r.fork_from = reqs[-1]
            reqs.append(r)
            pending.append(r)
        for _ in range(400):
            if pending and (rng.random() < 0.4 or not eng.busy):
                eng.submit(pending.pop(0))
            elif eng.busy:
                eng.step()
            live = [r for r in reqs if r.eid >= 0 and not r.done]
            if live and rng.random() < 0.25:
                victim = live[int(rng.integers(0, len(live)))]
                eng.cancel(victim.eid)
            # conservation INVARIANT mid-flight, not just at drain:
            # free + uniquely-held == total
            alloc = eng.cache.allocator
            held = {p for pages in alloc._held.values() for p in pages}
            assert alloc.n_free + len(held) == n_pages, \
                (trial, "pages leaked mid-flight")
            if not pending and not eng.busy:
                break
        while eng.busy:
            eng.step()
        assert _drained(eng), (trial, "pages/lanes leaked at drain")
        for r in reqs:
            assert r.done
            assert r.cancelled or r.rejected or r.truncated \
                or len(r.out_tokens) > 0


def test_preempted_fork_child_rebuilds_instead_of_readopting():
    """Regression: a fork child preempted mid-decode requeues with
    (prompt + generated) as its new prompt, which has DIVERGED from the
    parent's pages (the parent samples its own continuation) — so
    preemption must sever `fork_from`.  Re-admitting through the fork
    path would adopt parent KV rows for tokens the child never saw:
    observable as a second fork admission, and as silent KV corruption.
    The greedy child must also stay identical to an unshared run."""
    prompt = np.arange(1, 9, dtype=np.int32)        # 8 tokens, ps 4

    def run(fork):
        # fits both prompts but not both full generations -> preemption
        eng = _engine(max_batch=2, max_seq=64, page_size=4, n_pages=8,
                      prefill_chunk=8)
        parent = ServeRequest(prompt=prompt.copy(), max_new_tokens=30,
                              rid=0,
                              sampling=SamplingParams(temperature=5.0))
        child = ServeRequest(prompt=prompt.copy(), max_new_tokens=12,
                             rid=1, fork_from=parent if fork else None)
        eng.run([parent, child])
        assert _drained(eng)
        return parent, child, eng

    _, base_child, _ = run(fork=False)
    parent, child, eng = run(fork=True)
    assert len(child.prompt) > 8, \
        "scenario must preempt the child (prompt rebuilt with output)"
    assert child.fork_from is None and child.forked_tokens == 0, \
        "preemption must sever the fork link"
    assert eng.telemetry.fork_admissions == 1, \
        "a preempted child must rebuild by prefill, not re-fork"
    assert child.out_tokens == base_child.out_tokens, \
        "preempted fork child diverged from unshared serving"
