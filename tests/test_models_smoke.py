"""Per-architecture smoke tests (REQUIRED): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; plus decode
consistency and a gradient step per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, get_config
from repro.models import DecoderLM, init_params
from repro.models.common import spec_structs

B, S = 2, 32


def _inputs(cfg, key, with_labels=True):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    if cfg.embed_inputs:
        out = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab)}
    else:
        out = {"embeddings": jax.random.normal(
            k1, (B, S, cfg.d_model), jnp.float32).astype(jnp.bfloat16)}
    if with_labels:
        out["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab)
    return out


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
        model = DecoderLM(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             dtype_override=jnp.float32)
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_forward_shapes_and_finite(arch, smoke_models):
    cfg, model, params = smoke_models[arch]
    logits = model.forward(params, _inputs(cfg, 1, with_labels=False))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_train_step_finite_grads(arch, smoke_models):
    cfg, model, params = smoke_models[arch]
    batch = _inputs(cfg, 2)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # at least one nonzero gradient per arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_decode_matches_forward(arch, smoke_models):
    cfg, model, params = smoke_models[arch]
    inp = _inputs(cfg, 3, with_labels=False)
    logits_full = model.forward(params, inp)

    cache = jax.tree_util.tree_map(
        lambda sp: jnp.zeros(sp.shape, sp.dtype),
        spec_structs(model.cache_specs(B, S, kv_dtype=jnp.float32)))
    logits_dec = None
    for t in range(S):
        tok = ({"tokens": inp["tokens"][:, t:t + 1]} if cfg.embed_inputs
               else {"embeddings": inp["embeddings"][:, t:t + 1]})
        logits_dec, cache = model.decode_step(params, cache, tok,
                                              jnp.int32(t))
    err = float(jnp.max(jnp.abs(logits_dec[:, 0, :]
                                - logits_full[:, -1, :])))
    # MoE archs may drop tokens at tiny capacity -> looser bound
    tol = 5e-2 if cfg.moe is not None else 1e-3
    assert err < tol, (arch, err)


@pytest.mark.parametrize("arch", list(ARCH_IDS))
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    table = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }
    L, d, h, kv, ff, v = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v)


def test_moe_dispatch_variants_agree(smoke_models):
    """gather dispatch == onehot dispatch at generous capacity."""
    cfg, model, params = smoke_models["qwen3-moe-235b-a22b"]
    import dataclasses
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="onehot",
                                               capacity_factor=4.0))
    cfg1 = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="gather",
                                               capacity_factor=4.0))
    inp = _inputs(cfg, 5, with_labels=False)
    l1 = DecoderLM(cfg1).forward(params, inp)
    l2 = DecoderLM(cfg2).forward(params, inp)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 2e-2


def test_gemma_local_global_flags():
    cfg = get_config("gemma3-4b")
    flags = [cfg.is_local_layer(i) for i in range(12)]
    assert flags[:6] == [True] * 5 + [False]      # 5:1 local:global
    cfg2 = get_config("gemma2-27b")
    flags2 = [cfg2.is_local_layer(i) for i in range(4)]
    assert flags2 == [True, False, True, False]   # 1:1


def test_mla_chunked_attention_value_dim():
    """Regression: MLA value dim (128) != query dim (192) must survive the
    q-chunked attention path (qs > Q_CHUNK)."""
    import repro.models.attention as A
    old = A.Q_CHUNK
    A.Q_CHUNK = 16
    try:
        cfg = get_smoke_config("deepseek-v2-lite-16b").replace(
            dtype="float32", remat=False)
        model = DecoderLM(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                             dtype_override=jnp.float32)
        inp = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 48),
                                            0, cfg.vocab)}
        out = model.forward(params, inp)
        assert out.shape == (2, 48, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(out)))
    finally:
        A.Q_CHUNK = old
