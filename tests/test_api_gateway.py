"""Gateway e2e over real sockets: SSE streaming byte-identical to the
offline engine, n>1 parallel sampling via KV fork, disconnect
cancellation leaving zero leaked pages, deterministic 429 backpressure,
protocol validation, and the /metrics payload."""
import asyncio
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Gateway, iter_sse
from repro.api.protocol import ProtocolError, parse_completion, sanitize
from repro.models import DecoderLM, ModelConfig, init_params
from repro.serve import PagedServeEngine, ServeRequest


def _model():
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


@pytest.fixture(scope="module")
def model_params():
    return _model()


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return PagedServeEngine(model, params, **kw)


async def _raw_post(host, port, payload: bytes, path="/v1/completions"):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"POST {path} HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n"
                  ).encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data


async def _post(host, port, body: dict):
    return await _raw_post(host, port, json.dumps(body).encode())


async def _get(host, port, path):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data


def _status(raw: bytes) -> int:
    return int(raw.split(b"\r\n", 1)[0].split()[1])


def _body(raw: bytes) -> bytes:
    return raw.partition(b"\r\n\r\n")[2]


def _stream_tokens(raw: bytes):
    """index -> [tokens], plus finish events, from an SSE response."""
    toks, fins = {}, {}
    for e in iter_sse(_body(raw)):
        if "token" in e:
            toks.setdefault(e["index"], []).append(e["token"])
        elif "finish_reason" in e:
            fins[e["index"]] = e["finish_reason"]
    return toks, fins


# ----------------------------------------------------------------------------
# e2e: streaming output is byte-identical to the offline engine
# ----------------------------------------------------------------------------
def test_gateway_sse_greedy_byte_identical_to_offline(model_params):
    model, params = model_params
    prompts = [np.array([1, 2, 3], np.int32),
               np.array([5, 6, 7, 8, 9, 10, 11], np.int32),
               np.array([40, 2, 9, 9], np.int32)]

    offline = _engine(model, params)
    reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=6, rid=i)
            for i, p in enumerate(prompts)]
    offline.run(reqs)
    ref = [r.out_tokens for r in reqs]

    async def run():
        gw = Gateway(_engine(model, params), max_pending=16)
        host, port = await gw.start()
        try:
            # concurrent submission: continuous batching must not
            # change any stream
            raws = await asyncio.gather(*[
                _post(host, port,
                      {"prompt": [int(t) for t in p], "max_tokens": 6})
                for p in prompts])
        finally:
            await gw.stop()
        return raws

    raws = asyncio.run(run())
    for raw, want in zip(raws, ref):
        assert _status(raw) == 200
        toks, fins = _stream_tokens(raw)
        assert toks[0] == want, "gateway stream diverged from offline"
        assert fins[0] == "length"


def test_gateway_json_mode_and_usage(model_params):
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=16)
        host, port = await gw.start()
        try:
            raw = await _post(host, port, {"prompt": [1, 2, 3],
                                           "max_tokens": 4,
                                           "stream": False})
        finally:
            await gw.stop()
        return raw

    raw = asyncio.run(run())
    assert _status(raw) == 200
    out = json.loads(_body(raw))
    assert len(out["choices"]) == 1
    assert len(out["choices"][0]["tokens"]) == 4
    assert out["usage"] == {"prompt_tokens": 3, "completion_tokens": 4}


# ----------------------------------------------------------------------------
# n>1 parallel sampling via PagedKVCache.fork
# ----------------------------------------------------------------------------
def test_gateway_parallel_sampling_forks_share_prompt_pages(model_params):
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=16)
        host, port = await gw.start()
        try:
            raw = await _post(host, port, {
                "prompt": list(range(1, 18)), "max_tokens": 8,
                "temperature": 1.5, "n": 4})
            metrics = json.loads(_body(await _get(host, port,
                                                  "/metrics")))
        finally:
            await gw.stop()
        return raw, metrics

    raw, metrics = asyncio.run(run())
    assert _status(raw) == 200
    toks, fins = _stream_tokens(raw)
    assert sorted(toks) == [0, 1, 2, 3], "all four samples streamed"
    assert all(len(v) == 8 for v in toks.values())
    assert set(fins.values()) == {"length"}
    # prompt pages are fork-shared, and sampling diversifies the forks
    eng = metrics["engine"]
    assert eng["kv_pages_shared"] > 0
    assert eng["fork_admissions"] == 3
    assert eng["prefill_tokens_skipped"] >= 3 * 16
    assert len({tuple(v) for v in toks.values()}) > 1, \
        "temperature sampling must diversify the forked samples"


def test_gateway_parallel_sampling_greedy_forks_match_primary(
        model_params):
    """Greedy n>1 is the sharpest correctness probe: every fork
    re-prefills only the last prompt token over shared pages, so any
    KV corruption from fork/COW shows up as a diverged stream."""
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=16)
        host, port = await gw.start()
        try:
            raw = await _post(host, port, {
                "prompt": list(range(1, 14)), "max_tokens": 6, "n": 3})
        finally:
            await gw.stop()
        return raw

    toks, _ = _stream_tokens(asyncio.run(run()))
    assert toks[1] == toks[0] and toks[2] == toks[0], \
        "greedy forks must reproduce the primary stream exactly"


# ----------------------------------------------------------------------------
# cancellation on client disconnect
# ----------------------------------------------------------------------------
def test_gateway_disconnect_mid_stream_frees_all_pages(model_params):
    model, params = model_params

    async def run():
        eng = _engine(model, params, n_pages=32)
        gw = Gateway(eng, max_pending=16)
        host, port = await gw.start()
        try:
            payload = json.dumps({"prompt": [3, 4, 5, 6, 7, 8, 9, 10, 11],
                                  "max_tokens": 50, "n": 2,
                                  "temperature": 1.0}).encode()
            reader, writer = await asyncio.open_connection(host, port)
            writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                          f"Content-Length: {len(payload)}\r\n\r\n"
                          ).encode() + payload)
            await writer.drain()
            await reader.read(256)          # a few SSE events arrived
            writer.close()                  # hang up mid-generation
            await writer.wait_closed()
            for _ in range(100):            # wait for the abort to land
                state = await asyncio.wrap_future(gw.driver.call(
                    lambda e: (e.cache.n_free_or_cached(),
                               e.cache.allocator.n_pages, e.n_running,
                               e.scheduler.n_queued,
                               e.telemetry.cancelled)))
                if state[4] > 0 and state[2] == 0 and state[3] == 0:
                    break
                await asyncio.sleep(0.05)
        finally:
            await gw.stop()
        return state

    free_or_cached, n_pages, running, queued, cancelled = asyncio.run(run())
    assert cancelled >= 1, "disconnect must cancel the in-flight samples"
    assert running == 0 and queued == 0
    assert free_or_cached == n_pages, \
        "disconnect leaked KV pages (not free and not trie-reclaimable)"


# ----------------------------------------------------------------------------
# backpressure
# ----------------------------------------------------------------------------
def test_gateway_429_with_retry_after_when_saturated(model_params):
    """Deterministic: park the engine thread on an event so the first
    request pins the admission budget, then assert the second sheds."""
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=1)
        host, port = await gw.start()
        gate = threading.Event()
        try:
            gw.driver.call(lambda e: gate.wait(30))     # park the driver
            first = asyncio.ensure_future(
                _post(host, port, {"prompt": [1, 2], "max_tokens": 2}))
            # the first request is accepted (inflight 1) before the
            # second arrives — admission is event-loop-side state
            for _ in range(200):
                if gw.counters["accepted_samples"] == 1:
                    break
                await asyncio.sleep(0.01)
            second = await _post(host, port,
                                 {"prompt": [3, 4], "max_tokens": 2})
            gate.set()
            first_raw = await first
        finally:
            gate.set()
            await gw.stop()
        return first_raw, second

    first_raw, second = asyncio.run(run())
    assert _status(first_raw) == 200
    assert _status(second) == 429
    assert b"retry-after" in second.lower()
    body = json.loads(_body(second))
    assert "error" in body


# ----------------------------------------------------------------------------
# protocol validation + metrics payload
# ----------------------------------------------------------------------------
def test_parse_completion_rejects_malformed_bodies():
    for bad, why in [
            (b"", "not JSON"),
            (b"[1,2]", "not an object"),
            (b'{"prompt": "hi"}', "string prompt (no tokenizer)"),
            (b'{"prompt": []}', "empty prompt"),
            (b'{"prompt": [1, -2]}', "negative token"),
            (b'{"prompt": [1, 99]}', "token out of vocab"),
            (b'{"prompt": [1], "max_tokens": 0}', "zero budget"),
            (b'{"prompt": [1], "n": 99}', "n beyond max_n"),
            (b'{"prompt": [1], "temperature": -1}', "bad temperature"),
            (b'{"prompt": [1], "top_p": 2.0}', "bad top_p"),
            (b'{"prompt": [1], "deadline_s": 0}', "bad deadline")]:
        with pytest.raises(ProtocolError):
            parse_completion(bad, vocab=64, max_n=8)
    req = parse_completion(b'{"prompt": [1, 2], "n": 2, "stream": false}',
                           vocab=64)
    assert req.n == 2 and req.stream is False


def test_gateway_http_errors(model_params):
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=4)
        host, port = await gw.start()
        try:
            bad = await _raw_post(host, port, b"not json")
            missing = await _get(host, port, "/nope")
            health = await _get(host, port, "/healthz")
        finally:
            await gw.stop()
        return bad, missing, health

    bad, missing, health = asyncio.run(run())
    assert _status(bad) == 400
    assert _status(missing) == 404
    assert _status(health) == 200 and json.loads(_body(health))["ok"]


def test_gateway_metrics_percentiles_and_histograms(model_params):
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=16)
        host, port = await gw.start()
        try:
            for _ in range(2):
                await _post(host, port, {"prompt": [1, 2, 3],
                                         "max_tokens": 4})
            raw = await _get(host, port, "/metrics")
        finally:
            await gw.stop()
        return raw

    m = json.loads(_body(asyncio.run(run())))   # strict JSON (no NaN)
    eng = m["engine"]
    for key in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s", "itl_p50_s",
                "itl_p95_s", "itl_p99_s", "queue_p95_s", "cancelled",
                "fork_admissions"):
        assert key in eng, key
    assert eng["ttft_p95_s"] is not None
    hist = m["histograms"]
    for name in ("ttft_s", "queue_s", "itl_s"):
        h = hist[name]
        assert len(h["counts"]) == len(h["edges_s"]) - 1
    # every measured TTFT landed in some bucket
    assert sum(hist["ttft_s"]["counts"]) == 2
    assert m["gateway"]["accepted_samples"] == 2
    assert m["gateway"]["inflight"] == 0


def test_sanitize_strips_nonfinite():
    out = sanitize({"a": float("nan"), "b": [1.0, float("inf")],
                    "c": {"d": 2}})
    assert out == {"a": None, "b": [1.0, None], "c": {"d": 2}}
    json.dumps(out, allow_nan=False)        # must not raise


def test_gateway_stray_trailing_byte_does_not_abort_stream(model_params):
    """A client that sends a stray byte after the body (trailing CRLF,
    half-baked pipelining) must still receive its full stream — only a
    true EOF or a broken socket is a disconnect."""
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=8)
        host, port = await gw.start()
        try:
            payload = json.dumps({"prompt": [1, 2, 3],
                                  "max_tokens": 5}).encode()
            reader, writer = await asyncio.open_connection(host, port)
            writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                          f"Content-Length: {len(payload)}\r\n\r\n"
                          ).encode() + payload + b"\r\n")   # stray bytes
            await writer.drain()
            raw = await reader.read()
            writer.close()
        finally:
            await gw.stop()
        return raw

    raw = asyncio.run(run())
    assert _status(raw) == 200
    toks, fins = _stream_tokens(raw)
    assert len(toks[0]) == 5 and fins[0] == "length"
    assert b"[DONE]" in raw


def test_gateway_logprobs_round_trip(model_params):
    """`logprobs=true` adds per-token logprob + entropy to both wire
    modes; greedy decoding's processed distribution is one-hot, so the
    values are exactly 0.  Off by default: no extra keys, no cost."""
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=8)
        host, port = await gw.start()
        try:
            on = await _post(host, port, {"prompt": [1, 2, 3],
                                          "max_tokens": 4,
                                          "logprobs": True})
            off = await _post(host, port, {"prompt": [1, 2, 3],
                                           "max_tokens": 4})
            body = await _post(host, port, {"prompt": [1, 2, 3],
                                            "max_tokens": 4, "n": 2,
                                            "stream": False,
                                            "logprobs": True})
        finally:
            await gw.stop()
        return on, off, body

    on, off, body = asyncio.run(run())
    on_events = [e for e in iter_sse(_body(on)) if "token" in e]
    assert len(on_events) == 4
    for e in on_events:
        assert e["logprob"] == 0.0 and e["entropy"] == 0.0, \
            "greedy sampling is deterministic: logprob 0, entropy 0"
    for e in iter_sse(_body(off)):
        assert "logprob" not in e and "entropy" not in e, \
            "logprobs are strictly opt-in"
    choices = json.loads(_body(body))["choices"]
    for c in choices:
        assert len(c["logprobs"]) == len(c["tokens"])
        assert all(lp["logprob"] == 0.0 and lp["entropy"] == 0.0
                   for lp in c["logprobs"])
    # the two greedy samples decoded identical streams either way
    assert choices[0]["tokens"] == choices[1]["tokens"] \
        == [e["token"] for e in on_events]


def test_gateway_healthz_503_when_driver_dead(model_params):
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=8)
        host, port = await gw.start()
        try:
            ok = await _get(host, port, "/healthz")
            gw.driver.stop()                    # engine thread gone
            dead = await _get(host, port, "/healthz")
        finally:
            await gw.stop()
        return ok, dead

    ok, dead = asyncio.run(run())
    assert _status(ok) == 200
    assert _status(dead) == 503, \
        "a dead engine driver must fail status-code liveness probes"


def test_telemetry_retention_is_bounded():
    """The gateway runs the engine indefinitely: traces and ITL samples
    must not grow with total traffic served, while monotonic counters
    keep exact totals."""
    from repro.serve.telemetry import (MAX_DONE_TRACES, MAX_ITL_SAMPLES,
                                       Telemetry)
    t = Telemetry()
    n = MAX_DONE_TRACES + 500
    for rid in range(n):
        t.enqueue(rid, float(rid))
        t.token(rid, float(rid) + 0.1, decode=False)
        for j in range(2, 6):
            t.token(rid, float(rid) + 0.1 * j)
        t.done(rid, float(rid) + 1.0)
    assert len(t.traces) <= MAX_DONE_TRACES
    assert len(t.itl_samples) <= MAX_ITL_SAMPLES
    s = t.summary()
    assert s["requests"] == float(n), "monotonic totals stay exact"
    assert s["tokens"] == float(5 * n)
    assert np.isfinite(s["ttft_p99_s"]) and np.isfinite(s["itl_p95_s"])


def test_gateway_completions_503_when_driver_dead(model_params):
    """A dead engine thread must fail requests fast (503), not hang
    the handler and leak the admission budget."""
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=8)
        host, port = await gw.start()
        try:
            gw.driver.stop()
            raw = await _post(host, port, {"prompt": [1, 2],
                                           "max_tokens": 2})
            inflight = gw._inflight
        finally:
            await gw.stop()
        return raw, inflight

    raw, inflight = asyncio.run(run())
    assert _status(raw) == 503
    assert inflight == 0, "rejected request must not leak the budget"


def test_gateway_malformed_framing_gets_400_not_crash(model_params):
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=8)
        host, port = await gw.start()
        out = []
        try:
            for raw_req in [
                    b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                    b"Content-Length: -1\r\n\r\n",
                    b"GARBAGE\r\n\r\n",
                    b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                    b"Content-Length: 99999999999\r\n\r\n"]:
                reader, writer = await asyncio.open_connection(host, port)
                writer.write(raw_req)
                await writer.drain()
                out.append(await reader.read())
                writer.close()
            health = await _get(host, port, "/healthz")
        finally:
            await gw.stop()
        return out, health

    out, health = asyncio.run(run())
    for raw in out:
        assert _status(raw) == 400, raw[:80]
    assert _status(health) == 200, "malformed framing must not kill it"


def test_gateway_json_mode_survives_client_half_close(model_params):
    """stream=false with a client that half-closes its write side after
    the request (legal HTTP/1.1) must still produce the completion."""
    model, params = model_params

    async def run():
        gw = Gateway(_engine(model, params), max_pending=8)
        host, port = await gw.start()
        try:
            payload = json.dumps({"prompt": [2, 3, 4], "max_tokens": 3,
                                  "stream": False}).encode()
            reader, writer = await asyncio.open_connection(host, port)
            writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                          f"Content-Length: {len(payload)}\r\n\r\n"
                          ).encode() + payload)
            await writer.drain()
            writer.write_eof()          # half-close: still reading
            raw = await reader.read()
            writer.close()
        finally:
            await gw.stop()
        return raw

    raw = asyncio.run(run())
    assert _status(raw) == 200
    body = json.loads(_body(raw))
    assert len(body["choices"][0]["tokens"]) == 3
    assert body["choices"][0]["finish_reason"] == "length"


def test_driver_fatal_step_error_fails_watchers_and_future_calls():
    """A fatal engine.step() must not strand clients: watched requests
    are failed (on_done fires, inflight budget releases) and later
    driver.call()s fail fast instead of hanging forever."""
    import time as _time
    from types import SimpleNamespace

    from repro.api import EngineDriver

    class ExplodingEngine:
        busy = True

        def __init__(self):
            self._eid = 0

        def submit(self, r):
            r.eid = self._eid
            self._eid += 1

        def step(self):
            raise RuntimeError("device on fire")

    drv = EngineDriver(ExplodingEngine())
    req = SimpleNamespace(done=False, cancelled=False, rid=0, eid=-1)
    fired = []
    drv.submit([req], fired.append)
    drv.start()
    for _ in range(200):
        if not drv.alive:
            break
        _time.sleep(0.01)
    assert not drv.alive and isinstance(drv.error, RuntimeError)
    assert req.done and req.cancelled, "in-flight request failed over"
    assert fired == [req], "on_done fired exactly once"
    with pytest.raises(RuntimeError):
        drv.call(lambda e: None).result(timeout=5)


def test_read_http_request_rejects_header_floods():
    from repro.api.protocol import read_http_request

    async def parse(raw: bytes):
        reader = asyncio.StreamReader()
        reader.feed_data(raw)
        reader.feed_eof()
        return await read_http_request(reader)

    flood = (b"GET / HTTP/1.1\r\n"
             + b"".join(b"x%d: y\r\n" % i for i in range(1000))
             + b"\r\n")
    with pytest.raises(ProtocolError, match="too many headers"):
        asyncio.run(parse(flood))
    # a normal request with plenty of headroom still parses
    method, path, headers, body = asyncio.run(parse(
        b"POST /p HTTP/1.1\r\nA: 1\r\nContent-Length: 2\r\n\r\nhi"))
    assert (method, path, body) == ("POST", "/p", b"hi")
    assert headers["a"] == "1"


def test_parse_completion_spec_and_stream_are_strict_bools():
    for key in ("stream", "spec"):
        with pytest.raises(ProtocolError, match=key):
            parse_completion(
                json.dumps({"prompt": [1], key: "false"}).encode(),
                vocab=64)
    req = parse_completion(b'{"prompt": [1], "spec": false}', vocab=64)
    assert req.spec is False and req.stream is True
