"""Property tests for the paged-KV block allocator and scheduler.

Hypothesis is not in the container's package set, so these drive the
invariants with seeded random op sequences instead — same coverage
style, zero extra deps.
"""
import numpy as np
import pytest

from repro.models import DecoderLM, ModelConfig
from repro.serve import (BlockAllocator, OutOfPagesError, PagedKVCache,
                         Scheduler, ServeRequest)


def test_allocator_basic_invariants():
    a = BlockAllocator(8)
    p1 = a.alloc(owner=1, n=3)
    p2 = a.alloc(owner=2, n=2)
    assert len(set(p1) | set(p2)) == 5, "no page handed out twice"
    assert a.n_free == 3
    assert a.occupancy() == pytest.approx(5 / 8)
    freed = a.free(1)
    assert sorted(freed) == sorted(p1), "free returns exactly owner's pages"
    assert a.n_free == 6
    with pytest.raises(KeyError):
        a.free(1)               # double free of an owner is an error
    with pytest.raises(KeyError):
        a.free(99)              # unknown owner is an error, not a no-op
    with pytest.raises(KeyError):
        a.free_pages(99, p2[:1])


def test_allocator_exhaustion_raises_and_recovers():
    a = BlockAllocator(4)
    a.alloc(0, 4)
    assert not a.can_alloc(1)
    with pytest.raises(OutOfPagesError):
        a.alloc(1, 1)
    a.free(0)
    assert a.n_free == 4
    assert len(a.alloc(1, 4)) == 4


def test_allocator_random_ops_preserve_invariants():
    """Randomized alloc/free interleavings: pages are conserved, never
    double-held, and occupancy accounting matches the ledger."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        n_pages = int(rng.integers(4, 40))
        a = BlockAllocator(n_pages)
        held = {}
        for _ in range(200):
            if rng.random() < 0.6 and a.n_free > 0:
                owner = int(rng.integers(0, 8))
                n = int(rng.integers(1, a.n_free + 1))
                pages = a.alloc(owner, n)
                held.setdefault(owner, []).extend(pages)
            elif held:
                owner = int(rng.choice(list(held)))
                got = a.free(owner)
                assert sorted(got) == sorted(held.pop(owner))
            all_held = [p for ps in held.values() for p in ps]
            assert len(all_held) == len(set(all_held)), "double-held page"
            assert a.n_free + len(all_held) == n_pages, "pages leaked"
            assert a.occupancy() == pytest.approx(len(all_held) / n_pages)
            for owner, ps in held.items():
                assert a.n_held(owner) == len(ps)


def _cache(n_pages=16, page_size=4, max_seq=32):
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    return PagedKVCache(DecoderLM(cfg), n_pages, page_size, max_seq)


def test_paged_cache_admit_grow_release():
    c = _cache()
    seq = c.admit(rid=7, prompt_len=6)          # 2 pages of 4
    assert len(seq.pages) == 2
    seq.length = 6
    assert c.ensure_room(7, 3)                  # 9 tokens -> 3 pages
    assert len(seq.pages) == 3
    tab = c.table_for(7)
    assert tab.shape == (8,)
    assert list(tab[:3]) == seq.pages
    c.release(7)
    assert c.allocator.n_free == 16
    assert 7 not in c.seqs


def test_paged_cache_room_respects_max_seq_and_pool():
    c = _cache(n_pages=4, page_size=4, max_seq=16)
    c.admit(rid=0, prompt_len=12)               # 3 of 4 pages
    c.seqs[0].length = 12
    assert c.ensure_room(0, 4)                  # hits exactly max_seq
    c.seqs[0].length = 16
    assert not c.ensure_room(0, 1), "cannot grow past max_seq"
    c2 = _cache(n_pages=3, page_size=4, max_seq=32)
    c2.admit(rid=1, prompt_len=12)
    c2.seqs[1].length = 12
    assert not c2.ensure_room(1, 1), "pool exhausted"


def test_allocator_free_pages_partial_and_double_free():
    a = BlockAllocator(8)
    pages = a.alloc(owner=1, n=5)
    a.free_pages(1, pages[3:])                  # tail rollback
    assert a.n_held(1) == 3 and a.n_free == 5
    with pytest.raises(ValueError):
        a.free_pages(1, [pages[4]])             # double-free is an error
    a.free_pages(1, pages[:3])
    assert a.n_held(1) == 0 and a.n_free == 8


def test_trim_frees_tail_pages_and_keeps_table_prefix():
    c = _cache(n_pages=16, page_size=4, max_seq=64)
    seq = c.admit(rid=3, prompt_len=6)          # 2 pages
    seq.length = 6
    assert c.ensure_room(3, 7)                  # 13 tokens -> 4 pages
    seq.length = 13
    kept = list(seq.pages[:2])
    freed = c.trim(3, 7)                        # roll back to 7 -> 2 pages
    assert freed == 2 and seq.length == 7
    assert seq.pages == kept, "surviving table prefix untouched"
    assert c.allocator.n_free == 14
    assert c.trim(3, 7) == 0, "idempotent at the same length"
    c.release(3)
    assert c.allocator.n_free == 16


def test_paged_cache_spec_append_rollback_property():
    """Speculative decode hammers (multi-token append -> partial
    rollback) on the allocator.  Seeded random op sequences: pages are
    conserved, never double-held, capacity always covers length, and
    every sequence's block table stays a prefix of its page list."""
    rng = np.random.default_rng(7)
    for trial in range(15):
        page_size = int(rng.choice([2, 4, 8]))
        max_seq = 64
        n_pages = int(rng.integers(6, 24))
        c = _cache(n_pages=n_pages, page_size=page_size, max_seq=max_seq)
        live = {}
        next_rid = 0
        for _ in range(300):
            op = rng.random()
            if (op < 0.3 or not live) and c.allocator.can_alloc(1):
                plen = int(rng.integers(1, 2 * page_size))
                if c.allocator.can_alloc(c.pages_needed(plen)):
                    seq = c.admit(next_rid, plen)
                    seq.length = plen
                    live[next_rid] = seq
                    next_rid += 1
            elif op < 0.7 and live:
                rid = int(rng.choice(list(live)))
                seq = live[rid]
                window = int(rng.integers(1, 6))    # k-token append
                if c.ensure_room(rid, window):
                    seq.length += window
                    accepted = int(rng.integers(0, window + 1))
                    c.trim(rid, seq.length - (window - accepted))
            elif op < 0.9 and live:
                rid = int(rng.choice(list(live)))
                c.release(rid)
                live.pop(rid)
            # invariants
            held = [p for s in live.values() for p in s.pages]
            assert len(held) == len(set(held)), "page double-held"
            assert c.allocator.n_free + len(held) == n_pages, "leak"
            for rid, seq in live.items():
                assert seq.capacity(page_size) >= seq.length
                assert seq.length <= max_seq
                assert c.allocator.n_held(rid) == len(seq.pages)
                tab = c.table_for(rid)
                assert list(tab[:len(seq.pages)]) == seq.pages
        for rid in list(live):
            c.release(rid)
        assert c.allocator.n_free == n_pages, "drain leaves pages behind"


def test_scheduler_priority_and_deadline():
    c = _cache(n_pages=32, page_size=4, max_seq=32)
    s = Scheduler(max_batch=2)
    lo = ServeRequest(prompt=np.arange(4, dtype=np.int32), rid=0, eid=0,
                      priority=5)
    hi = ServeRequest(prompt=np.arange(4, dtype=np.int32), rid=1, eid=1,
                      priority=0)
    late = ServeRequest(prompt=np.arange(4, dtype=np.int32), rid=2, eid=2,
                        priority=0, deadline_s=1.0)
    s.submit(lo, now=0.0)
    s.submit(hi, now=0.0)
    s.submit(late, now=0.0)
    admitted = s.admit(now=5.0, n_running=0, cache=c)
    # `late` expired at t=1 and is rejected; `hi` outranks `lo`
    assert [r.rid for r in admitted] == [1, 0]
    assert late.rejected and late.done
    assert not lo.rejected


def test_scheduler_admission_gated_on_pages():
    c = _cache(n_pages=6, page_size=4, max_seq=32)
    other = c.admit(rid=9, prompt_len=12)       # occupies 3 of 6 pages
    assert len(other.pages) == 3
    s = Scheduler(max_batch=4)
    big = ServeRequest(prompt=np.arange(12, dtype=np.int32), rid=0, eid=0)
    s.submit(big, now=0.0)
    # 12 tokens need 3 pages + 1 growth page > 3 FREE -> stays queued
    # (it fits the pool total, so it must wait, not be rejected)
    assert s.admit(now=0.0, n_running=1, cache=c) == []
    assert s.n_queued == 1
    small = ServeRequest(prompt=np.arange(4, dtype=np.int32), rid=1, eid=1)
    s.submit(small, now=0.0)
    # head-of-line: the big request blocks; nothing is admitted
    assert s.admit(now=0.0, n_running=1, cache=c) == []
    # once pages free up, it admits
    c.release(9)
    assert [r.rid for r in s.admit(now=0.0, n_running=0, cache=c)] == [0, 1]


def test_resubmit_preserves_original_deadline():
    """Preemption resubmits with resubmit=True: the deadline stays
    anchored to first arrival, not to the eviction time."""
    c = _cache()
    s = Scheduler(max_batch=2)
    r = ServeRequest(prompt=np.arange(4, dtype=np.int32), rid=0, eid=0,
                     deadline_s=1.0)
    s.submit(r, now=0.0)
    assert [x.eid for x in s.admit(now=0.5, n_running=0, cache=c)] == [0]
    c.release(0)
    s.submit(r, now=5.0, resubmit=True)      # preemption path
    assert s.admit(now=5.0, n_running=0, cache=c) == []
    assert r.done, "deadline measured from t=0, so t=5 is expired"


def test_scheduler_rejects_request_that_can_never_fit():
    c = _cache(n_pages=3, page_size=4, max_seq=32)
    s = Scheduler(max_batch=4)
    big = ServeRequest(prompt=np.arange(12, dtype=np.int32), rid=0)
    s.submit(big, now=0.0)
    # needs 4 pages but the pool only HAS 3: deferring would spin forever
    assert s.admit(now=0.0, n_running=0, cache=c) == []
    assert big.rejected and s.n_queued == 0
