"""Speculative decoding: drafters, acceptance rule, verify step, engine
equivalence (the tentpole guarantee: greedy spec output is byte-identical
to plain decode), rollback hygiene."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import DecoderLM, ModelConfig, init_params
from repro.models.common import spec_structs
from repro.serve import (PagedServeEngine, SamplingParams, ServeRequest)
from repro.serve.sampling import processed_probs
from repro.spec import (DraftModelDrafter, NGramDrafter, SpecConfig,
                        accept_draft)


def _model(seed=0, **kw):
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False, **kw)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         dtype_override=jnp.float32)
    return model, params


def _zeros(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  spec_structs(tree))


PROMPTS = [np.array([1, 2, 3, 1, 2, 3, 1, 2], np.int32),      # repetitive
           np.array([7, 9, 11], np.int32),                     # short
           np.arange(10, 30, dtype=np.int32) % 64]             # long


def _run(model, params, spec, prompts=PROMPTS, new=12, **kw):
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=8, prefill_chunk=8, spec=spec, **kw)
    reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=new, rid=i)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return [r.out_tokens for r in reqs], eng


# ----------------------------------------------------------------------------
# n-gram drafter
# ----------------------------------------------------------------------------
def test_ngram_drafter_finds_repetition():
    d = NGramDrafter(ngram_max=3)
    h = np.array([5, 6, 7, 8, 5, 6, 7], np.int32)
    prop = d.propose([h], k=4, sampling=[None])
    # suffix [5,6,7] matched at position 0 -> continuation [8, 5, 6, 7]
    assert list(prop.tokens[0][:prop.n[0]]) == [8, 5, 6, 7]
    assert prop.probs is None


def test_ngram_drafter_prefers_longest_then_most_recent():
    d = NGramDrafter(ngram_max=3)
    # suffix [2,3] occurs twice; the LATER occurrence's continuation wins
    h = np.array([2, 3, 9, 1, 2, 3, 7, 4, 2, 3], np.int32)
    prop = d.propose([h], k=2, sampling=[None])
    assert list(prop.tokens[0][:prop.n[0]]) == [7, 4]


def test_ngram_drafter_no_match_is_empty():
    d = NGramDrafter()
    prop = d.propose([np.array([1, 2, 3, 4, 5], np.int32)], k=4,
                     sampling=[None])
    assert prop.n[0] == 0
    prop = d.propose([None, np.array([1], np.int32)], k=4,
                     sampling=[None, None])
    assert list(prop.n) == [0, 0]


# ----------------------------------------------------------------------------
# acceptance rule
# ----------------------------------------------------------------------------
def test_accept_draft_greedy_exact_match():
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((4, 16))
    tops = np.argmax(logits, axis=-1)
    g = SamplingParams(temperature=0.0)
    # full acceptance: drafts == argmax everywhere
    n, emitted = accept_draft(logits, tops[:3], None, g, rng)
    assert n == 3 and emitted == list(tops[:4])
    # first mismatch stops the walk and emits the target's token
    draft = tops[:3].copy()
    draft[1] = (draft[1] + 1) % 16
    n, emitted = accept_draft(logits, draft, None, g, rng)
    assert n == 1 and emitted == [int(tops[0]), int(tops[1])]


def test_accept_draft_pointmass_preserves_distribution():
    """Prompt-lookup acceptance (q = point mass) must serve exactly the
    target distribution: empirical frequencies of the FIRST emitted
    token over many walks match p within sampling noise."""
    rng_logits = np.random.default_rng(1)
    logits = rng_logits.standard_normal((2, 8)) * 2.0
    sp = SamplingParams(temperature=1.0)
    p = processed_probs(logits[0], 1.0, 0, 1.0)
    draft = np.array([3], np.int32)          # always propose token 3
    rng = np.random.default_rng(2)
    counts = np.zeros(8)
    trials = 4000
    for _ in range(trials):
        n, emitted = accept_draft(logits, draft, None, sp, rng)
        counts[emitted[0]] += 1
    emp = counts / trials
    assert np.abs(emp - p).max() < 0.03, (emp, p)


def test_accept_draft_model_q_preserves_distribution():
    """Full-q acceptance: draft tokens sampled from q, accepted with
    min(1, p/q), residual on reject — first emitted token ~ p."""
    rng_logits = np.random.default_rng(3)
    logits = rng_logits.standard_normal((2, 8)) * 1.5
    sp = SamplingParams(temperature=1.0)
    p = processed_probs(logits[0], 1.0, 0, 1.0)
    q = processed_probs(rng_logits.standard_normal(8) * 1.5, 1.0, 0, 1.0)
    rng = np.random.default_rng(4)
    counts = np.zeros(8)
    trials = 4000
    for _ in range(trials):
        x = rng.choice(8, p=q)               # draft genuinely sampled ~ q
        n, emitted = accept_draft(logits, np.array([x]), q[None, :], sp,
                                  rng)
        counts[emitted[0]] += 1
    emp = counts / trials
    assert np.abs(emp - p).max() < 0.03, (emp, p)


def test_accept_draft_respects_truncation():
    """A draft token outside the lane's top-k support must never be
    emitted — acceptance judges against the PROCESSED distribution."""
    logits = np.zeros((2, 8))
    logits[0, :4] = 10.0                     # top-4 dominates
    sp = SamplingParams(temperature=1.0, top_k=4)
    rng = np.random.default_rng(0)
    for _ in range(50):
        n, emitted = accept_draft(logits, np.array([6]), None, sp, rng)
        assert n == 0 and emitted[0] < 4


# ----------------------------------------------------------------------------
# verify step == sequential decode
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [{}, {"local_window": 3, "local_pattern": 2,
                                     "rope_theta_local": 10000.0}])
def test_paged_verify_step_matches_sequential(kw):
    model, params = _model(**kw)
    toks = np.array([5, 9, 3, 17, 2, 41, 8, 30], np.int32)
    tables = jnp.asarray([[3, 7, 1, 5, 0, 0, 0, 0]], jnp.int32)

    pool = _zeros(model.paged_cache_specs(10, 4, jnp.float32))
    seq = []
    for t, tok in enumerate(toks):
        lg, pool = model.paged_step(
            params, pool, {"tokens": jnp.asarray([[tok]])}, tables,
            jnp.asarray([t], jnp.int32), jnp.asarray([1], jnp.int32))
        seq.append(np.asarray(lg[0, 0]))

    pool2 = _zeros(model.paged_cache_specs(10, 4, jnp.float32))
    lg, pool2 = model.paged_step(
        params, pool2, {"tokens": jnp.asarray(toks[None, :3])}, tables,
        jnp.asarray([0], jnp.int32), jnp.asarray([3], jnp.int32))
    vg, pool2 = model.paged_verify_step(
        params, pool2, {"tokens": jnp.asarray(toks[None, 3:])}, tables,
        jnp.asarray([3], jnp.int32), jnp.asarray([5], jnp.int32))
    for i in range(5):
        np.testing.assert_allclose(np.asarray(vg[0, i]), seq[3 + i],
                                   atol=1e-4, err_msg=f"window pos {i}")


# ----------------------------------------------------------------------------
# engine equivalence (the acceptance criterion)
# ----------------------------------------------------------------------------
def test_greedy_spec_ngram_byte_identical():
    model, params = _model()
    base, _ = _run(model, params, None)
    out, eng = _run(model, params, SpecConfig(k=4, drafter="ngram"))
    assert out == base
    s = eng.summary()
    assert s["spec_drafted"] > 0
    assert eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages


def test_greedy_spec_draft_model_byte_identical():
    """Equivalence holds for ANY draft model — here one with different
    random weights, so most drafts are wrong and rollback is exercised
    constantly."""
    model, params = _model()
    draft_model, draft_params = _model(seed=3)
    base, _ = _run(model, params, None)
    out, eng = _run(model, params,
                    SpecConfig(k=3, drafter="model", draft_model=draft_model,
                               draft_params=draft_params,
                               draft_page_size=8))
    assert out == base
    assert eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages
    d = eng.spec.drafter
    assert d.cache.allocator.n_free == d.cache.allocator.n_pages, \
        "draft cache leaked pages"


def test_spec_repetitive_accepts_multiple_tokens_per_step():
    model, params = _model()
    prompts = [np.array([1, 2, 3] * 6, np.int32)]
    out, eng = _run(model, params, SpecConfig(k=4, drafter="ngram"),
                    prompts=prompts, new=16)
    s = eng.summary()
    assert s["tokens_per_decode_step"] > 1.0
    assert s["spec_acceptance_rate"] > 0.0


def test_spec_per_request_opt_out_and_mixed_batch():
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=8, spec=SpecConfig(k=3))
    on = ServeRequest(prompt=np.array([1, 2, 3, 1, 2, 3], np.int32),
                      max_new_tokens=8, rid=0)
    off = ServeRequest(prompt=np.array([4, 5, 6, 4, 5, 6], np.int32),
                       max_new_tokens=8, rid=1, spec=False)
    eng.run([on, off])
    assert on.done and off.done
    base = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                            page_size=8)
    b_on = ServeRequest(prompt=np.array([1, 2, 3, 1, 2, 3], np.int32),
                        max_new_tokens=8, rid=0)
    b_off = ServeRequest(prompt=np.array([4, 5, 6, 4, 5, 6], np.int32),
                         max_new_tokens=8, rid=1)
    base.run([b_on, b_off])
    assert on.out_tokens == b_on.out_tokens
    assert off.out_tokens == b_off.out_tokens


def test_spec_engine_eos_and_max_tokens_respected():
    model, params = _model()
    out, eng = _run(model, params, SpecConfig(k=4), new=5)
    assert all(len(o) == 5 for o in out)
    # eos inside an accepted window truncates the emission: pick the
    # (prompt, token) whose FIRST occurrence in the baseline stream is
    # deepest, so acceptance windows can overrun it
    base, _ = _run(model, params, None, new=12)
    j, eos, pos = max(
        ((j, t, o.index(t)) for j, o in enumerate(base) for t in set(o)),
        key=lambda x: x[2])
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=8, prefill_chunk=8, eos_id=eos,
                           spec=SpecConfig(k=4))
    reqs = [ServeRequest(prompt=PROMPTS[j].copy(), max_new_tokens=12,
                         rid=0)]
    eng.run(reqs)
    assert reqs[0].out_tokens == base[j][:pos + 1], "stop AT eos, not after"


def test_spec_stochastic_run_completes_and_rolls_back():
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=8, spec=SpecConfig(k=3))
    reqs = [ServeRequest(prompt=np.array([1, 2, 3] * 4, np.int32),
                         max_new_tokens=10, rid=i,
                         sampling=SamplingParams(temperature=0.8, top_k=20,
                                                 top_p=0.95))
            for i in range(3)]
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) == 10 for r in reqs)
    assert eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages


def test_spec_engine_preempts_and_recovers_when_pool_exhausts():
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=4, n_pages=8, prefill_chunk=8,
                           spec=SpecConfig(k=4))
    reqs = [ServeRequest(prompt=np.arange(1, 9, dtype=np.int32),
                         max_new_tokens=10, rid=i) for i in range(2)]
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) >= 10 for r in reqs)
    assert eng.cache.n_free_or_cached() == 8


def test_draft_model_drafter_cache_survives_lane_reuse():
    """More requests than lanes: the drafter must detect lane reuse via
    its prefix check/release and never serve one request's cache rows to
    another."""
    model, params = _model()
    draft_model, draft_params = _model(seed=5)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, int(n)).astype(np.int32)
               for n in [3, 11, 7, 20, 5]]
    base, _ = _run(model, params, None, prompts=prompts, new=6)
    out, eng = _run(model, params,
                    SpecConfig(k=2, drafter="model", draft_model=draft_model,
                               draft_params=draft_params,
                               draft_page_size=8),
                    prompts=prompts, new=6)
    assert out == base


# ----------------------------------------------------------------------------
# telemetry accounting
# ----------------------------------------------------------------------------
def test_tokens_per_decode_step_is_one_without_spec():
    model, params = _model()
    _, eng = _run(model, params, None)
    s = eng.summary()
    assert s["tokens_per_decode_step"] == pytest.approx(1.0)
    assert s["decode_steps"] <= s["steps"]


def test_spec_decode_counts_all_emitted_tokens():
    model, params = _model()
    out, eng = _run(model, params, SpecConfig(k=4))
    t = eng.telemetry
    assert t.decode_tokens == sum(len(o) for o in out) - len(out), \
        "every request's first token comes from prefill, the rest decode"
    assert t.decode_tokens > t.decode_lane_steps * 0, "sanity"
    s = eng.summary()
    assert s["spec_accepted"] <= s["spec_drafted"]


def test_draft_model_drafter_skips_overlong_history():
    """A history longer than the drafter's own max_seq drafts nothing
    (no KeyError from the catch-up path)."""
    model, params = _model()
    d = DraftModelDrafter(model, params, max_batch=1, max_seq=8,
                          page_size=8)
    prop = d.propose([np.arange(10, dtype=np.int32)], 2, [None])
    assert prop.n[0] == 0


def test_spec_all_optout_batch_uses_plain_decode_width():
    """A spec engine whose every lane opted out must serve correctly
    (and rides the 1-wide decode graph on those steps)."""
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=8, spec=SpecConfig(k=4))
    reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=8, rid=i,
                         spec=False) for i, p in enumerate(PROMPTS[:2])]
    eng.run(reqs)
    base = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                            page_size=8)
    breqs = [ServeRequest(prompt=p.copy(), max_new_tokens=8, rid=i)
             for i, p in enumerate(PROMPTS[:2])]
    base.run(breqs)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in breqs]
    s = eng.summary()
    assert s["spec_drafted"] == 0
    assert s["tokens_per_decode_step"] == pytest.approx(1.0)


# ----------------------------------------------------------------------------
# drafter-k autotuning (EMA of measured acceptance)
# ----------------------------------------------------------------------------
def test_autok_adapts_up_and_down():
    """Perfect acceptance drives the draft window to k_max; constant
    rejection drives it to 1; recovery pulls it back up."""
    from repro.spec.decode import SpecDecoder
    model, _ = _model()
    dec = SpecDecoder(model, SpecConfig(k=6, autok=True, autok_beta=0.5),
                      max_batch=2, max_seq=64)
    start = dec.current_k()
    assert 1 < start < 6, "autok starts mid-window"
    for _ in range(12):
        dec.observe(drafted=8, accepted=8)
    assert dec.current_k() == 6, "full acceptance earns the full window"
    for _ in range(12):
        dec.observe(drafted=8, accepted=0)
    assert dec.current_k() == 1, "rejection stops paying draft cost"
    for _ in range(12):
        dec.observe(drafted=4, accepted=4)
    assert dec.current_k() == 6, "k recovers when acceptance returns"
    # steps that drafted nothing carry no signal
    k = dec.current_k()
    dec.observe(drafted=0, accepted=0)
    assert dec.current_k() == k


def test_autok_off_pins_k_and_engine_ignores_observations():
    from repro.spec.decode import SpecDecoder
    model, _ = _model()
    dec = SpecDecoder(model, SpecConfig(k=4), max_batch=2, max_seq=64)
    for _ in range(10):
        dec.observe(drafted=8, accepted=0)
    assert dec.current_k() == 4


def test_autok_greedy_byte_identical_and_summary_reports_k():
    """autok narrows only how much is DRAFTED — the accept rule is
    untouched, so greedy output stays byte-identical; the live k lands
    in the engine summary."""
    model, params = _model()
    base, _ = _run(model, params, None)
    out, eng = _run(model, params,
                    SpecConfig(k=4, drafter="ngram", autok=True))
    assert out == base
    s = eng.summary()
    assert 1.0 <= s["spec_k_now"] <= 4.0
    assert eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages
