"""End-to-end system tests: train->checkpoint->serve pipeline, quantized
decode accuracy, and a subprocess mini dry-run exercising the full pjit
path (8 host devices, reduced configs, same code as the 512-device run)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.models import DecoderLM, ModelConfig, init_params
from repro.quant import quantize_params
from repro.serve import Request, ServeEngine
from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_train_checkpoint_serve_pipeline(tmp_path):
    """The quickstart story: train a small LM on the Markov stream until
    it beats the unigram baseline, checkpoint, restore, serve greedily,
    and check the served continuations follow the chain."""
    cfg = ModelConfig(name="e2e", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=64, global_batch=8))
    opt = AdamW(lr=cosine_schedule(3e-3, 10, 80), weight_decay=0.01)
    tr = Trainer(model, opt, data,
                 TrainConfig(steps=80, ckpt_every=40,
                             ckpt_dir=str(tmp_path / "ck"),
                             async_checkpoint=False))
    out = tr.run()
    assert out["losses"][-1] < 3.0 < out["losses"][0]

    # restore from checkpoint and serve
    from repro.train import checkpoint as ck
    like = {"params": out["params"], "opt": tuple(out["opt_state"])}
    restored, meta = ck.restore(str(tmp_path / "ck"), like)
    eng = ServeEngine(model, restored["params"], n_slots=2, max_seq=96)
    prompt = data.batch(999)["tokens"][0, :8].astype(np.int32)
    reqs = eng.run([Request(prompt=prompt, max_new_tokens=16)])
    gen = reqs[0].out_tokens
    assert len(gen) == 16
    # generated tokens must be plausible chain successors (trained model):
    # each token should be among the 8 branch targets of its predecessor
    hits = 0
    prev = int(prompt[-1])
    for t in gen:
        if t in set(data.next_tokens[prev]):
            hits += 1
        prev = t
    assert hits >= 12, f"only {hits}/16 tokens follow the learned chain"


def test_quantized_decode_close_to_fp(tmp_path):
    """INT8-quantized serve path produces near-identical greedy tokens."""
    cfg = ModelConfig(name="q", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=64, global_batch=8))
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    out = Trainer(model, opt, data, TrainConfig(steps=60)).run()
    prompt = np.array([1, 2, 3, 4], np.int32)

    def gen(params):
        eng = ServeEngine(model, params, n_slots=1, max_seq=64)
        return eng.run([Request(prompt=prompt, max_new_tokens=12)]
                       )[0].out_tokens

    fp = gen(out["params"])
    q8 = gen(quantize_params(out["params"], bits=8, group=16))
    agree = sum(a == b for a, b in zip(fp, q8))
    assert agree >= 9, (fp, q8)


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import get_smoke_config
    from repro.launch.specs import SMOKE_SHAPES, build_cell
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for arch in {archs}:
        for shape in {shapes}:
            cfg = get_smoke_config(arch)
            cell = build_cell(arch, shape, mesh, quant="{quant}", cfg=cfg,
                              shapes=SMOKE_SHAPES)
            with mesh:
                jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                                 donate_argnums=cell.donate)
                compiled = jitted.lower(*cell.args).compile()
                mem = compiled.memory_analysis()
                cost = compiled.cost_analysis()
            if isinstance(cost, list):   # older jax: one dict per device
                cost = cost[0]
            assert float(cost.get("flops", 0)) > 0
            print("OK", arch, shape)
""")


def _run_mini(archs, shapes, quant="bf16"):
    code = MINI_DRYRUN.format(archs=archs, shapes=shapes, quant=quant)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_mini_dryrun_dense_and_moe():
    out = _run_mini(["qwen2.5-3b", "deepseek-v2-lite-16b"],
                    ["train_4k", "decode_32k"])
    assert out.count("OK") == 4


@pytest.mark.slow
def test_mini_dryrun_recurrent_families():
    out = _run_mini(["xlstm-1.3b", "zamba2-7b"],
                    ["train_4k", "decode_32k"])
    assert out.count("OK") == 4


@pytest.mark.slow
def test_mini_dryrun_quantized_decode():
    out = _run_mini(["gemma3-4b"], ["decode_32k"], quant="int4")
    assert out.count("OK") == 1
