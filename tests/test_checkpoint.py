"""Checkpoint/restore: roundtrip, atomicity, async, elastic restore."""
import os
import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ck


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                       "c": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    ck.save(d, 10, tree, metadata={"data_seed": 3})
    out, meta = ck.restore(d, tree)
    assert meta["step"] == 10 and meta["data_seed"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_latest_pointer_tracks_newest(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _tree())
    ck.save(d, 2, _tree())
    assert ck.latest_step(d) == 2
    out, meta = ck.restore(d, _tree())
    assert meta["step"] == 2


def test_old_checkpoint_survives_new_save(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _tree())
    ck.save(d, 2, _tree())
    out, meta = ck.restore(d, _tree(), step=1)   # explicit older step
    assert meta["step"] == 1


def test_async_save_joins(tmp_path):
    d = str(tmp_path)
    t = ck.save(d, 5, _tree(), blocking=False)
    t.join()
    assert ck.latest_step(d) == 5


def test_restore_with_shardings_single_device(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("x",))
    d = str(tmp_path)
    tree = _tree()
    ck.save(d, 1, tree)
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
    out, _ = ck.restore(d, tree, shardings=sh)
    assert out["a"].sharding == NamedSharding(mesh, P())
