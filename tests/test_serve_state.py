"""Unified per-layer decode state: continuous batching for recurrent and
hybrid families.

Invariants pinned here:
  * `serve_step` == the lockstep `decode_step` reference, per logit,
    for xlstm, hybrid zamba, and pure-mamba (chunked prefill included);
  * mixed-length recurrent batches through `PagedServeEngine` produce
    byte-identical greedy output to serving each request alone
    (continuous admission, no equal-length grouping);
  * recurrent prefill is ONE device call per chunk, not one per prompt
    token (the old `_run_recurrent` regression);
  * StateArena save -> evict -> restore is bit-identical mid-generation
    (seeded-numpy property test; no hypothesis in this container);
  * preempted pure-recurrent lanes resume from the host snapshot with
    output identical to an unpreempted run;
  * prefix-cache / speculative-decoding capability guards raise clear
    ValueErrors on recurrent-state models (engine and launcher).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import DecoderLM, ModelConfig, init_params
from repro.models.config import SSMConfig, ZambaConfig
from repro.models.common import spec_structs
from repro.serve import PagedServeEngine, ServeRequest, StateArena


def _zeros(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  spec_structs(tree))


def _xlstm(n_layers=4):
    cfg = ModelConfig(name="x", family="xlstm", n_layers=n_layers,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab=64, head_dim=16, dtype="float32", remat=False,
                      ssm=SSMConfig(mlstm_heads=2, slstm_every=2))
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


def _zamba(shared_every=2, n_layers=4):
    """shared_every > n_layers gives the pure-mamba shape (zero shared
    attention groups -> no paged layers at all)."""
    cfg = ModelConfig(name="z", family="zamba", n_layers=n_layers,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab=64, head_dim=16, dtype="float32", remat=False,
                      ssm=SSMConfig(d_state=16, head_dim=16, expand=2),
                      zamba=ZambaConfig(shared_every=shared_every,
                                        lora_rank=4, shared_d_ff=64))
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(1),
                         dtype_override=jnp.float32)
    return model, params


FAMILIES = {
    "xlstm": _xlstm,
    "zamba": _zamba,                                 # hybrid
    "mamba2": lambda: _zamba(shared_every=8, n_layers=3),  # pure recurrent
}


# ----------------------------------------------------------------------------
# serve_step == lockstep decode_step reference
# ----------------------------------------------------------------------------
def _serve_vs_decode(model, params, toks, chunk=4, atol=1e-4):
    cache = _zeros(model.cache_specs(1, 32, jnp.float32))
    dense = []
    for t, tok in enumerate(toks):
        lg, cache = model.decode_step(params, cache,
                                      {"tokens": jnp.asarray([[tok]])},
                                      jnp.int32(t))
        dense.append(np.asarray(lg[0, 0]))

    state = _zeros(model.decode_state_specs(1, 10, 4, jnp.float32))
    served_cache = {**state["paged"], **state["arena"]}
    tables = jnp.asarray([[3, 7, 1, 5, 0, 0, 0, 0]], jnp.int32)
    lg, served_cache = model.serve_step(
        params, served_cache, {"tokens": jnp.asarray(toks[None, :chunk])},
        tables, jnp.asarray([0], jnp.int32), jnp.asarray([chunk], jnp.int32))
    served = [np.asarray(lg[0, i]) for i in range(chunk)]
    L = chunk
    for tok in toks[chunk:]:
        lg, served_cache = model.serve_step(
            params, served_cache, {"tokens": jnp.asarray([[tok]])}, tables,
            jnp.asarray([L], jnp.int32), jnp.asarray([1], jnp.int32))
        served.append(np.asarray(lg[0, 0]))
        L += 1
    for i, (d, p) in enumerate(zip(dense, served)):
        np.testing.assert_allclose(p, d, atol=atol,
                                   err_msg=f"position {i}")


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_serve_step_matches_decode_step(family):
    model, params = FAMILIES[family]()
    toks = np.array([5, 9, 3, 17, 2, 41, 8], np.int32)
    _serve_vs_decode(model, params, toks)


# ----------------------------------------------------------------------------
# continuous batching: mixed lengths == single-request, token for token
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_mixed_length_batch_matches_single_request(family):
    model, params = FAMILIES[family]()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, int(n)).astype(np.int32)
               for n in [3, 11, 7, 20, 5]]          # > lanes, all unequal

    def engine():
        return PagedServeEngine(model, params, max_batch=2, max_seq=64,
                                page_size=8, prefill_chunk=4)

    eng = engine()
    batch = [ServeRequest(prompt=p, max_new_tokens=6, rid=i)
             for i, p in enumerate(prompts)]
    eng.run(batch)
    assert all(r.done and len(r.out_tokens) == 6 for r in batch)

    for req, prompt in zip(batch, prompts):
        solo = ServeRequest(prompt=prompt, max_new_tokens=6, rid=0)
        engine().run([solo])
        assert req.out_tokens == solo.out_tokens, (
            f"lane output diverged from solo run for prompt len "
            f"{len(prompt)}")

    m = eng.summary()
    assert m["state_slot_occupancy_peak"] == 1.0
    assert m[f"lane_steps_{model.cfg.family}"] > 0
    assert m["state_bytes"] > 0


# ----------------------------------------------------------------------------
# recurrent prefill is one device call per CHUNK, not per token
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["xlstm", "mamba2"])
def test_recurrent_prefill_one_call_per_chunk(family):
    model, params = FAMILIES[family]()
    chunk = 8
    eng = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                           page_size=8, prefill_chunk=chunk)
    shapes = []
    orig = eng._step_fn

    def counting(params_, cache_, inputs_, *rest):
        shapes.append(inputs_["tokens"].shape)
        return orig(params_, cache_, inputs_, *rest)

    eng._step_fn = counting
    prompt_len = 21
    req = ServeRequest(prompt=np.arange(prompt_len, dtype=np.int32) % 64,
                       max_new_tokens=3, rid=0)
    eng.run([req])
    prefill_calls = [s for s in shapes if s[1] == chunk]
    n_chunks = -(-prompt_len // chunk)
    assert len(prefill_calls) == n_chunks, (
        f"{len(prefill_calls)} prefill calls for a {prompt_len}-token "
        f"prompt at chunk {chunk}; want {n_chunks} (one per chunk, "
        f"not one per token)")
    assert {s[1] for s in shapes} <= {chunk, 1}, shapes


# ----------------------------------------------------------------------------
# StateArena lane ops: save -> evict -> restore is bit-identical
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_state_arena_save_evict_restore_bit_identical(family):
    """Seeded-numpy property test (no hypothesis in this container):
    random lane traffic, then for each lane save -> clobber/reset ->
    restore and require every leaf row back bit-for-bit."""
    model, _ = FAMILIES[family]()
    if not model.has_recurrent_state():
        pytest.skip("attention-only")
    rng = np.random.default_rng(42)
    arena = StateArena(model, max_batch=3)
    # fill the arena with random state (as if mid-generation)
    arena.state = jax.tree_util.tree_map(
        lambda leaf: jnp.asarray(
            rng.standard_normal(leaf.shape).astype(leaf.dtype)),
        arena.state)
    for trial in range(10):
        lane = int(rng.integers(0, 3))
        other = (lane + 1) % 3
        snap = arena.save_lane(lane)
        other_before = arena.save_lane(other)
        # evict: zero the lane, then scribble random state into it (a
        # new request occupying the slot)
        arena.reset_lane(lane)
        scribble = jax.tree_util.tree_map(
            lambda leaf: rng.standard_normal(leaf.shape).astype(
                leaf.dtype), snap)
        arena.restore_lane(lane, scribble)
        # re-admit the preempted request: snapshot back, bit for bit
        arena.restore_lane(lane, snap)
        for a, b in zip(jax.tree_util.tree_leaves(snap),
                        jax.tree_util.tree_leaves(arena.save_lane(lane))):
            np.testing.assert_array_equal(a, b)
        # lane ops never touch another lane's rows
        for a, b in zip(jax.tree_util.tree_leaves(other_before),
                        jax.tree_util.tree_leaves(arena.save_lane(other))):
            np.testing.assert_array_equal(a, b)


def test_state_arena_reset_zeroes_only_that_lane():
    model, _ = _xlstm()
    rng = np.random.default_rng(3)
    arena = StateArena(model, max_batch=2)
    arena.state = jax.tree_util.tree_map(
        lambda leaf: jnp.asarray(
            rng.standard_normal(leaf.shape).astype(leaf.dtype)),
        arena.state)
    keep = arena.save_lane(1)
    arena.reset_lane(0)
    for leaf in jax.tree_util.tree_leaves(arena.save_lane(0)):
        assert not np.any(leaf), "reset lane must be zero"
    for a, b in zip(jax.tree_util.tree_leaves(keep),
                    jax.tree_util.tree_leaves(arena.save_lane(1))):
        np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------------
# preemption: pure-recurrent lanes resume from the host snapshot
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["xlstm", "mamba2"])
def test_preempted_recurrent_lane_resumes_identically(family):
    model, params = FAMILIES[family]()
    prompt = np.arange(1, 9, dtype=np.int32)

    def run(n_pages):
        eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                               page_size=4, n_pages=n_pages,
                               prefill_chunk=8)
        reqs = [ServeRequest(prompt=prompt.copy(), max_new_tokens=10,
                             rid=i) for i in range(2)]
        eng.run(reqs)
        return reqs, eng

    tight, eng = run(n_pages=8)        # both generations cannot coexist
    assert all(r.done and len(r.out_tokens) >= 10 for r in tight)
    assert eng.cache.n_free_or_cached() == 8, "pages leaked after drain"
    roomy, _ = run(n_pages=None)       # worst-case pool: no preemption
    for a, b in zip(tight, roomy):
        assert a.out_tokens == b.out_tokens, (
            "resume-from-snapshot diverged from the unpreempted run")


# ----------------------------------------------------------------------------
# capability guards
# ----------------------------------------------------------------------------
@pytest.mark.parametrize("family", ["xlstm", "zamba", "mamba2"])
def test_spec_on_recurrent_model_raises_named_capability(family):
    from repro.spec import SpecConfig
    model, params = FAMILIES[family]()
    with pytest.raises(ValueError, match="speculative-decoding"):
        PagedServeEngine(model, params, max_batch=1, max_seq=32,
                         page_size=8, spec=SpecConfig(k=2))


@pytest.mark.parametrize("family", ["xlstm", "zamba", "mamba2"])
def test_prefix_cache_on_recurrent_model_raises_named_capability(family):
    model, params = FAMILIES[family]()
    with pytest.raises(ValueError, match="prefix-cache"):
        PagedServeEngine(model, params, max_batch=1, max_seq=32,
                         page_size=8, prefix_cache=True)
    # default (auto) quietly disables instead of raising
    eng = PagedServeEngine(model, params, max_batch=1, max_seq=32,
                           page_size=8)
    assert eng.prefix is None


def test_launch_capability_check():
    from repro.launch.serve import check_capabilities
    xl_model, _ = _xlstm(n_layers=2)
    with pytest.raises(ValueError, match="speculative-decoding"):
        check_capabilities(xl_model, "ngram", no_prefix_cache=False)
    # hybrid/recurrent families auto-imply --no-prefix-cache
    assert check_capabilities(xl_model, "off", no_prefix_cache=False) \
        is False
    za_model, _ = _zamba()
    assert check_capabilities(za_model, "off", no_prefix_cache=False) \
        is False
    dense = DecoderLM(ModelConfig(name="d", family="dense", n_layers=1,
                                  d_model=32, n_heads=2, n_kv_heads=2,
                                  d_ff=64, vocab=64, head_dim=16,
                                  dtype="float32", remat=False))
    assert check_capabilities(dense, "off", no_prefix_cache=False) is True
    assert check_capabilities(dense, "off", no_prefix_cache=True) is False


# ----------------------------------------------------------------------------
# telemetry
# ----------------------------------------------------------------------------
def test_state_slot_occupancy_absent_for_attention_only_models():
    cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    eng = PagedServeEngine(model, params, max_batch=1, max_seq=32,
                           page_size=8)
    eng.run([ServeRequest(prompt=np.array([1, 2, 3], np.int32),
                          max_new_tokens=3, rid=0)])
    m = eng.summary()
    assert np.isnan(m["state_slot_occupancy_peak"])
    assert m["lane_steps_dense"] > 0
    assert "state_bytes" not in m
