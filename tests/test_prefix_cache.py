"""Prefix sharing / copy-on-write: allocator refcounts, the radix-trie
prefix index, fork + COW correctness, and engine-level prefix reuse.

Hypothesis is not in the container's package set, so the COW invariants
are driven with seeded random op sequences (same style as
test_paged_cache.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import DecoderLM, ModelConfig, init_params
from repro.serve import (BlockAllocator, PagedKVCache, PagedServeEngine,
                         PrefixIndex, ServeRequest)
from repro.serve.prefix import PREFIX_OWNER


def _cache(n_pages=16, page_size=4, max_seq=32):
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    return PagedKVCache(DecoderLM(cfg), n_pages, page_size, max_seq,
                        kv_dtype=jnp.float32)


def _model(seed=0):
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         dtype_override=jnp.float32)
    return model, params


# ----------------------------------------------------------------------------
# allocator refcounts
# ----------------------------------------------------------------------------
def test_share_increfs_and_free_decrefs():
    a = BlockAllocator(8)
    pages = a.alloc(owner=1, n=3)
    a.share(owner=2, pages=pages[:2])
    assert [a.refcount(p) for p in pages] == [2, 2, 1]
    assert a.n_free == 5, "sharing allocates nothing"
    assert a.free(1) == [pages[2]], "only the unshared page is collected"
    assert [a.refcount(p) for p in pages[:2]] == [1, 1]
    assert sorted(a.free(2)) == sorted(pages[:2])
    assert a.n_free == 8


def test_share_of_free_page_is_an_error():
    a = BlockAllocator(4)
    with pytest.raises(ValueError):
        a.share(owner=1, pages=[0])


def test_free_pages_decref_collects_only_last_owner():
    a = BlockAllocator(4)
    (p,) = a.alloc(owner=1, n=1)
    a.share(owner=2, pages=[p])
    assert a.free_pages(1, [p]) == [], "other owner still holds it"
    assert a.refcount(p) == 1 and a.n_free == 3
    assert a.free_pages(2, [p]) == [p]
    assert a.refcount(p) == 0 and a.n_free == 4


def test_shared_random_ops_refcounts_never_negative_pages_conserved():
    """alloc/share/free/free_pages interleavings against a shadow
    ledger: refcount == number of holder entries, never negative, and
    n_free + unique allocated == n_pages throughout."""
    rng = np.random.default_rng(11)
    for trial in range(10):
        n_pages = int(rng.integers(4, 32))
        a = BlockAllocator(n_pages)
        held = {}                       # owner -> list of pages
        for _ in range(300):
            op = rng.random()
            if op < 0.4 and a.n_free > 0:
                owner = int(rng.integers(0, 6))
                n = int(rng.integers(1, a.n_free + 1))
                held.setdefault(owner, []).extend(a.alloc(owner, n))
            elif op < 0.6 and held:
                src = int(rng.choice(list(held)))
                owner = int(rng.integers(0, 6))
                take = [p for p in held[src]
                        if p not in held.get(owner, [])]
                if take:
                    share = list(rng.choice(
                        take, size=int(rng.integers(1, len(take) + 1)),
                        replace=False))
                    a.share(owner, share)
                    held.setdefault(owner, []).extend(share)
            elif op < 0.8 and held:
                owner = int(rng.choice(list(held)))
                got = a.free(owner)
                mine = held.pop(owner)
                others = {p for ps in held.values() for p in ps}
                assert sorted(got) == sorted(
                    [p for p in set(mine) if p not in others])
            elif held:
                owner = int(rng.choice(list(held)))
                k = int(rng.integers(1, len(held[owner]) + 1))
                drop = list(rng.choice(held[owner], size=k, replace=False))
                # choice on a list with duplicates can repeat a page;
                # free exactly the multiset we remove from the ledger
                for p in drop:
                    held[owner].remove(p)
                a.free_pages(owner, drop)
                if not held[owner]:
                    held.pop(owner)
            allocated = {p for ps in held.values() for p in ps}
            assert a.n_free + len(allocated) == n_pages, "pages leaked"
            for p in allocated:
                want = sum(ps.count(p) for ps in held.values())
                assert a.refcount(p) == want, "refcount drifted"
                assert a.refcount(p) > 0


# ----------------------------------------------------------------------------
# prefix index (radix trie)
# ----------------------------------------------------------------------------
def test_prefix_index_match_insert_and_cap():
    a = BlockAllocator(16)
    idx = PrefixIndex(a, page_size=4)
    prompt = np.arange(12, dtype=np.int32)
    pages = a.alloc(owner=0, n=3)
    assert idx.insert(prompt, pages) == 3
    assert [a.refcount(p) for p in pages] == [2, 2, 2]

    # full match, capped below the prompt tail
    got_tokens, got_pages = idx.match(prompt)
    assert got_tokens == 8 and got_pages == pages[:2], \
        "match never covers the last token (prefill must emit logits)"
    long = np.concatenate([prompt, np.arange(12, 20, dtype=np.int32)])
    assert idx.match(long) == (12, pages)

    # divergence mid-way matches only the shared prefix
    fork = prompt.copy()
    fork[5] = 63
    t, p = idx.match(np.concatenate([fork, [1]]))
    assert t == 4 and p == pages[:1]
    # a prefix shorter than one full page never matches
    assert idx.match(np.arange(4, dtype=np.int32)) == (0, [])


def test_prefix_index_same_tokens_different_parent_do_not_collide():
    """KV depends on the whole causal prefix: page tokens [4..7] under
    two different first pages must resolve to different pages."""
    a = BlockAllocator(16)
    idx = PrefixIndex(a, page_size=4)
    tail = np.arange(4, 8, dtype=np.int32)
    p_a = a.alloc(owner=0, n=2)
    p_b = a.alloc(owner=1, n=2)
    idx.insert(np.concatenate([np.zeros(4, np.int32), tail]), p_a)
    idx.insert(np.concatenate([np.ones(4, np.int32), tail]), p_b)
    q = np.concatenate([np.ones(4, np.int32), tail, [9]])
    assert idx.match(q) == (8, p_b)


def test_prefix_index_insert_existing_node_keeps_original_page():
    a = BlockAllocator(8)
    idx = PrefixIndex(a, page_size=4)
    prompt = np.arange(8, dtype=np.int32)
    first = a.alloc(owner=0, n=2)
    dup = a.alloc(owner=1, n=2)
    assert idx.insert(prompt, first) == 2
    assert idx.insert(prompt, dup) == 0, "duplicate content not adopted"
    assert idx.match(np.concatenate([prompt, [1]]))[1] == first
    assert [a.refcount(p) for p in dup] == [1, 1], "dup stays seq-owned"


def test_prefix_index_lru_eviction_skips_shared_pages():
    a = BlockAllocator(16)
    idx = PrefixIndex(a, page_size=4)
    p1 = a.alloc(owner=0, n=1)
    p2 = a.alloc(owner=1, n=1)
    idx.insert(np.arange(4, dtype=np.int32), p1)
    idx.insert(np.arange(10, 14, dtype=np.int32), p2)
    a.free(0)
    a.free(1)                   # both pages now trie-only (refcount 1)
    assert idx.n_pages == 2 and idx.n_evictable() == 2

    # touch p1: p2 becomes LRU and is evicted first
    idx.match(np.arange(5, dtype=np.int32))
    assert idx.evict(1) == 1
    assert idx.match(np.arange(10, 15, dtype=np.int32)) == (0, [])
    assert idx.match(np.arange(5, dtype=np.int32)) == (4, p1)

    # a page a live sequence shares is never pulled out from under it
    a.share(owner=7, pages=p1)
    assert idx.evict(1) == 0 and idx.n_pages == 1
    a.free(7)
    assert idx.evict(1) == 1 and idx.n_pages == 0
    assert a.n_free == 16


def test_prefix_index_evicts_leaf_first():
    a = BlockAllocator(8)
    idx = PrefixIndex(a, page_size=2)
    pages = a.alloc(owner=0, n=3)
    idx.insert(np.arange(6, dtype=np.int32), pages)
    a.free(0)
    assert idx.evict(1) == 1
    # the deepest page went first; the 4-token prefix still matches
    assert idx.match(np.arange(7, dtype=np.int32)) == (4, pages[:2])


# ----------------------------------------------------------------------------
# fork + copy-on-write
# ----------------------------------------------------------------------------
def _stamp_pages(c, pages, base):
    """Give each page's pool rows a distinct constant so copies are
    checkable."""
    for j, p in enumerate(pages):
        c.pools = jax.tree_util.tree_map(
            lambda leaf, val=float(base + j), pg=p:
                leaf.at[:, pg].set(val), c.pools)


def _page_val(c, page):
    leaf = jax.tree_util.tree_leaves(c.pools)[0]
    return float(np.asarray(leaf[0, page]).ravel()[0])


def test_fork_shares_pages_and_cow_on_unaligned_write():
    c = _cache(n_pages=8, page_size=4)
    a = c.admit(rid=0, prompt_len=6)            # 2 pages, tail half-full
    a.length = 6
    _stamp_pages(c, a.pages, base=10)

    b = c.fork(new_rid=1, src_rid=0, prefix_len=6)
    assert b.pages == a.pages and b.length == 6
    assert [c.allocator.refcount(p) for p in a.pages] == [2, 2]
    assert c.pages_shared == 2

    # B's next write lands inside shared page 1 -> copy-on-write
    assert c.prepare_write(1, 1)
    assert c.cow_copies == 1
    assert b.pages[0] == a.pages[0], "full prefix page stays shared"
    assert b.pages[1] != a.pages[1], "tail page was copied"
    assert _page_val(c, b.pages[1]) == _page_val(c, a.pages[1]), \
        "copy carries the original rows"
    assert [c.allocator.refcount(p) for p in a.pages] == [2, 1]
    assert c.allocator.refcount(b.pages[1]) == 1

    # A keeps writing its own tail page without further copies
    assert c.prepare_write(0, 1) and c.cow_copies == 1
    c.release(0)
    c.release(1)
    assert c.allocator.n_free == 8


def test_fork_aligned_prefix_never_copies():
    c = _cache(n_pages=8, page_size=4)
    a = c.admit(rid=0, prompt_len=8)
    a.length = 8
    b = c.fork(new_rid=1, src_rid=0, prefix_len=8)
    assert c.prepare_write(1, 3)                # writes start a new page
    assert c.cow_copies == 0
    assert b.pages[:2] == a.pages[:2] and len(b.pages) == 3


def test_trim_decrefs_shared_pages_instead_of_freeing():
    """Spec-decode rollback on a forked sequence must never free a page
    the source still reads."""
    c = _cache(n_pages=8, page_size=4)
    a = c.admit(rid=0, prompt_len=8)
    a.length = 8
    b = c.fork(new_rid=1, src_rid=0, prefix_len=8)
    assert c.ensure_room(1, 5)                  # b grows its own page 2
    b.length = 13
    c.trim(1, 3)                                # roll back INTO the share
    assert b.pages == [a.pages[0]]
    assert [c.allocator.refcount(p) for p in a.pages[:2]] == [2, 1], \
        "trim decrefs the shared page; source still holds it"
    assert a.length == 8, "source untouched"
    c.release(1)
    assert [c.allocator.refcount(p) for p in a.pages] == [1, 1]
    c.release(0)
    assert c.allocator.n_free == 8


def test_cow_fork_trim_evict_interleavings_conserve_pages():
    """Randomized fork/append/trim/evict/insert/release sequences on the
    real cache + trie: refcounts match the holder ledger, total pages
    are conserved, capacity covers length, block tables stay valid."""
    rng = np.random.default_rng(3)
    for trial in range(6):
        page_size = int(rng.choice([2, 4]))
        n_pages = int(rng.integers(8, 20))
        c = _cache(n_pages=n_pages, page_size=page_size, max_seq=32)
        idx = PrefixIndex(c.allocator, page_size)
        c.prefix_index = idx
        live, prompts, next_rid = {}, {}, 0
        for _ in range(150):
            op = rng.random()
            if op < 0.25 or not live:
                plen = int(rng.integers(1, 3 * page_size))
                if c.can_admit(plen):
                    prompt = rng.integers(0, 64, plen).astype(np.int32)
                    try:
                        seq = c.admit(next_rid, plen, prompt=prompt)
                    except Exception:
                        continue
                    seq.length = plen
                    live[next_rid] = seq
                    prompts[next_rid] = prompt
                    next_rid += 1
            elif op < 0.4 and live:
                src = int(rng.choice(list(live)))
                cut = int(rng.integers(0, live[src].length + 1))
                if c.allocator.can_alloc(1):   # room for a later COW
                    seq = c.fork(next_rid, src, cut)
                    live[next_rid] = seq
                    prompts[next_rid] = prompts[src][:cut]
                    next_rid += 1
            elif op < 0.65 and live:
                rid = int(rng.choice(list(live)))
                seq = live[rid]
                window = int(rng.integers(1, 5))
                if c.prepare_write(rid, window):
                    seq.length += window
                    accepted = int(rng.integers(0, window + 1))
                    c.trim(rid, seq.length - (window - accepted))
            elif op < 0.8 and live:
                rid = int(rng.choice(list(live)))
                seq = live[rid]
                n_full = min(len(prompts[rid]) // page_size,
                             len(seq.pages))
                if n_full:
                    idx.insert(prompts[rid][:n_full * page_size],
                               seq.pages[:n_full])
                c.release(rid)
                live.pop(rid)
                prompts.pop(rid)
            else:
                idx.evict(int(rng.integers(1, 4)))

            # invariants ------------------------------------------------
            holders = {}
            for rid, seq in live.items():
                for p in seq.pages:
                    holders[p] = holders.get(p, 0) + 1
            for node in idx._walk():
                holders[node.page] = holders.get(node.page, 0) + 1
            assert c.allocator.n_free + len(holders) == n_pages, "leak"
            for p, want in holders.items():
                assert c.allocator.refcount(p) == want
                assert c.allocator.refcount(p) > 0
            for rid, seq in live.items():
                assert seq.capacity(page_size) >= seq.length
                tab = c.table_for(rid)
                assert list(tab[:len(seq.pages)]) == seq.pages
        for rid in list(live):
            c.release(rid)
        idx.evict(n_pages)
        assert c.allocator.n_free == n_pages, "drain leaves pages behind"


# ----------------------------------------------------------------------------
# byte-identical decode through shared and copied pages
# ----------------------------------------------------------------------------
def test_forked_sequence_decode_is_byte_identical_to_unshared():
    """A fork reading shared pages (and writing through COW) must
    produce bit-for-bit the logits of an unshared sequence fed the
    same tokens."""
    model, params = _model()
    toks = np.array([5, 9, 3, 17, 2, 41], np.int32)   # 6 tokens, ps 4

    def prefill(c, rid, tokens):
        seq = c.admit(rid, len(tokens), prompt=None)
        tab = jnp.asarray(c.table_for(rid)[None, :])
        lg, c.pools = model.paged_step(
            params, c.pools, {"tokens": jnp.asarray(tokens[None, :])},
            tab, jnp.asarray([seq.length], jnp.int32),
            jnp.asarray([len(tokens)], jnp.int32))
        seq.length += len(tokens)
        return lg

    def decode(c, rid, tok):
        assert c.prepare_write(rid, 1)
        seq = c.seqs[rid]
        tab = jnp.asarray(c.table_for(rid)[None, :])
        lg, c.pools = model.paged_step(
            params, c.pools, {"tokens": jnp.asarray([[tok]])}, tab,
            jnp.asarray([seq.length], jnp.int32),
            jnp.asarray([1], jnp.int32))
        seq.length += 1
        return np.asarray(lg[0, 0])

    c = _cache(n_pages=12, page_size=4)
    prefill(c, 0, toks)
    c.fork(new_rid=1, src_rid=0, prefix_len=6)   # unaligned: COW on write
    forked = [decode(c, 1, 7), decode(c, 1, 22)]
    assert c.cow_copies == 1

    c2 = _cache(n_pages=12, page_size=4)
    prefill(c2, 0, toks)
    plain = [decode(c2, 0, 7), decode(c2, 0, 22)]

    for f, p in zip(forked, plain):
        np.testing.assert_array_equal(f, p)
    # the source is unperturbed by the fork's writes
    src_after = decode(c, 0, 7)
    np.testing.assert_array_equal(src_after, plain[0])


# ----------------------------------------------------------------------------
# engine end-to-end
# ----------------------------------------------------------------------------
def test_engine_prefix_reuse_skips_prefill_and_outputs_match():
    model, params = _model()
    prompt = np.arange(1, 17, dtype=np.int32)    # 16 tokens, ps 4

    def run(prefix_cache):
        eng = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                               page_size=4, prefill_chunk=4,
                               prefix_cache=prefix_cache)
        reqs = [ServeRequest(prompt=prompt.copy(), max_new_tokens=6,
                             rid=i) for i in range(2)]
        eng.run(reqs)
        return reqs, eng.summary()

    base, mb = run(prefix_cache=False)
    shared, ms = run(prefix_cache=True)
    for b, s in zip(base, shared):
        assert b.out_tokens == s.out_tokens, \
            "prefix adoption must not change greedy output"
    # request 2 matched 3 full pages (12 of 16 tokens; the last token is
    # always recomputed, capping the match at 12)
    assert ms["prefill_tokens_skipped"] == 12
    assert ms["prefix_hit_rate"] == pytest.approx(0.5)
    assert ms["prefill_tokens"] == mb["prefill_tokens"] - 12
    assert mb["prefill_tokens_skipped"] == 0


def test_engine_prefix_eviction_under_pressure_keeps_serving():
    """Distinct prompts cycling through a small pool force trie
    eviction on the admission path; everything completes and no page is
    lost."""
    model, params = _model()
    rng = np.random.default_rng(0)
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=32,
                           page_size=4, n_pages=10, prefill_chunk=8)
    reqs = [ServeRequest(prompt=rng.integers(0, 64, 8).astype(np.int32),
                         max_new_tokens=4, rid=i) for i in range(6)]
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
    assert eng.prefix.pages_evicted > 0, "pressure must evict"
    assert eng.cache.n_free_or_cached() == 10


def test_engine_spec_decode_with_prefix_sharing_byte_identical():
    """Spec-decode rollback over adopted prefix pages: trim must decref
    shared pages, never free them, and greedy output stays identical to
    the plain engine."""
    from repro.spec import SpecConfig
    model, params = _model()
    prompt = np.array([1, 2, 3, 4] * 4, np.int32)   # draftable, 16 toks

    def run(spec, prefix_cache):
        eng = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                               page_size=4, prefill_chunk=8,
                               spec=spec, prefix_cache=prefix_cache)
        reqs = [ServeRequest(prompt=prompt.copy(), max_new_tokens=8,
                             rid=i) for i in range(2)]
        eng.run(reqs)
        return [r.out_tokens for r in reqs], eng

    base, _ = run(None, prefix_cache=False)
    out, eng = run(SpecConfig(k=3, drafter="ngram"), prefix_cache=True)
    assert out == base
    m = eng.summary()
    assert m["prefill_tokens_skipped"] > 0
    assert m["spec_drafted"] > 0
    assert eng.cache.n_free_or_cached() == eng.cache.allocator.n_pages


def test_generated_suffix_cached_for_follow_up_turns():
    """A follow-up turn that extends a prior completion (chat history
    grows turn by turn) must adopt the GENERATED pages too, not just
    the original prompt's — and stay byte-identical to a cold engine."""
    model, params = _model()
    prompt = np.arange(1, 13, dtype=np.int32)       # 12 tokens, ps 4

    eng = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                           page_size=4, prefill_chunk=4)
    first = ServeRequest(prompt=prompt.copy(), max_new_tokens=9, rid=0)
    eng.run([first])
    # follow-up: the full first turn (prompt + completion) plus new text
    history = np.concatenate([prompt,
                              np.asarray(first.out_tokens, np.int32)])
    follow_prompt = np.concatenate(
        [history, np.array([50, 51, 52], np.int32)])
    follow = ServeRequest(prompt=follow_prompt.copy(), max_new_tokens=4,
                          rid=1)
    eng.run([follow])
    m = eng.summary()
    # prompt-only caching would cap the match at the 12 prompt tokens'
    # 3 full pages; suffix caching extends it across generated pages
    # (the final emitted token was never materialized, so the cached
    # history is 12 + 9 - 1 = 20 tokens = 5 full pages)
    assert m["prefill_tokens_skipped"] >= 20

    cold = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                            page_size=4, prefill_chunk=4,
                            prefix_cache=False)
    ref = ServeRequest(prompt=follow_prompt.copy(), max_new_tokens=4,
                       rid=0)
    cold.run([ref])
    assert follow.out_tokens == ref.out_tokens, \
        "suffix adoption changed greedy output"


def test_generated_suffix_not_committed_when_prefix_cache_off():
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                           page_size=4, prefix_cache=False)
    req = ServeRequest(prompt=np.arange(8, dtype=np.int32),
                       max_new_tokens=6, rid=0)
    eng.run([req])
    assert eng.prefix is None
    assert eng.cache.allocator.n_free == eng.cache.allocator.n_pages


def test_preempted_request_commits_only_true_history_keys():
    """Regression: a preempted-then-resumed request folds generated
    tokens into its prompt; the suffix-cache commit (and a second
    preemption's rebuild) must append out_tokens past the fold cursor,
    never the whole list — otherwise the trie gains keys with
    duplicated token runs whose pages hold different KV (silent wrong
    adoption for any prompt matching the poisoned key)."""
    from repro.serve import SamplingParams
    model, params = _model()
    prompt = np.arange(1, 9, dtype=np.int32)        # 8 tokens, ps 4
    # fits both prompts but not both generations -> preemption; sampled
    # (non-repetitive) outputs make a duplicated run observable — the
    # tiny model's greedy stream is a constant token, which would mask
    # the poisoning this test exists to catch
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=4, n_pages=8, prefill_chunk=8,
                           seed=3)
    reqs = [ServeRequest(prompt=prompt.copy(), max_new_tokens=20, rid=i,
                         sampling=SamplingParams(temperature=2.0))
            for i in range(2)]
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) >= 20 for r in reqs)
    assert any(r.prompt_folded > 0 for r in reqs), \
        "scenario must actually preempt someone"
    histories = [list(prompt) + r.out_tokens for r in reqs]

    # no rebuilt prompt carries a duplicated run...
    for r in reqs:
        assert list(r.prompt) == \
            list(prompt) + r.out_tokens[:r.prompt_folded]
    # ...and every trie path spells a prefix of a TRUE served history
    def paths(node, acc):
        for child in node.children.values():
            key = acc + list(child.key)
            yield key
            yield from paths(child, key)

    for key in paths(eng.prefix.root, []):
        assert any(key == h[:len(key)] for h in histories), \
            f"trie key {key} is not a prefix of any served history"
