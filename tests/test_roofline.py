"""HLO collective parser + roofline terms."""
from repro.roofline.analysis import (Roofline, parse_collectives,
                                     PEAK_FLOPS, HBM_BW, ICI_BW)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,2048]{1,0} parameter(0)
  %ag = bf16[256,2048]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[128,128]{1,0} all-reduce(%x), to_apply=%sum
  %rs = bf16[8,2048]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%z)
  %agd = bf16[9]{0} all-gather-done(%h)
  %tup = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-reduce-start(%a, %b)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO)
    assert st.count_by_kind["all-gather"] == 1
    assert st.bytes_by_kind["all-gather"] == 256 * 2048 * 2
    assert st.bytes_by_kind["reduce-scatter"] == 8 * 2048 * 2
    assert st.bytes_by_kind["collective-permute"] == 4 * 4 * 2
    # tuple-shaped async start counted once, both operands
    assert st.count_by_kind["all-reduce"] == 2
    assert st.bytes_by_kind["all-reduce"] == 128 * 128 * 4 + 2 * 16 * 16 * 4


def test_roofline_dominant_term():
    r = Roofline(arch="a", shape_id="s", kind="train", mesh="single",
                 quant="bf16", flops=PEAK_FLOPS, hlo_bytes=HBM_BW * 2,
                 collective_bytes=ICI_BW * 0.5, model_flops=PEAK_FLOPS / 2)
    assert r.t_compute == 1.0 and r.t_memory == 2.0 and r.t_collective == 0.5
    assert r.dominant == "memory"
    assert abs(r.roofline_fraction - 0.25) < 1e-9
    assert abs(r.useful_flops_ratio - 0.5) < 1e-9
