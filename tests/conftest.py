import os
import sys

# repo-local imports without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Release compiled executables between modules: the CPU backend keeps
    every jitted dylib alive, and a full-suite session otherwise exhausts
    the JIT linker late in the run (Fatal 'Failed to materialize
    symbols')."""
    yield
    jax.clear_caches()
