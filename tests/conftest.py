import os
import sys

# repo-local imports without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# force a 2-device host mesh BEFORE jax initializes so tensor-parallel
# tests (test_tp_serving) can build a real ("model",) mesh on the CPU
# backend; single-device tests are unaffected — default computations
# still land on device 0.  Respect an explicit caller override.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_force_host_platform_device_count=2").strip()

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    """Release compiled executables between modules: the CPU backend keeps
    every jitted dylib alive, and a full-suite session otherwise exhausts
    the JIT linker late in the run (Fatal 'Failed to materialize
    symbols')."""
    yield
    jax.clear_caches()
