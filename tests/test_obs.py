"""Observability layer (repro.obs): span tracer ring semantics and
thread safety, Chrome-trace and Prometheus exporters (including a real
2-replica gateway capture with request ids correlated across
gateway/router/engine spans), flight-recorder postmortems on driver
death, the CIM-cost-model energy meter, and the structured access log.
"""
import json
import re
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Gateway, iter_sse
from repro.api.driver import EngineDriver
from repro.fleet import FleetRouter
from repro.fleet.router import aggregate_summaries
from repro.models import DecoderLM, ModelConfig, init_params
from repro.obs import (EnergyMeter, FlightRecorder, chrome_trace,
                       get_tracer, prometheus_text,
                       slm_spec_from_model_config)
from repro.obs.trace import NULL_SPAN, Tracer
from repro.serve import PagedServeEngine, ServeRequest


def _cfg():
    return ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                       head_dim=16, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def model_params():
    cfg = _cfg()
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                        dtype_override=jnp.float32)
    return model, params


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return PagedServeEngine(model, params, **kw)


@pytest.fixture
def tracing():
    """Enable the process tracer for one test, then restore the quiet
    default so unrelated tests stay un-instrumented."""
    tr = get_tracer()
    tr.clear()
    tr.enable()
    yield tr
    tr.disable()
    tr.clear()


# ----------------------------------------------------------------------------
# tracer: ring semantics
# ----------------------------------------------------------------------------
def test_disabled_tracer_records_nothing():
    tr = Tracer()
    assert not tr.enabled
    assert tr.span("x") is NULL_SPAN         # shared no-op singleton
    with tr.span("x", cat="engine", k=1):
        pass
    tr.instant("y", rid=3)
    tr.complete("z", 0.0, 1.0)
    assert tr.events() == []
    assert tr.dropped() == 0


def test_ring_wraparound_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=8).enable()
    for i in range(20):
        tr.instant("e", i=i)
    evs = tr.events()
    assert len(evs) == 8
    assert [e["args"]["i"] for e in evs] == list(range(12, 20))
    assert tr.dropped() == 12
    tr.clear()
    assert tr.events() == [] and tr.dropped() == 0


def test_span_and_complete_record_durations():
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    tr = Tracer(clock=clock).enable()
    with tr.span("work", cat="driver", job=7):
        pass
    tr.complete("measured", t0=10.0, dur_s=0.25, cat="engine", rids=[1])
    spans = {e["name"]: e for e in tr.events()}
    assert spans["work"]["ph"] == "X"
    assert spans["work"]["dur_s"] == pytest.approx(0.5)
    assert spans["work"]["args"] == {"job": 7}
    assert spans["measured"]["t_s"] == 10.0
    assert spans["measured"]["dur_s"] == 0.25
    assert spans["measured"]["args"]["rids"] == [1]


def test_per_thread_rings_and_unique_request_ids():
    tr = Tracer(capacity=256).enable()
    ids, errs = [], []

    def worker(k):
        try:
            for i in range(100):
                tr.instant("e", w=k)
                ids.append(tr.next_request_id())
        except Exception as e:      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    rings = tr.rings()
    assert len(rings) == 4              # one ring per worker thread
    # each worker wrote its own ring, never a shared one (the OS may
    # reuse thread idents, so count per-ring events, not distinct tids)
    assert [len(r.events) for r in rings] == [100] * 4
    assert len(tr.events()) == 400 and tr.dropped() == 0
    assert len(set(ids)) == 400         # process-unique correlation ids


# ----------------------------------------------------------------------------
# Chrome trace export
# ----------------------------------------------------------------------------
def test_chrome_trace_event_shape():
    tr = Tracer(clock=lambda: 2.0).enable()
    with tr.span("s", cat="engine", rids=[0]):
        pass
    tr.instant("i", cat="gateway", rid=0)
    doc = json.loads(json.dumps(chrome_trace(tr)))     # serializable
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in metas} >= {"process_name", "thread_name"}
    for e in evs:
        assert {"ph", "name", "pid"} <= set(e)
        if e["ph"] != "M":
            assert "ts" in e and "tid" in e
            assert e["ts"] == pytest.approx(2.0e6)     # microseconds
    span = next(e for e in evs if e["name"] == "s")
    assert span["ph"] == "X" and span["dur"] == 0.0
    assert span["args"]["rids"] == [0]
    inst = next(e for e in evs if e["name"] == "i")
    assert inst["ph"] == "i" and inst["s"] == "t"
    assert doc["metadata"]["dropped_events"] == 0


# ----------------------------------------------------------------------------
# flight recorder
# ----------------------------------------------------------------------------
def test_flight_recorder_ring_and_dump(tmp_path):
    rec = FlightRecorder(capacity=4, label="unit",
                         clock=iter(np.arange(100.0)).__next__)
    for i in range(10):
        rec.record("step", i=i)
    assert rec.dropped == 6
    snap = rec.snapshot()
    assert [e["i"] for e in snap] == [6, 7, 8, 9]
    assert all(e["kind"] == "step" for e in snap)
    path = rec.dump(reason="boom", directory=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert payload["label"] == "unit" and payload["reason"] == "boom"
    assert payload["dropped"] == 6 and len(payload["events"]) == 4


def test_driver_death_dumps_flight_record(model_params, tmp_path,
                                          monkeypatch):
    """A fatal engine step must leave a postmortem on disk: the ring of
    events leading up to the crash plus the recorded reason."""
    monkeypatch.setenv("REPRO_FLIGHT_DIR", str(tmp_path))
    model, params = model_params
    eng = _engine(model, params)
    boom = RuntimeError("induced step failure")

    def bad_step():
        raise boom
    eng.step = bad_step
    drv = EngineDriver(eng, idle_wait_s=0.01).start()
    done = threading.Event()
    fut = drv.submit([ServeRequest(prompt=np.array([1, 2, 3], np.int32),
                                   max_new_tokens=4, rid=0)],
                     lambda req: done.set())
    fut.result(timeout=5)
    drv._thread.join(timeout=5)
    assert not drv.alive and drv.error is boom
    assert done.wait(timeout=5)         # watcher failed over, not hung
    assert drv.flight_path is not None
    with open(drv.flight_path) as f:
        payload = json.load(f)
    assert repr(boom) in payload["reason"]
    kinds = [e["kind"] for e in payload["events"]]
    assert kinds[-1] == "fatal"         # last event is the crash itself
    assert "submit" in kinds            # ...preceded by engine history


# ----------------------------------------------------------------------------
# energy meter
# ----------------------------------------------------------------------------
def test_energy_meter_linear_fit_and_accounting():
    meter = EnergyMeter(_cfg())
    # the fitted per-token cost must match a direct simulator call
    from repro.core.hw import HWConfig
    from repro.core.simulator import EdgeCIMSimulator
    direct = EdgeCIMSimulator().decode_token(
        slm_spec_from_model_config(_cfg()), HWConfig(), 256.0,
        w_bits=4, a_bits=8)
    assert meter.decode_cost_j(256.0) == pytest.approx(direct.joules,
                                                       rel=1e-9)
    meter.charge_decode(10, mean_seq=256.0)
    meter.charge_prefill(64)
    assert meter.decode_j == pytest.approx(10 * direct.joules)
    assert meter.prefill_j > 0 and meter.total_j > meter.decode_j
    assert meter.tokens_per_j() == pytest.approx(10 / meter.total_j)
    s = meter.summary()
    assert s["sim_decode_tokens"] == 10.0
    assert s["sim_tokens_per_j"] > 0 and s["sim_tokens_per_s"] > 0
    meter.reset()
    assert meter.total_j == 0.0 and meter.summary()["sim_tokens_per_j"] == 0.0


def test_engine_summary_reports_simulated_energy(model_params):
    model, params = model_params
    eng = _engine(model, params)
    reqs = [ServeRequest(prompt=np.array([1, 2, 3, 4], np.int32),
                         max_new_tokens=5, rid=i) for i in range(2)]
    eng.run(reqs)
    m = eng.summary()
    assert m["sim_energy_j"] > 0
    # each request's first token comes off the prefill graph; all the
    # rest are decode tokens the meter charged
    assert m["sim_decode_tokens"] == m["tokens"] - m["requests"]
    assert m["sim_tokens_per_j"] == pytest.approx(
        m["sim_decode_tokens"] / m["sim_energy_j"])


def test_fleet_aggregation_recomputes_energy_ratios():
    a = {"sim_energy_j": 2.0, "sim_decode_tokens": 100.0,
         "sim_time_s": 1.0, "tokens": 110.0}
    b = {"sim_energy_j": 6.0, "sim_decode_tokens": 200.0,
         "sim_time_s": 3.0, "tokens": 220.0}
    agg = aggregate_summaries([a, b])
    assert agg["sim_energy_j"] == pytest.approx(8.0)
    # ratio recomputed from fleet sums, NOT averaged per replica
    assert agg["sim_tokens_per_j"] == pytest.approx(300.0 / 8.0)
    assert agg["sim_tokens_per_s"] == pytest.approx(300.0 / 4.0)


# ----------------------------------------------------------------------------
# prometheus exposition
# ----------------------------------------------------------------------------
_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"            # metric name
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""  # first label
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" (NaN|[+-]?Inf|[-+0-9.eE]+)$")


def _parse_prom(text):
    """Parse exposition text into {name: {labelstr: float}}; asserts
    every non-comment line matches the 0.0.4 grammar."""
    samples = {}
    for line in text.strip().split("\n"):
        if line.startswith("# TYPE "):
            parts = line.split()
            assert len(parts) == 4
            assert parts[3] in ("counter", "gauge", "histogram")
            continue
        assert _PROM_LINE.match(line), f"bad exposition line: {line!r}"
        name_labels, _, value = line.rpartition(" ")
        name, _, labels = name_labels.partition("{")
        samples.setdefault(name, {})[labels] = float(value)
    return samples


def test_prometheus_text_grammar_and_agreement():
    payload = {
        "schema_version": 2,
        "engine": {"tokens": 42.0, "requests": 7.0,
                   "ttft_p50_s": 0.0125, "spec_acceptance_rate":
                   float("nan"), "sim_tokens_per_j": 173.0},
        "n_running": 3, "n_queued": 0, "kv_pages_free": 11,
        "gateway": {"http_requests": 9, "inflight": 2,
                    "max_pending": 64},
        "fleet": {"n_replicas": 2, "n_live": 2,
                  "counters": {"dispatches": 5},
                  "affinity_hits": 4,
                  "replicas": {
                      "0": {"alive": True, "pending": 1,
                            "dispatches": 3,
                            "snapshot": {"kv_occupancy": 0.5}},
                      "1": {"alive": False, "pending": 0,
                            "dispatches": 2, "snapshot": {}}}},
        "histograms": {"ttft_s": {
            "edges_s": [0.0, 0.1, 1.0, "inf"], "counts": [2, 3, 1]}},
    }
    text = prometheus_text(payload)
    samples = _parse_prom(text)
    assert samples["repro_engine_tokens_total"][""] == 42.0
    assert samples["repro_engine_requests_total"][""] == 7.0
    assert samples["repro_engine_ttft_p50_s"][""] == 0.0125
    assert samples["repro_engine_sim_tokens_per_j"][""] == 173.0
    assert samples["repro_gateway_http_requests_total"][""] == 9.0
    assert samples["repro_gateway_inflight"][""] == 2.0
    assert samples["repro_fleet_dispatches_total"][""] == 5.0
    assert samples["repro_fleet_affinity_hits_total"][""] == 4.0
    up = samples["repro_replica_up"]
    assert up['replica="0"}'] == 1.0 and up['replica="1"}'] == 0.0
    # histogram: cumulative buckets ending in +Inf == count
    buckets = samples["repro_ttft_seconds_bucket"]
    assert buckets['le="0.1"}'] == 2.0
    assert buckets['le="1.0"}'] == 5.0
    assert buckets['le="+Inf"}'] == 6.0
    assert samples["repro_ttft_seconds_count"][""] == 6.0
    # "no data yet" is an ABSENT series, never a NaN sample: a NaN line
    # poisons every Prometheus recording rule that aggregates over it
    assert "repro_engine_spec_acceptance_rate" not in text
    assert "NaN" not in text


# ----------------------------------------------------------------------------
# end-to-end: 2-replica gateway capture
# ----------------------------------------------------------------------------
async def _get(host, port, path):
    import asyncio
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data


async def _post(host, port, body):
    import asyncio
    payload = json.dumps(body).encode()
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n"
                  ).encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data


def _status(raw):
    return int(raw.split(b"\r\n", 1)[0].split()[1])


def _body(raw):
    return raw.partition(b"\r\n\r\n")[2]


def test_gateway_trace_prometheus_and_access_log(model_params, tracing,
                                                 tmp_path):
    import asyncio
    import io
    model, params = model_params
    log = io.StringIO()

    async def run():
        engines = [_engine(model, params) for _ in range(2)]
        gw = Gateway(FleetRouter(engines, policy="rr", max_pending=16),
                     access_log=log)
        host, port = await gw.start()
        try:
            raws = await asyncio.gather(*[
                _post(host, port, {"prompt": [1 + i, 2, 3],
                                   "max_tokens": 4}) for i in range(4)])
            trace_raw = await _get(host, port, "/debug/trace")
            prom_raw = await _get(host, port,
                                  "/metrics?format=prometheus")
            json_raw = await _get(host, port, "/metrics")
        finally:
            await gw.stop()
        return raws, trace_raw, prom_raw, json_raw

    raws, trace_raw, prom_raw, json_raw = asyncio.run(run())
    assert all(_status(r) == 200 for r in raws)

    # -- Chrome trace: request ids correlate across all three layers
    doc = json.loads(_body(trace_raw))
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} >= {"M", "X", "i"}
    gw_spans = [e for e in evs
                if e.get("name") == "request" and e["ph"] == "X"]
    assert len(gw_spans) == 4
    gw_rids = {e["args"]["rid"] for e in gw_spans}
    route_rids = {r for e in evs if e.get("name") == "route_dispatch"
                  for r in e["args"]["rids"]}
    decode_rids = {r for e in evs if e.get("name") == "decode_step"
                   for r in e["args"]["rids"]}
    assert gw_rids <= route_rids, "router missed dispatch events"
    assert gw_rids <= decode_rids, \
        "engine decode spans don't carry the gateway's request ids"
    # distinct per-replica driver tracks, named by the fleet
    thread_names = {e["args"]["name"] for e in evs
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"engine-driver-0", "engine-driver-1"} <= thread_names
    # rr over 4 requests lands work on both replicas
    driver_tids = {e["tid"] for e in evs
                   if e.get("name") == "decode_step"}
    assert len(driver_tids) == 2

    # -- Prometheus view parses and agrees with the JSON payload
    assert b"text/plain; version=0.0.4" in prom_raw
    samples = _parse_prom(_body(prom_raw).decode())
    payload = json.loads(_body(json_raw))
    assert payload["schema_version"] == 3
    assert samples["repro_metrics_schema_version"][""] == 3.0
    # scraped AFTER the json view, but the server was idle in between:
    # token counters must agree exactly
    assert samples["repro_engine_tokens_total"][""] == \
        payload["engine"]["tokens"]
    assert samples["repro_gateway_completed_samples_total"][""] == \
        payload["gateway"]["completed_samples"]
    assert payload["engine"]["sim_energy_j"] > 0
    assert payload["engine"]["sim_tokens_per_j"] > 0
    assert samples["repro_engine_sim_tokens_per_j"][""] > 0
    assert samples["repro_ttft_seconds_count"][""] == 4.0

    # -- structured access log: one JSON line per request
    lines = [json.loads(ln) for ln in
             log.getvalue().strip().split("\n")]
    assert len(lines) == 4
    for ln in lines:
        assert ln["status"] == "ok" and ln["tokens"] == 4
        assert ln["replica"] in (0, 1) and ln["policy"] == "rr"
        assert ln["ttft_s"] > 0 and ln["dur_s"] >= ln["ttft_s"]
    assert {ln["rid"] for ln in lines} <= gw_rids


def test_debug_trace_404_when_disabled(model_params):
    import asyncio
    model, params = model_params
    get_tracer().disable()

    async def run():
        gw = Gateway(_engine(model, params))
        host, port = await gw.start()
        try:
            return await _get(host, port, "/debug/trace")
        finally:
            await gw.stop()

    raw = asyncio.run(run())
    assert _status(raw) == 404
    assert b"tracing disabled" in raw


def test_tracing_disabled_emits_no_events(model_params):
    """The default path must stay quiet: an untraced engine run leaves
    the process tracer empty (the recorder, by contrast, is always
    on)."""
    model, params = model_params
    tr = get_tracer()
    tr.disable()
    tr.clear()
    eng = _engine(model, params)
    eng.run([ServeRequest(prompt=np.array([1, 2, 3], np.int32),
                          max_new_tokens=3, rid=0)])
    assert tr.events() == []
    assert eng.recorder.pushes > 0


# ----------------------------------------------------------------------------
# trace_view CLI
# ----------------------------------------------------------------------------
def test_trace_view_rollup(tmp_path, capsys):
    import importlib.util
    import os
    spec = importlib.util.spec_from_file_location(
        "trace_view", os.path.join(os.path.dirname(__file__), "..",
                                   "tools", "trace_view.py"))
    tv = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(tv)

    doc = {"traceEvents": [
        {"ph": "X", "name": "request", "cat": "gateway", "ts": 0,
         "dur": 5000.0, "pid": 1, "tid": 1,
         "args": {"rid": 7, "status": "ok", "tokens": 3}},
        {"ph": "X", "name": "decode_step", "cat": "engine", "ts": 100,
         "dur": 1000.0, "pid": 1, "tid": 2, "args": {"rids": [7, 8]}},
        {"ph": "X", "name": "decode_step", "cat": "engine", "ts": 1200,
         "dur": 2000.0, "pid": 1, "tid": 2, "args": {"rids": [7]}},
        {"ph": "i", "name": "admit", "cat": "engine", "ts": 50,
         "pid": 1, "tid": 2, "args": {"rid": 7}},
    ]}
    path = tmp_path / "t.json"
    path.write_text(json.dumps(doc))

    events = tv.load_events(str(path))
    agg = tv.phase_breakdown(events)
    assert agg["decode_step"]["n"] == 2
    assert agg["decode_step"]["total_us"] == pytest.approx(3000.0)
    reqs = tv.per_request(events)
    assert reqs[7]["wall_us"] == pytest.approx(5000.0)
    # rid 7 is charged BOTH decode steps; rid 8 only the shared one
    assert reqs[7]["phases"]["decode_step"] == pytest.approx(3000.0)
    assert reqs[8]["phases"]["decode_step"] == pytest.approx(1000.0)
    assert tv.main([str(path), "--top", "2"]) == 0
    out = capsys.readouterr().out
    assert "decode_step" in out and "slowest requests" in out
