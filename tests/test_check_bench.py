"""The CI benchmark regression gate (tools/check_bench.py): direction
and tolerance semantics that bench-smoke relies on."""
import importlib.util
import os

import pytest

_spec = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(os.path.dirname(__file__), "..", "tools",
                                "check_bench.py"))
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)

TOLS = {"quality": 0.15, "timing": 1.0}


def _row(**kw):
    base = {"mix": "short", "batch": 2}
    base.update(kw)
    return base


@pytest.mark.bench
def test_identical_runs_pass():
    rows = [_row(ttft_p50_s=0.1, tokens_per_s_decode=40.0,
                 kv_savings=0.7)]
    assert check_bench.check_file("b", rows, rows, TOLS) == []


@pytest.mark.bench
def test_timing_regression_beyond_tolerance_fails():
    base = [_row(ttft_p50_s=0.1)]
    ok = [_row(ttft_p50_s=0.19)]          # < 2x: inside timing tol
    bad = [_row(ttft_p50_s=0.21)]         # > 2x
    assert check_bench.check_file("b", base, ok, TOLS) == []
    fails = check_bench.check_file("b", base, bad, TOLS)
    assert len(fails) == 1 and "ttft_p50_s" in fails[0]


@pytest.mark.bench
def test_higher_is_better_direction():
    base = [_row(tokens_per_s_decode=40.0, acceptance_rate=0.8)]
    faster = [_row(tokens_per_s_decode=400.0, acceptance_rate=1.0)]
    assert check_bench.check_file("b", base, faster, TOLS) == [], \
        "improvement is never a regression"
    worse = [_row(tokens_per_s_decode=40.0, acceptance_rate=0.5)]
    fails = check_bench.check_file("b", base, worse, TOLS)
    assert len(fails) == 1 and "acceptance_rate" in fails[0]


@pytest.mark.bench
def test_higher_better_timing_metric_can_fail_at_large_tol():
    """The ratio band must gate throughput collapses even at loose
    timing tolerance (an additive band never could for tol >= 1)."""
    tols = {"quality": 0.15, "timing": 3.0}
    base = [_row(tokens_per_s_decode=40.0, ttft_speedup=1.8)]
    collapsed = [_row(tokens_per_s_decode=1.0, ttft_speedup=0.1)]
    fails = check_bench.check_file("b", base, collapsed, tols)
    assert len(fails) == 2
    barely = [_row(tokens_per_s_decode=11.0, ttft_speedup=0.5)]
    assert check_bench.check_file("b", base, barely, tols) == [], \
        "within b/(1+tol) still passes"


@pytest.mark.bench
def test_missing_row_and_metric_fail():
    base = [_row(mix="short", ttft_p50_s=0.1),
            _row(mix="mixed", ttft_p50_s=0.1)]
    cur = [_row(mix="short")]
    fails = check_bench.check_file("b", base, cur, TOLS)
    assert any("row missing" in f for f in fails)
    assert any("disappeared" in f for f in fails)


@pytest.mark.bench
def test_nan_baseline_and_unknown_metrics_ignored():
    base = [_row(acceptance_rate=float("nan"), n_pages=8,
                 some_counter=3.0)]
    cur = [_row(acceptance_rate=0.0, n_pages=99, some_counter=0.0)]
    assert check_bench.check_file("b", base, cur, TOLS) == []


@pytest.mark.bench
def test_nan_baseline_tolerates_absent_current_metric():
    """A baseline that never measured a metric (NaN) must accept a
    current run that omits it entirely — absent-not-NaN is the
    exporters' encoding for "no data", so the row JSON may simply drop
    the key.  A metric the baseline DID measure still fails when it
    vanishes (covered by test_missing_row_and_metric_fail)."""
    base = [_row(acceptance_rate=float("nan"), ttft_p50_s=0.1)]
    cur = [_row(ttft_p50_s=0.1)]          # acceptance_rate absent
    assert check_bench.check_file("b", base, cur, TOLS) == []


@pytest.mark.bench
def test_metric_degrading_to_nan_fails():
    """A measurable baseline turning NaN (e.g. acceptance rate with
    zero drafts) is a regression, not a skip."""
    base = [_row(acceptance_rate=0.9)]
    cur = [_row(acceptance_rate=float("nan"))]
    fails = check_bench.check_file("b", base, cur, TOLS)
    assert len(fails) == 1 and "NaN" in fails[0]


@pytest.mark.bench
def test_main_fails_when_current_json_missing(tmp_path):
    """A committed baseline whose bench produced no JSON this run must
    fail the gate, not silently drop out of the comparison set."""
    import json
    import sys
    baseline, current = tmp_path / "base", tmp_path / "cur"
    baseline.mkdir(), current.mkdir()
    (baseline / "serve_bench.json").write_text(
        json.dumps([_row(ttft_p50_s=0.1)]))
    argv = ["check_bench", "--baseline", str(baseline),
            "--current", str(current)]
    old = sys.argv
    try:
        sys.argv = argv
        assert check_bench.main() == 1
    finally:
        sys.argv = old


@pytest.mark.bench
def test_scaling_rule_gates_replica_goodput_within_current_run():
    """Fleet rows differing only in `replicas` must show N-replica
    goodput >= scaling_min x the 1-replica row — judged on the CURRENT
    run alone, so a dispatch regression that flattens scaling fails
    even when every row individually beats its baseline."""
    cur = [_row(rate=100.0, policy="least-loaded", replicas=1,
                goodput_tokens_per_s=80.0),
           _row(rate=100.0, policy="least-loaded", replicas=2,
                goodput_tokens_per_s=130.0)]
    assert check_bench.check_scaling("b", cur, 1.5) == []
    flat = [dict(cur[0]), dict(cur[1], goodput_tokens_per_s=90.0)]
    fails = check_bench.check_scaling("b", flat, 1.5)
    assert len(fails) == 1 and "1.12x" in fails[0]
    # different rate => different identity group: never compared
    other = [dict(cur[0]), dict(cur[1], rate=8.0,
                                goodput_tokens_per_s=1.0)]
    assert check_bench.check_scaling("b", other, 1.5) == []
    # single-engine benches carry no `replicas` key: rule is inert
    legacy = [_row(rate=20.0, goodput_tokens_per_s=50.0)]
    assert check_bench.check_scaling("b", legacy, 1.5) == []


@pytest.mark.bench
def test_replicas_policy_are_identity_not_metrics():
    """`replicas`/`policy` distinguish rows (no cross-policy metric
    comparison) and are never themselves gated."""
    base = [_row(policy="rr", replicas=2, ttft_p50_s=0.1),
            _row(policy="prefix", replicas=2, ttft_p50_s=0.5)]
    cur = [_row(policy="rr", replicas=2, ttft_p50_s=0.1),
           _row(policy="prefix", replicas=2, ttft_p50_s=0.5)]
    assert check_bench.check_file("b", base, cur, TOLS) == []
    assert check_bench.check_file(
        "b", base, [cur[1], cur[0]], TOLS) == [], "order-insensitive"
    fails = check_bench.check_file("b", base, [cur[0]], TOLS)
    assert len(fails) == 1 and "policy=prefix" in fails[0]


@pytest.mark.bench
def test_tracing_overhead_gate_within_current_run():
    """api_bench --trace emits each cell as an off/on pair differing
    only in `tracing`; the traced goodput must stay within
    overhead_max of the untraced one — judged on the current run, so
    runner speed cancels out."""
    cur = [_row(rate=20.0, replicas=2, tracing=False,
                goodput_tokens_per_s=100.0),
           _row(rate=20.0, replicas=2, tracing=True,
                goodput_tokens_per_s=97.0)]
    assert check_bench.check_tracing_overhead("b", cur, 0.05) == []
    slow = [dict(cur[0]), dict(cur[1], goodput_tokens_per_s=80.0)]
    fails = check_bench.check_tracing_overhead("b", slow, 0.05)
    assert len(fails) == 1 and "tracing costs 20.0%" in fails[0]
    # an unpaired traced row, or rows without the field, gate nothing
    assert check_bench.check_tracing_overhead("b", [cur[1]], 0.05) == []
    legacy = [_row(rate=20.0, goodput_tokens_per_s=50.0)]
    assert check_bench.check_tracing_overhead("b", legacy, 0.05) == []
    # different rates are different cells: never compared
    other = [dict(cur[0]), dict(cur[1], rate=8.0,
                                goodput_tokens_per_s=1.0)]
    assert check_bench.check_tracing_overhead("b", other, 0.05) == []


@pytest.mark.bench
def test_tracing_is_identity_not_a_metric():
    """A `tracing` mismatch means a DIFFERENT row, not a regression —
    and the field itself is never gated as a metric."""
    base = [_row(tracing=False, ttft_p50_s=0.1),
            _row(tracing=True, ttft_p50_s=0.5)]
    assert check_bench.check_file("b", base, base, TOLS) == []
    fails = check_bench.check_file("b", base, [base[0]], TOLS)
    assert len(fails) == 1 and "tracing=True" in fails[0]


@pytest.mark.bench
def test_bool_quality_metric_gates():
    base = [_row(outputs_byte_identical=True)]
    cur = [_row(outputs_byte_identical=False)]
    fails = check_bench.check_file("b", base, cur, TOLS)
    assert len(fails) == 1 and "outputs_byte_identical" in fails[0]


@pytest.mark.bench
def test_slo_gate_pages_and_drift_band():
    """api_bench --slo rows: a page-level alert in the smoke cell or a
    worst-replica drift ratio outside [1/drift_max, drift_max] fails —
    judged on the current run alone (no baseline ratios: a twin whose
    baseline drifted too would sail through a relative check)."""
    ok = [_row(rate=20.0, replicas=2, slo=True, slo_worst="ok",
               slo_page_alerts=0, slo_warn_alerts=0,
               sim_drift_ratio=1.2, sim_drift_alarms=0)]
    assert check_bench.check_slo("b", ok, 3.0) == []
    paged = [dict(ok[0], slo_page_alerts=2, slo_worst="page")]
    fails = check_bench.check_slo("b", paged, 3.0)
    assert len(fails) == 1 and "page-level" in fails[0]
    # the band is symmetric: 4x in either direction fails at 3x max
    slow = [dict(ok[0], sim_drift_ratio=0.25)]
    fast = [dict(ok[0], sim_drift_ratio=4.0)]
    for bad in (slow, fast):
        fails = check_bench.check_slo("b", bad, 3.0)
        assert len(fails) == 1 and "sim_drift_ratio" in fails[0]
    assert check_bench.check_slo("b", slow, 5.0) == [], \
        "--drift-max widens the band"
    # NaN ratio = no replica calibrated: skipped, not failed
    uncal = [dict(ok[0], sim_drift_ratio=float("nan"))]
    assert check_bench.check_slo("b", uncal, 3.0) == []
    # rows not labeled slo (or labeled False) gate nothing
    off = [_row(rate=20.0, replicas=2, slo=False, sim_drift_ratio=9.0),
           _row(rate=20.0, goodput_tokens_per_s=50.0)]
    assert check_bench.check_slo("b", off, 3.0) == []


@pytest.mark.bench
def test_slo_is_identity_not_a_metric():
    """An `slo` mismatch means a DIFFERENT row; slo'd and plain cells
    of the same sweep never cross-compare."""
    base = [_row(slo=True, ttft_p50_s=0.5), _row(ttft_p50_s=0.1)]
    assert check_bench.check_file("b", base, base, TOLS) == []
    fails = check_bench.check_file("b", base, [base[1]], TOLS)
    assert len(fails) == 1 and "slo=True" in fails[0]
