"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cim_gemv import cim_gemv
from repro.kernels.flash_decode import flash_decode
from repro.kernels.paged_flash_decode import (paged_flash_decode,
                                              paged_flash_verify)
from repro.kernels.ref import (ref_flash_decode, ref_paged_decode,
                               ref_paged_verify, ref_qmatmul,
                               ref_swiglu_qgemv)
from repro.kernels.swiglu_gemv import swiglu_qgemv
from repro.kernels import ops
from repro.quant.qarray import quantize


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("m,k,n,bk,bn,group", [
    (1, 256, 128, 256, 128, 128),     # pure GEMV
    (4, 512, 256, 256, 128, 128),
    (8, 1024, 512, 512, 256, 128),    # default-ish blocks
    (2, 512, 384, 256, 128, 64),      # non-default group
    (1, 256, 128, 128, 128, 32),      # small group
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cim_gemv_sweep(bits, m, k, n, bk, bn, group, dtype):
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n), jnp.float32) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), jnp.float32
                          ).astype(dtype)
    qt = quantize(w, bits=bits, group=group)
    ref = ref_qmatmul(x.astype(jnp.float32), qt, out_dtype=jnp.float32)
    out = cim_gemv(x, qt.data, qt.scales, bits=bits, group=group,
                   block_n=bn, block_k=bk, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    rel = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))
                / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < tol, rel


@pytest.mark.parametrize("S,block_s,window,cap", [
    (512, 256, 0, 0.0),
    (1024, 512, 0, 0.0),
    (1024, 256, 200, 0.0),
    (1024, 256, 0, 50.0),
    (512, 512, 64, 30.0),
])
@pytest.mark.parametrize("pos_frac", [0.1, 0.7, 1.0])
def test_flash_decode_sweep(S, block_s, window, cap, pos_frac):
    b, g, qpk, hd = 2, 2, 4, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, g, qpk, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, S, g, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, S, g, hd), jnp.float32)
    pos = jnp.int32(int(pos_frac * (S - 1)))
    ref = ref_flash_decode(q, k, v, pos, window, cap)
    qf = q.reshape(b * g, qpk, hd)
    kf = k.swapaxes(1, 2).reshape(b * g, S, hd)
    vf = v.swapaxes(1, 2).reshape(b * g, S, hd)
    out = flash_decode(qf, kf, vf, pos, block_s=block_s, window=window,
                       attn_cap=cap, interpret=True).reshape(b, g, qpk, hd)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("page_size,max_pages,window,cap", [
    (16, 8, 0, 0.0),
    (32, 4, 0, 0.0),
    (16, 8, 40, 0.0),
    (16, 8, 0, 30.0),
    (8, 16, 24, 50.0),
])
def test_paged_flash_decode_sweep(page_size, max_pages, window, cap):
    """Block-table kernel vs the gather oracle, shuffled page layouts and
    ragged per-sequence lengths."""
    b, g, qpk, hd = 3, 2, 4, 64
    n_pages = b * max_pages
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, g, qpk, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, page_size, g, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page_size, g, hd)),
                     jnp.float32)
    tables = jnp.asarray(
        rng.permutation(n_pages).reshape(b, max_pages), jnp.int32)
    S = max_pages * page_size
    lengths = jnp.asarray(rng.integers(1, S + 1, size=b), jnp.int32)
    ref = ref_paged_decode(q, kp, vp, tables, lengths, window, cap)
    out = paged_flash_decode(q, kp, vp, tables, lengths, window=window,
                             attn_cap=cap, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


@pytest.mark.parametrize("s,page_size,max_pages,window,cap", [
    (4, 16, 8, 0, 0.0),
    (5, 8, 16, 0, 0.0),
    (3, 16, 8, 24, 0.0),
    (4, 16, 8, 0, 30.0),
    (2, 8, 16, 12, 50.0),
])
def test_paged_flash_verify_sweep(s, page_size, max_pages, window, cap):
    """Multi-query verify kernel vs the gather oracle: shuffled page
    layouts, ragged base lengths, every intra-window causal horizon."""
    b, g, qpk, hd = 3, 2, 4, 64
    n_pages = b * max_pages
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, s, g, qpk, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, page_size, g, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page_size, g, hd)),
                     jnp.float32)
    tables = jnp.asarray(
        rng.permutation(n_pages).reshape(b, max_pages), jnp.int32)
    S = max_pages * page_size
    lengths = jnp.asarray(rng.integers(0, S - s + 1, size=b), jnp.int32)
    ref = ref_paged_verify(q, kp, vp, tables, lengths, window, cap)
    out = paged_flash_verify(q, kp, vp, tables, lengths, window=window,
                             attn_cap=cap, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_paged_verify_s1_matches_paged_decode():
    """A 1-wide verify window IS a decode step (lengths exclusive vs
    inclusive is the only difference in convention)."""
    b, g, qpk, hd, ps, mp = 2, 2, 4, 64, 16, 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((b, 1, g, qpk, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((b * mp, ps, g, hd)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((b * mp, ps, g, hd)), jnp.float32)
    tables = jnp.arange(b * mp, dtype=jnp.int32).reshape(b, mp)
    lengths = jnp.asarray([17, 90], jnp.int32)
    dec = ref_paged_decode(q[:, 0], kp, vp, tables, lengths + 1)
    ver = ref_paged_verify(q, kp, vp, tables, lengths)[:, 0]
    assert float(jnp.max(jnp.abs(dec - ver))) < 1e-6
    krn = paged_flash_verify(q, kp, vp, tables, lengths,
                             interpret=True)[:, 0]
    assert float(jnp.max(jnp.abs(dec - krn))) < 1e-5


def test_paged_decode_matches_dense_flash_decode():
    """Identity block table + full lengths == the dense decode kernel."""
    b, g, qpk, hd, ps, n_pg = 2, 2, 2, 32, 16, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((b, g, qpk, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((b * n_pg // 2, ps, g, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((b * n_pg // 2, ps, g, hd)),
                     jnp.float32)
    tables = jnp.arange(b * n_pg // 2, dtype=jnp.int32).reshape(b, -1)
    S = (n_pg // 2) * ps
    kd = kp.reshape(b, S, g, hd)
    vd = vp.reshape(b, S, g, hd)
    pos = jnp.int32(100)
    dense = ref_flash_decode(q, kd, vd, pos)
    paged = ops.paged_decode_attention(
        q, kp, vp, tables, jnp.full((b,), 101, jnp.int32),
        use_kernel=False)
    assert float(jnp.max(jnp.abs(dense - paged))) < 1e-6


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("k,f", [(256, 128), (512, 256)])
def test_swiglu_fused_sweep(bits, k, f):
    wg = jax.random.normal(jax.random.PRNGKey(0), (k, f), jnp.float32) * 0.1
    wu = jax.random.normal(jax.random.PRNGKey(1), (k, f), jnp.float32) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (2, k), jnp.float32)
    qg = quantize(wg, bits, 128)
    qu = quantize(wu, bits, 128)
    ref = ref_swiglu_qgemv(x, qg, qu)
    out = swiglu_qgemv(x, qg.data, qg.scales, qu.data, qu.scales, bits=bits,
                       group=128, block_n=128, block_k=256, interpret=True)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_ops_qmatmul_dispatches_and_matches():
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 256), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 512), jnp.float32)
    qt = quantize(w, 4, 128)
    out_kernel = ops.qmatmul(x, qt)          # aligned -> pallas interpret
    out_ref = ops.qmatmul_xla(x, qt)         # dequants to bf16 (serving path)
    rel = float(jnp.max(jnp.abs(out_kernel - out_ref))
                / jnp.max(jnp.abs(out_ref)))
    assert rel < 5e-3


def test_decode_attention_wrapper():
    b, g, qpk, hd, S = 2, 2, 2, 32, 1024
    q = jax.random.normal(jax.random.PRNGKey(0), (b, g, qpk, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, S, g, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, S, g, hd))
    pos = jnp.int32(900)
    out_k = ops.decode_attention(q, k, v, pos, use_kernel=True)
    out_r = ops.decode_attention(q, k, v, pos, use_kernel=False)
    assert float(jnp.max(jnp.abs(out_k - out_r))) < 1e-5
