"""Paged continuous-batching runtime: equivalence vs the dense decode
path, the prefill-clobbering regression, sampling, and telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import DecoderLM, ModelConfig, init_params
from repro.models.config import MLAConfig
from repro.models.common import spec_structs
from repro.serve import (PagedServeEngine, SamplingParams, ServeRequest,
                         sample_tokens)


def _model(**kw):
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False, **kw)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


def _zeros(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  spec_structs(tree))


def _paged_vs_dense(model, params, toks, chunk=4, atol=1e-4):
    """Decode `toks` through decode_step and paged_step; compare logits."""
    cache = _zeros(model.cache_specs(1, 32, jnp.float32))
    dense = []
    for t, tok in enumerate(toks):
        lg, cache = model.decode_step(params, cache,
                                      {"tokens": jnp.asarray([[tok]])},
                                      jnp.int32(t))
        dense.append(np.asarray(lg[0, 0]))

    ps, n_pages = 4, 10
    pool = _zeros(model.paged_cache_specs(n_pages, ps, jnp.float32))
    tables = jnp.asarray([[3, 7, 1, 5, 0, 0, 0, 0]], jnp.int32)
    lg, pool = model.paged_step(
        params, pool, {"tokens": jnp.asarray(toks[None, :chunk])}, tables,
        jnp.asarray([0], jnp.int32), jnp.asarray([chunk], jnp.int32))
    paged = [np.asarray(lg[0, i]) for i in range(chunk)]
    L = chunk
    for tok in toks[chunk:]:
        lg, pool = model.paged_step(
            params, pool, {"tokens": jnp.asarray([[tok]])}, tables,
            jnp.asarray([L], jnp.int32), jnp.asarray([1], jnp.int32))
        paged.append(np.asarray(lg[0, 0]))
        L += 1
    for i, (d, p) in enumerate(zip(dense, paged)):
        np.testing.assert_allclose(p, d, atol=atol,
                                   err_msg=f"position {i}")


def test_paged_matches_dense_gqa():
    model, params = _model()
    toks = np.array([5, 9, 3, 17, 2, 41, 8], np.int32)
    _paged_vs_dense(model, params, toks)


def test_paged_matches_dense_local_window():
    model, params = _model(local_window=3, local_pattern=2,
                           rope_theta_local=10000.0)
    toks = np.array([5, 9, 3, 17, 2, 41, 8, 30], np.int32)
    _paged_vs_dense(model, params, toks)


def test_paged_matches_dense_mla():
    cfg = ModelConfig(name="m", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False,
                      attn_kind="mla",
                      mla=MLAConfig(kv_lora_rank=16, qk_nope_head_dim=16,
                                    qk_rope_head_dim=8, v_head_dim=16))
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(1),
                         dtype_override=jnp.float32)
    toks = np.array([5, 9, 3, 17, 2, 41], np.int32)
    _paged_vs_dense(model, params, toks)


# ----------------------------------------------------------------------------
# the seed `_prefill_slot` regression: prefilling one request must not
# clobber cache rows of requests already decoding
# ----------------------------------------------------------------------------
def test_prefill_does_not_clobber_active_requests():
    model, params = _model()
    prompt_a = np.array([1, 2, 3], np.int32)
    prompt_b = np.arange(10, 34, dtype=np.int32) % 64   # long: multi-chunk

    def run(requests):
        eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                               page_size=8, prefill_chunk=4)
        eng.run(requests)
        return requests

    solo = run([ServeRequest(prompt=prompt_a, max_new_tokens=12, rid=0)])
    a, b = run([ServeRequest(prompt=prompt_a, max_new_tokens=12, rid=0),
                ServeRequest(prompt=prompt_b, max_new_tokens=4, rid=1)])
    # b's chunked prefill interleaves with a's first decode steps; a's
    # greedy continuation must be identical to running alone
    assert a.out_tokens == solo[0].out_tokens
    assert len(b.out_tokens) == 4


def test_engine_mixed_lengths_more_requests_than_lanes():
    model, params = _model()
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(prompt=rng.integers(0, 64, int(n)
                                             ).astype(np.int32),
                         max_new_tokens=5, rid=i)
            for i, n in enumerate([3, 11, 7, 20, 5])]
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=8, n_pages=12, prefill_chunk=8)
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) == 5 for r in reqs)
    # drained: every page is free or retained only by the prefix trie
    # (reclaimable on demand) — nothing is leaked to dead sequences
    assert eng.cache.n_free_or_cached() == 12, "pages leaked after drain"
    m = eng.summary()
    assert m["tokens"] == 25
    assert m["kv_occupancy_peak"] <= 1.0
    assert np.isfinite(m["ttft_p50_s"]) and np.isfinite(m["tpot_p50_s"])
    assert m["ttft_p99_s"] >= m["ttft_p50_s"]


def test_paged_pool_smaller_than_dense_on_mixed_workload():
    """The acceptance bar: a workload-sized pool serves a mixed-length
    request set in less KV memory than the dense (n_slots, max_seq)
    cache the seed engine would allocate."""
    model, params = _model()
    rng = np.random.default_rng(1)
    lens = [4, 28, 9, 17]
    max_batch, max_seq, page_size, new = 4, 64, 8, 6
    peak_tokens = sum(n + new for n in lens)
    n_pages = -(-peak_tokens // page_size) + max_batch
    eng = PagedServeEngine(model, params, max_batch=max_batch,
                           max_seq=max_seq, page_size=page_size,
                           n_pages=n_pages, kv_dtype=jnp.bfloat16)
    reqs = [ServeRequest(prompt=rng.integers(0, 64, n).astype(np.int32),
                         max_new_tokens=new, rid=i)
            for i, n in enumerate(lens)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    row_bytes = eng.cache.kv_bytes() // (n_pages * page_size)
    dense_bytes = max_batch * max_seq * row_bytes
    assert eng.cache.kv_bytes() < dense_bytes


def test_overlong_prompt_rejected_not_crashed():
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=32,
                           page_size=8)
    reqs = [ServeRequest(prompt=np.arange(50, dtype=np.int32) % 64,
                         max_new_tokens=4, rid=0),
            ServeRequest(prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=4, rid=1)]
    eng.run(reqs)
    assert reqs[0].rejected and reqs[0].out_tokens == []
    assert reqs[1].done and len(reqs[1].out_tokens) == 4


def test_pool_too_small_for_generation_terminates():
    """A request whose generation can never fit the pool must end
    rejected (with partial output), not livelock run() forever."""
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=1, max_seq=64,
                           page_size=4, n_pages=3)
    r = ServeRequest(prompt=np.arange(8, dtype=np.int32),
                     max_new_tokens=10, rid=0)
    eng.run([r])           # must return, not spin
    assert r.done and r.truncated and not r.rejected
    assert len(r.out_tokens) >= 1, "partial progress is preserved"


def test_duplicate_default_rids_do_not_collide():
    """rid is a caller label; the engine keys its cache on its own ids,
    so two requests with the default rid=0 must both serve cleanly."""
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=32,
                           page_size=8)
    reqs = [ServeRequest(prompt=np.array([1, 2, 3], np.int32),
                         max_new_tokens=4),
            ServeRequest(prompt=np.array([4, 5, 6], np.int32),
                         max_new_tokens=4)]
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) == 4 for r in reqs)


def test_empty_prompt_rejected_not_hung():
    model, params = _model()
    eng = PagedServeEngine(model, params, max_batch=1, max_seq=32,
                           page_size=8)
    r = ServeRequest(prompt=np.array([], np.int32), max_new_tokens=4,
                     rid=0)
    eng.run([r])
    assert r.rejected and r.out_tokens == []


def test_shim_accepts_any_max_seq():
    """The seed API took arbitrary max_seq; the shim must keep that."""
    from repro.serve import Request, ServeEngine
    model, params = _model()
    eng = ServeEngine(model, params, n_slots=1, max_seq=100)
    out = eng.run([Request(prompt=np.array([1, 2, 3], np.int32),
                           max_new_tokens=4)])
    assert len(out[0].out_tokens) == 4


def test_engine_preempts_and_recovers_when_pool_exhausts():
    model, params = _model()
    # pool fits both prompts but not both full generations
    eng = PagedServeEngine(model, params, max_batch=2, max_seq=64,
                           page_size=4, n_pages=8, prefill_chunk=8)
    reqs = [ServeRequest(prompt=np.arange(1, 9, dtype=np.int32),
                         max_new_tokens=10, rid=i) for i in range(2)]
    eng.run(reqs)
    assert all(r.done and len(r.out_tokens) >= 10 for r in reqs)
    assert eng.cache.n_free_or_cached() == 8


# ----------------------------------------------------------------------------
# sampling (the seed's softmax-then-argmax bug)
# ----------------------------------------------------------------------------
def test_sample_tokens_greedy_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((3, 64)).astype(np.float32))
    out = sample_tokens(jax.random.PRNGKey(0), logits,
                        jnp.zeros(3), jnp.zeros(3, jnp.int32))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sample_tokens_temperature_varies_with_key():
    logits = jnp.zeros((1, 64))          # uniform: sampling must explore
    temp = jnp.ones(1)
    topk = jnp.zeros(1, jnp.int32)
    draws = {int(sample_tokens(jax.random.PRNGKey(k), logits, temp,
                               topk)[0]) for k in range(20)}
    assert len(draws) > 3, "temperature sampling is not degenerate argmax"
    # deterministic per key
    a = sample_tokens(jax.random.PRNGKey(7), logits, temp, topk)
    b = sample_tokens(jax.random.PRNGKey(7), logits, temp, topk)
    assert int(a[0]) == int(b[0])


def test_sample_tokens_top_k_restricts_support():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((1, 64)).astype(np.float32))
    top5 = set(np.asarray(jnp.argsort(logits[0])[::-1][:5]))
    for k in range(30):
        tok = int(sample_tokens(jax.random.PRNGKey(k), logits,
                                jnp.ones(1) * 2.0,
                                jnp.asarray([5], jnp.int32))[0])
        assert tok in top5


def test_sample_tokens_mixed_lanes():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    out = sample_tokens(jax.random.PRNGKey(0), logits,
                        jnp.asarray([0.0, 1.0]),
                        jnp.asarray([0, 0], jnp.int32))
    assert int(out[0]) == int(jnp.argmax(logits[0]))


def test_sample_tokens_top_p_restricts_support():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((1, 64)).astype(np.float32))
    probs = np.asarray(jax.nn.softmax(logits[0]))
    order = np.argsort(probs)[::-1]
    nucleus = set(order[:np.searchsorted(np.cumsum(probs[order]), 0.5) + 1])
    for k in range(40):
        tok = int(sample_tokens(jax.random.PRNGKey(k), logits,
                                jnp.ones(1), jnp.zeros(1, jnp.int32),
                                jnp.asarray([0.5], jnp.float32))[0])
        assert tok in nucleus, (tok, nucleus)


def test_sample_tokens_top_p_one_keeps_full_support():
    """top_p=1.0 must not truncate: uniform logits stay explorable."""
    logits = jnp.zeros((1, 64))
    draws = {int(sample_tokens(jax.random.PRNGKey(k), logits, jnp.ones(1),
                               jnp.zeros(1, jnp.int32),
                               jnp.ones(1, jnp.float32))[0])
             for k in range(30)}
    assert len(draws) > 5


def test_sample_tokens_top_p_composes_with_top_k():
    """With both active the tighter truncation wins per lane."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    top3_row1 = set(np.asarray(jnp.argsort(logits[1])[::-1][:3]))
    for k in range(30):
        out = sample_tokens(jax.random.PRNGKey(k), logits,
                            jnp.asarray([0.0, 2.0]),
                            jnp.asarray([0, 3], jnp.int32),
                            jnp.asarray([0.9, 0.99], jnp.float32))
        # lane 0 greedy regardless of truncation params
        assert int(out[0]) == int(jnp.argmax(logits[0]))
        assert int(out[1]) in top3_row1


def test_sample_tokens_vocab_wide_top_k_lane_does_not_untruncate_others():
    """One lane asking for top_k >= vocab must not disable another
    lane's truncation (the batch-max k is clamped, not zeroed)."""
    rng = np.random.default_rng(4)
    v = 16
    logits = jnp.asarray(rng.standard_normal((2, v)).astype(np.float32))
    top3 = set(np.asarray(jnp.argsort(logits[0])[::-1][:3]))
    for k in range(30):
        out = sample_tokens(jax.random.PRNGKey(k), logits,
                            jnp.asarray([2.0, 2.0]),
                            jnp.asarray([3, v], jnp.int32))
        assert int(out[0]) in top3


def test_sample_tokens_and_processed_probs_top_p_zero_is_argmax():
    """top_p <= 0 floors to greedy on BOTH the device path and the host
    mirror (no crash, no empty support)."""
    from repro.serve.sampling import processed_probs
    rng = np.random.default_rng(5)
    logits = rng.standard_normal(32).astype(np.float32)
    best = int(np.argmax(logits))
    p = processed_probs(logits, 1.0, 0, 0.0)
    assert int(np.argmax(p)) == best and p[best] == pytest.approx(1.0)
    for k in range(10):
        tok = int(sample_tokens(jax.random.PRNGKey(k),
                                jnp.asarray(logits[None, :]), jnp.ones(1),
                                jnp.zeros(1, jnp.int32),
                                jnp.zeros(1, jnp.float32))[0])
        assert tok == best


def test_sample_tokens_top_p_always_keeps_argmax():
    """Even a tiny nucleus keeps the most likely token (the exclusive-
    cumsum rule), so sampling never degenerates to an empty support."""
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.standard_normal((1, 32)).astype(np.float32))
    tok = int(sample_tokens(jax.random.PRNGKey(0), logits, jnp.ones(1),
                            jnp.zeros(1, jnp.int32),
                            jnp.asarray([1e-6], jnp.float32))[0])
    assert tok == int(jnp.argmax(logits[0]))


def test_processed_probs_matches_device_truncation():
    """The host-side mirror (speculative acceptance) must keep exactly
    the support the device sampler keeps."""
    from repro.serve.sampling import processed_probs
    rng = np.random.default_rng(3)
    logits = rng.standard_normal(64).astype(np.float32)
    for temp, top_k, top_p in [(1.0, 0, 1.0), (0.7, 5, 1.0),
                               (1.3, 0, 0.6), (0.9, 12, 0.8),
                               (0.0, 0, 1.0)]:
        p = processed_probs(logits, temp, top_k, top_p)
        assert p.shape == (64,) and abs(p.sum() - 1.0) < 1e-9
        support = set(np.nonzero(p > 0)[0])
        if temp <= 0:
            assert support == {int(np.argmax(logits))}
            continue
        draws = set()
        for k in range(200):
            tok = int(sample_tokens(
                jax.random.PRNGKey(k), jnp.asarray(logits[None, :]),
                jnp.asarray([temp]), jnp.asarray([top_k], jnp.int32),
                jnp.asarray([top_p], jnp.float32))[0])
            draws.add(tok)
            assert tok in support, (temp, top_k, top_p)
        # all mass the device explores lives inside the mirror's support
        assert draws <= support


def test_engine_temperature_sampling_end_to_end():
    model, params = _model()
    prompt = np.array([1, 2, 3], np.int32)

    def gen(seed):
        eng = PagedServeEngine(model, params, max_batch=1, max_seq=32,
                               page_size=8, seed=seed)
        r = ServeRequest(prompt=prompt, max_new_tokens=12, rid=0,
                         sampling=SamplingParams(temperature=1.5,
                                                 top_k=40))
        eng.run([r])
        return tuple(r.out_tokens)

    assert gen(0) == gen(0), "same engine seed -> same stream"
    outs = {gen(s) for s in range(4)}
    assert len(outs) > 1, "different seeds explore"


def test_deadline_rejection_and_streaming_callback():
    model, params = _model()
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    eng = PagedServeEngine(model, params, max_batch=1, max_seq=32,
                           page_size=8, clock=clock)
    got = []
    ok = ServeRequest(prompt=np.array([1, 2], np.int32), max_new_tokens=3,
                      rid=0, on_token=lambda rid, tok: got.append(tok))
    late = ServeRequest(prompt=np.array([3, 4], np.int32),
                        max_new_tokens=3, rid=1, deadline_s=1e-3,
                        priority=1)
    eng.run([ok, late])
    assert ok.done and got == ok.out_tokens, "streaming callback fires"
    assert late.rejected and late.out_tokens == []
