"""Serving engine: batching, slot refill, quantized params."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DecoderLM, ModelConfig, init_params
from repro.quant import quantize_params
from repro.serve import Request, ServeEngine


def _model():
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


def test_engine_completes_all_requests():
    model, params = _model()
    eng = ServeEngine(model, params, n_slots=2, max_seq=64)
    reqs = [Request(prompt=np.array([1, 2, 3], np.int32),
                    max_new_tokens=5, rid=i) for i in range(5)]
    done = eng.run(reqs)
    assert all(len(r.out_tokens) == 5 for r in done)
    assert all(r.done for r in done)
    assert eng.stats["tokens"] == 25


def test_engine_greedy_deterministic():
    model, params = _model()
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, params, n_slots=1, max_seq=32)
        r = eng.run([Request(prompt=np.array([1, 2], np.int32),
                             max_new_tokens=6)])[0]
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]


def test_engine_with_quantized_params():
    model, params = _model()
    qp = quantize_params(params, bits=8, group=16)
    eng = ServeEngine(model, qp, n_slots=1, max_seq=32)
    r = eng.run([Request(prompt=np.array([1, 2], np.int32),
                         max_new_tokens=4)])[0]
    assert len(r.out_tokens) == 4
