import numpy as np

from repro.data import DataConfig, SyntheticLM


def test_batches_deterministic_and_distinct():
    d = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=4))
    b1 = d.batch(0)
    b2 = d.batch(0)
    b3 = d.batch(1)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert not (b1["tokens"] == b3["tokens"]).all()


def test_labels_are_shifted_tokens():
    d = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=2))
    b = d.batch(5)
    # label t equals token t+1 (same underlying sequence)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_markov_structure_learnable():
    d = SyntheticLM(DataConfig(vocab=64, seq_len=64, global_batch=8,
                               branching=4))
    ent = d.bigram_entropy()
    assert 0 < ent < np.log(64)          # well below uniform entropy


def test_stream_resumes_at_cursor():
    d = SyntheticLM(DataConfig(vocab=64, seq_len=16, global_batch=2))
    it = d.stream(start_index=7)
    i, b = next(it)
    assert i == 7
    assert (b["tokens"] == d.batch(7)["tokens"]).all()
