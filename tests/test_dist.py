"""Sharding rules + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import (MULTI_POD_RULES, SINGLE_POD_RULES, sanitize_pspec,
                        compress_decompress_roundtrip)
from repro.dist.compress import _dq8, _q8, init_error_state


def test_rules_map_logical_axes():
    assert SINGLE_POD_RULES.pspec(("fsdp", "tp")) == P("data", "model")
    assert MULTI_POD_RULES.pspec(("batch", None, "tp")) == \
        P(("pod", "data"), None, "model")


def test_sanitize_drops_nondividing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    out = sanitize_pspec(P("data", "model"), (86, 2048), FakeMesh())
    assert out == P(None, "model")
    out2 = sanitize_pspec(P(("data", "model"), None), (512, 3), FakeMesh())
    assert out2 == P(("data", "model"), None)


def test_error_feedback_recovers_mean():
    """Quantize-with-error-feedback: accumulated updates converge to the
    true sum (the compression bias washes out)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(128).astype(np.float32)) * 1e-3
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        gf = g + err
        q, s = _q8(gf)
        deq = _dq8(q, s)
        err = gf - deq
        total = total + deq
    np.testing.assert_allclose(np.asarray(total), np.asarray(g * 50),
                               atol=float(jnp.max(jnp.abs(g))) * 0.6)
