"""Sharding rules + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import (MULTI_POD_RULES, SINGLE_POD_RULES, sanitize_pspec,
                        compress_decompress_roundtrip)
from repro.dist.compress import _dq8, _q8, init_error_state


def test_rules_map_logical_axes():
    assert SINGLE_POD_RULES.pspec(("fsdp", "tp")) == P("data", "model")
    assert MULTI_POD_RULES.pspec(("batch", None, "tp")) == \
        P(("pod", "data"), None, "model")


def test_sanitize_drops_nondividing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    out = sanitize_pspec(P("data", "model"), (86, 2048), FakeMesh())
    assert out == P(None, "model")
    out2 = sanitize_pspec(P(("data", "model"), None), (512, 3), FakeMesh())
    assert out2 == P(("data", "model"), None)


def test_qtree_shardings_one_pspec_across_packed_leaves():
    """A QTensor's data and scales must carry the SAME pspec, computed
    against every materialization of the weight: int4 packing halves
    the quant axis and grouping shrinks it to K/group, so sanitizing
    each leaf independently against the dense axes can shard the data
    while replicating (or raggedly splitting) its scales — silently
    misaligning the per-group dequant."""
    from repro.dist import SERVE_RULES, qtree_shardings
    from repro.models.common import ParamSpec
    from repro.quant.qarray import quantize

    mesh = jax.make_mesh((2,), ("model",))
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)

    # quant axis sharded, fine-grained groups: 32/2 dense rows, 16/2
    # packed rows, 2/2 scale groups — every materialization divides,
    # so dim 0 shards and BOTH fields carry the same spec
    spec = {"w": ParamSpec(shape=(32, 48), axes=("tp", None))}
    q_ok = {"w": quantize(w, bits=4, group=16, axis=0)}
    sh = qtree_shardings(spec, q_ok, mesh, SERVE_RULES)
    assert sh["w"].data.spec == P("model", None)
    assert sh["w"].scales.spec == sh["w"].data.spec

    # group == K: ONE scale group on the quant axis; the dense dim (32)
    # and the packed dim (16) divide but the scales dim (1) does not —
    # the whole dim must fall back to replicated on BOTH fields, never
    # shard the data away from its scales
    q_coarse = {"w": quantize(w, bits=4, group=32, axis=0)}
    sh = qtree_shardings(spec, q_coarse, mesh, SERVE_RULES)
    assert sh["w"].data.spec == P(None, None)
    assert sh["w"].scales.spec == sh["w"].data.spec

    # sharding the non-quantized output axis is orthogonal: dim 1 is 48
    # in all three shapes, so it shards on both fields
    spec_n = {"w": ParamSpec(shape=(32, 48), axes=(None, "tp"))}
    sh = qtree_shardings(spec_n, q_ok, mesh, SERVE_RULES)
    assert sh["w"].data.spec == P(None, "model")
    assert sh["w"].scales.spec == sh["w"].data.spec

    # dense leaves keep the plain tree_shardings path
    spec_d = {"w": ParamSpec(shape=(32, 48), axes=("tp", None))}
    sh = qtree_shardings(spec_d, {"w": w}, mesh, SERVE_RULES)
    assert sh["w"].spec == P("model", None)


def test_error_feedback_recovers_mean():
    """Quantize-with-error-feedback: accumulated updates converge to the
    true sum (the compression bias washes out)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(128).astype(np.float32)) * 1e-3
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        gf = g + err
        q, s = _q8(gf)
        deq = _dq8(q, s)
        err = gf - deq
        total = total + deq
    np.testing.assert_allclose(np.asarray(total), np.asarray(g * 50),
                               atol=float(jnp.max(jnp.abs(g))) * 0.6)
