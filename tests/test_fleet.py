"""Fleet routing layer: dispatch policies (unit + e2e), prefix-affinity
KV reuse across replicas, drain/requeue with zero loss and exact page
conservation, dead-replica eviction with partial-fleet /metrics and
/healthz, fleet-level load shedding, and 2-replica SSE byte-identity
with the offline engine."""
import asyncio
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Gateway, iter_sse
from repro.fleet import (FleetRouter, LeastLoadedPolicy,
                         PrefixAffinityPolicy, RoundRobinPolicy,
                         make_policy)
from repro.models import DecoderLM, ModelConfig, init_params
from repro.serve import PagedServeEngine, ServeRequest
from repro.serve.prefix import combine_hash, prompt_page_hashes, ROOT_HASH


def _model():
    cfg = ModelConfig(name="s", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


@pytest.fixture(scope="module")
def model_params():
    return _model()


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    return PagedServeEngine(model, params, **kw)


async def _raw_post(host, port, payload: bytes, path="/v1/completions"):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"POST {path} HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n"
                  ).encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data


async def _post(host, port, body: dict):
    return await _raw_post(host, port, json.dumps(body).encode())


async def _get(host, port, path):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data


def _status(raw: bytes) -> int:
    return int(raw.split(b"\r\n", 1)[0].split()[1])


def _body(raw: bytes) -> bytes:
    return raw.partition(b"\r\n\r\n")[2]


def _stream_tokens(raw: bytes):
    toks, fins = {}, {}
    for e in iter_sse(_body(raw)):
        if "token" in e:
            toks.setdefault(e["index"], []).append(e["token"])
        elif "finish_reason" in e:
            fins[e["index"]] = e["finish_reason"]
    return toks, fins


# ----------------------------------------------------------------------------
# policy units (no engines: replicas are stand-ins)
# ----------------------------------------------------------------------------
class _FakeReplica:
    def __init__(self, rid, depth=0.0, occ=0.0, fingerprint=()):
        self.id = rid
        self.page_size = 8
        self._depth = depth
        self._occ = occ
        self.fingerprint = frozenset(fingerprint)

    def depth(self):
        return self._depth

    def occupancy(self):
        return self._occ


def test_round_robin_cycles_replica_ids_not_candidate_slots():
    a, b, c = (_FakeReplica(i) for i in range(3))
    pol = RoundRobinPolicy()
    assert [pol.pick([a, b, c], None).id for _ in range(4)] == [0, 1, 2, 0]
    # replica 1 drops out (dead / saturated): the cycle skips it without
    # re-dealing the others
    assert [pol.pick([a, c], None).id for _ in range(3)] == [2, 0, 2]


def test_least_loaded_prefers_depth_then_occupancy():
    pol = LeastLoadedPolicy()
    a = _FakeReplica(0, depth=2.0, occ=0.1)
    b = _FakeReplica(1, depth=1.0, occ=0.9)
    c = _FakeReplica(2, depth=1.0, occ=0.2)
    assert pol.pick([a, b, c], None).id == 2


def test_prefix_affinity_scores_consecutive_pages_from_root():
    prompt = np.arange(24, dtype=np.int32)
    hashes = prompt_page_hashes(prompt, 8)
    assert len(hashes) == 2         # (24 - 1) // 8 full pages usable
    h0 = combine_hash(ROOT_HASH, tuple(int(t) for t in prompt[:8]))
    assert hashes[0] == h0
    holder = _FakeReplica(0, depth=5.0, fingerprint=hashes)
    cold = _FakeReplica(1, depth=0.0)
    gapped = _FakeReplica(2, depth=0.0, fingerprint=hashes[1:])
    pol = PrefixAffinityPolicy()
    # a fingerprint match beats a big load gap; a gap at the root scores
    # zero (KV rows depend on the whole causal prefix)
    assert pol.score(holder, hashes) == 2
    assert pol.score(gapped, hashes) == 0
    assert pol.pick([holder, cold, gapped], prompt) is holder
    assert (pol.hits, pol.misses) == (1, 0)
    # nobody holds anything: falls back to least-loaded
    assert pol.pick([_FakeReplica(0, depth=3.0), cold], prompt) is cold
    assert (pol.hits, pol.misses) == (1, 1)


def test_make_policy_names():
    assert isinstance(make_policy("rr"), RoundRobinPolicy)
    assert isinstance(make_policy("least-loaded"), LeastLoadedPolicy)
    assert isinstance(make_policy("prefix"), PrefixAffinityPolicy)
    pol = PrefixAffinityPolicy()
    assert make_policy(pol) is pol
    with pytest.raises(ValueError):
        make_policy("nope")


# ----------------------------------------------------------------------------
# prefix affinity e2e: a repeated prompt routes to the replica that
# holds its committed pages and skips their prefill
# ----------------------------------------------------------------------------
def test_fleet_prefix_affinity_routes_repeat_to_holder(model_params):
    model, params = model_params
    prompt = list(range(1, 13))     # 12 tokens: 1 committable page of 8

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="prefix", max_pending=8)
        gw = Gateway(router)
        host, port = await gw.start()
        try:
            first = await _post(host, port, {"prompt": prompt,
                                             "max_tokens": 4})
            holder = max(router.replicas, key=lambda r: r.dispatches)
            # the driver tap republishes the fingerprint after the trie
            # commit; completion ordering guarantees it already ran, but
            # poll a moment for the attribute swap to be visible here
            for _ in range(100):
                if holder.fingerprint:
                    break
                await asyncio.sleep(0.01)
            second = await _post(host, port, {"prompt": prompt,
                                              "max_tokens": 4})
            third = await _post(host, port, {"prompt": prompt,
                                             "max_tokens": 4})
            m = json.loads(_body(await _get(host, port, "/metrics")))
        finally:
            await gw.stop()
        stats = (router.policy.hits, router.policy.misses,
                 holder.dispatches, holder.id)
        return first, second, third, m, stats

    first, second, third, m, stats = asyncio.run(run())
    hits, misses, holder_dispatches, holder_id = stats
    for raw in (first, second, third):
        assert _status(raw) == 200
    assert misses >= 1, "cold fleet: the first dispatch is a miss"
    assert hits >= 2, "repeats must route by fingerprint match"
    assert holder_dispatches == 3, \
        "every repeat must land on the replica holding the prefix"
    # the holder's engine reused the committed page: repeats skipped
    # 8-token page prefills that round-robin would have re-run cold
    eng = m["fleet"]["replicas"][str(holder_id)]["engine"]
    assert eng["prefix_hits"] >= 2
    assert eng["prefill_tokens_skipped"] >= 16
    assert m["fleet"]["affinity_hits"] == hits
    assert m["engine"]["prefix_hit_rate"] > 0
    # identical sampling state per replica => identical greedy streams
    assert _stream_tokens(first)[0] == _stream_tokens(second)[0]


# ----------------------------------------------------------------------------
# drain: not-yet-started requests re-home with zero loss / duplication
# ----------------------------------------------------------------------------
def test_fleet_drain_requeues_without_loss_or_leaks(model_params):
    model, params = model_params
    prompts = [np.array([1, 2, 3], np.int32),
               np.array([5, 6, 7, 8], np.int32),
               np.array([9, 10, 11], np.int32)]

    offline = _engine(model, params)
    ref_reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=8, rid=i)
                for i, p in enumerate(prompts)]
    offline.run(ref_reqs)
    ref = [r.out_tokens for r in ref_reqs]

    async def run():
        # max_batch=1: one lane per replica, so two of the three groups
        # pin replica 0's scheduler queue until the drain re-homes them
        router = FleetRouter(
            [_engine(model, params, max_batch=1) for _ in range(2)],
            policy="least-loaded", max_pending=8).start()
        rep0, rep1 = router.replicas
        done, done_evt = [], threading.Event()

        def on_done(req):           # driver thread
            done.append(req)
            router.release(req)
            if len(done) == 3:
                done_evt.set()

        try:
            reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=8,
                                 rid=i) for i, p in enumerate(prompts)]
            for r in reqs:          # all three forced onto replica 0
                await asyncio.wrap_future(
                    router.dispatch(rep0, [r], on_done))
            for _ in range(200):    # one admitted, two queued
                state = await asyncio.wrap_future(rep0.driver.call(
                    lambda e: (e.n_running, e.scheduler.n_queued)))
                if state == (1, 2):
                    break
                await asyncio.sleep(0.01)
            assert state == (1, 2)
            requeued = await router.drain(0)
            assert not rep0.live and rep0.alive, \
                "draining replica serves its tail but takes no new work"
            assert router.route(prompts[0], 1) is rep1, \
                "routing must exclude the draining replica"
            await asyncio.get_running_loop().run_in_executor(
                None, done_evt.wait, 30)
            # conservation per replica: every page free or reclaimable,
            # no lane still occupied, and no double-counted request
            audit = []
            for rep in (rep0, rep1):
                audit.append(await asyncio.wrap_future(rep.driver.call(
                    lambda e: (e.cache.n_free_or_cached(),
                               e.cache.allocator.n_pages, e.n_running,
                               e.scheduler.n_queued,
                               e.telemetry.requests_total))))
        finally:
            router.stop()
        return reqs, done, requeued, audit, dict(router.counters), \
            (rep0.pending, rep1.pending)

    reqs, done, requeued, audit, counters, pending = asyncio.run(run())
    assert requeued == 2 and counters["requeued"] == 2
    assert counters["requeue_failed"] == 0
    assert len(done) == 3, "every request finishes exactly once"
    assert len({id(r) for r in done}) == 3, "no duplicated completion"
    for r, want in zip(reqs, ref):
        assert not r.cancelled and not r.rejected
        assert r.out_tokens == want, \
            "a re-homed request must decode exactly as offline"
    for free_or_cached, n_pages, running, queued, total in audit:
        assert (running, queued) == (0, 0)
        assert free_or_cached == n_pages, "drain leaked KV pages"
    assert [a[4] for a in audit] == [1.0, 2.0], \
        "requeue must not double-count requests_total across replicas"
    assert pending == (0, 0), "admission ledger must return to zero"


# ----------------------------------------------------------------------------
# add_replica: scale OUT under load with zero loss (inverse of drain)
# ----------------------------------------------------------------------------
def test_fleet_add_replica_under_load_zero_loss(model_params):
    model, params = model_params
    prompts = [np.array([1, 2, 3], np.int32),
               np.array([5, 6, 7, 8], np.int32),
               np.array([9, 10, 11], np.int32),
               np.array([13, 14], np.int32)]

    offline = _engine(model, params)
    ref_reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=8, rid=i)
                for i, p in enumerate(prompts)]
    offline.run(ref_reqs)
    ref = [r.out_tokens for r in ref_reqs]

    async def run():
        # one single-lane replica, saturated: first group runs, second
        # pins its scheduler queue — the fleet is under load when the
        # new replica joins
        router = FleetRouter([_engine(model, params, max_batch=1)],
                             policy="least-loaded", max_pending=8).start()
        rep0 = router.replicas[0]
        done, done_evt = [], threading.Event()

        def on_done(req):           # driver thread
            done.append(req)
            router.release(req)
            if len(done) == len(prompts):
                done_evt.set()

        try:
            reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=8,
                                 rid=i) for i, p in enumerate(prompts)]
            for r in reqs[:2]:
                await asyncio.wrap_future(
                    router.dispatch(rep0, [r], on_done))
            for _ in range(200):    # one admitted, one queued
                state = await asyncio.wrap_future(rep0.driver.call(
                    lambda e: (e.n_running, e.scheduler.n_queued)))
                if state == (1, 1):
                    break
                await asyncio.sleep(0.01)
            assert state == (1, 1)

            # replicas share params read-only: the new engine costs a KV
            # pool + a driver thread, not a second copy of the weights
            rep1 = router.add_replica(_engine(model, params, max_batch=1))
            assert rep1.id == 1 and rep1.live and rep1.driver.alive
            assert router.route(prompts[2], 1) is rep1, \
                "least-loaded must route fresh work to the empty newcomer"
            for r in reqs[2:]:
                rep = router.route(r.prompt, 1)
                await asyncio.wrap_future(
                    router.dispatch(rep, [r], on_done))
            await asyncio.get_running_loop().run_in_executor(
                None, done_evt.wait, 30)
            audit = []
            for rep in router.replicas:
                audit.append(await asyncio.wrap_future(rep.driver.call(
                    lambda e: (e.cache.n_free_or_cached(),
                               e.cache.allocator.n_pages,
                               e.n_running, e.scheduler.n_queued))))
        finally:
            router.stop()
        return reqs, done, audit, dict(router.counters), \
            [rep.dispatches for rep in router.replicas], \
            [rep.pending for rep in router.replicas]

    reqs, done, audit, counters, dispatches, pending = asyncio.run(run())
    assert counters["adds"] == 1
    assert len(done) == len(prompts), "every request finishes exactly once"
    assert len({id(r) for r in done}) == len(prompts), \
        "no duplicated completion"
    assert dispatches[1] >= 1, "the added replica must absorb load"
    for r, want in zip(reqs, ref):
        assert not r.cancelled and not r.rejected and not r.truncated
        assert r.out_tokens == want, \
            "a request served through the grown fleet must decode " \
            "exactly as offline"
    for free_or_cached, n_pages, running, queued in audit:
        assert (running, queued) == (0, 0)
        assert free_or_cached == n_pages, "scale-out leaked KV pages"
    assert pending == [0, 0], "admission ledger must return to zero"
    # guard rail: a replica serving a DIFFERENT model is refused
    cfg2 = ModelConfig(name="other", family="dense", n_layers=1,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab=64, head_dim=16, dtype="float32",
                       remat=False)
    other = DecoderLM(cfg2)
    oparams = init_params(other.param_specs(), jax.random.PRNGKey(2),
                          dtype_override=jnp.float32)
    router2 = FleetRouter([_engine(model, params)])
    with pytest.raises(AssertionError, match="same model"):
        router2.add_replica(_engine(other, oparams))


# ----------------------------------------------------------------------------
# replica death: evicted from rotation, partial-fleet metrics/healthz
# ----------------------------------------------------------------------------
def test_fleet_dead_replica_evicted_and_partial_metrics(model_params):
    model, params = model_params

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="rr", max_pending=8)
        gw = Gateway(router)
        host, port = await gw.start()
        try:
            router.replicas[0].driver.stop()    # replica 0 gone
            health = await _get(host, port, "/healthz")
            raws = [await _post(host, port, {"prompt": [1, 2, 3],
                                             "max_tokens": 3})
                    for _ in range(3)]
            m = json.loads(_body(await _get(host, port, "/metrics")))
            router.replicas[1].driver.stop()    # whole fleet down
            dead_health = await _get(host, port, "/healthz")
            dead_post = await _post(host, port, {"prompt": [1, 2],
                                                 "max_tokens": 2})
            dead_m = json.loads(_body(await _get(host, port, "/metrics")))
        finally:
            await gw.stop()
        return health, raws, m, dead_health, dead_post, dead_m, \
            router.replicas[1].dispatches

    health, raws, m, dead_health, dead_post, dead_m, surv = \
        asyncio.run(run())
    assert _status(health) == 200, "one live replica keeps /healthz green"
    assert json.loads(_body(health))["n_live"] == 1
    for raw in raws:
        assert _status(raw) == 200, "survivor must absorb all traffic"
    assert surv == 3
    # partial fleet: aggregate covers the survivor, the dead replica is
    # reported (not KeyError'd), and its absence doesn't nan the rollup
    assert m["fleet"]["n_live"] == 1
    assert m["fleet"]["replicas"]["0"]["alive"] is False
    assert "engine" not in m["fleet"]["replicas"]["0"]
    assert m["fleet"]["replicas"]["1"]["alive"] is True
    assert m["engine"]["requests"] == 3.0
    assert m["gateway"]["accepted_samples"] == 3
    # whole fleet down: honest 503s and a metrics payload that still
    # renders (engine=None + error, never a traceback)
    assert _status(dead_health) == 503
    assert _status(dead_post) == 503
    assert dead_m["engine"] is None and "error" in dead_m


# ----------------------------------------------------------------------------
# fleet-level shedding: 429 only when EVERY live replica is saturated
# ----------------------------------------------------------------------------
def test_fleet_429_only_when_all_replicas_saturated(model_params):
    model, params = model_params

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="least-loaded", max_pending=1)
        gw = Gateway(router)
        host, port = await gw.start()
        gates = [threading.Event(), threading.Event()]
        try:
            for rep, gate in zip(router.replicas, gates):
                rep.driver.call(lambda e, g=gate: g.wait(30))
            first = asyncio.ensure_future(
                _post(host, port, {"prompt": [1, 2], "max_tokens": 2}))
            second = asyncio.ensure_future(
                _post(host, port, {"prompt": [3, 4], "max_tokens": 2}))
            for _ in range(200):    # both replicas now hold one sample
                if gw.counters["accepted_samples"] == 2:
                    break
                await asyncio.sleep(0.01)
            third = await _post(host, port, {"prompt": [5, 6],
                                             "max_tokens": 2})
            for g in gates:
                g.set()
            first_raw, second_raw = await first, await second
        finally:
            for g in gates:
                g.set()
            await gw.stop()
        return first_raw, second_raw, third, dict(gw.counters)

    first_raw, second_raw, third, counters = asyncio.run(run())
    assert _status(first_raw) == 200 and _status(second_raw) == 200, \
        "one saturated replica must NOT shed while the other has room"
    assert _status(third) == 429, "both saturated: fleet-level shed"
    assert b"retry-after" in third.lower()
    assert counters["rejected_429"] == 1


# ----------------------------------------------------------------------------
# acceptance: greedy SSE through a 2-replica fleet is byte-identical to
# the single-engine (offline) runtime
# ----------------------------------------------------------------------------
def test_fleet_sse_greedy_byte_identical_to_offline(model_params):
    model, params = model_params
    prompts = [np.array([1, 2, 3], np.int32),
               np.array([5, 6, 7, 8, 9, 10, 11], np.int32),
               np.array([40, 2, 9, 9], np.int32),
               np.array([17, 3], np.int32)]

    offline = _engine(model, params)
    reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=6, rid=i)
            for i, p in enumerate(prompts)]
    offline.run(reqs)
    ref = [r.out_tokens for r in reqs]

    async def run():
        router = FleetRouter([_engine(model, params) for _ in range(2)],
                             policy="rr", max_pending=16)
        gw = Gateway(router)
        host, port = await gw.start()
        try:
            raws = await asyncio.gather(*[
                _post(host, port,
                      {"prompt": [int(t) for t in p], "max_tokens": 6})
                for p in prompts])
        finally:
            await gw.stop()
        return raws, [rep.dispatches for rep in router.replicas]

    raws, dispatches = asyncio.run(run())
    assert dispatches == [2, 2], "rr must spread the groups evenly"
    for raw, want in zip(raws, ref):
        assert _status(raw) == 200
        toks, fins = _stream_tokens(raw)
        assert toks[0] == want, "fleet stream diverged from offline"
        assert fins[0] == "length"
