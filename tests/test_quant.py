"""Quantization substrate: packing, error bounds, struct builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ParamSpec
from repro.quant import (QTensor, dequantize, quantize, quantize_params,
                         quantize_structs, unpack_int4)
from repro.quant.qarray import dequant_rows


@pytest.mark.parametrize("bits,group", [(4, 32), (4, 128), (8, 64),
                                        (8, 128)])
@pytest.mark.parametrize("shape,axis", [((256, 64), 0), ((4, 256, 64), 1),
                                        ((128, 256), 1)])
def test_roundtrip_error_bounded(bits, group, shape, axis):
    """|w - deq(q(w))| <= scale/2 elementwise (symmetric rounding)."""
    w = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    qt = quantize(w, bits=bits, group=group, axis=axis)
    deq = dequantize(qt, jnp.float32)
    qmax = 7.0 if bits == 4 else 127.0
    # reconstruct per-element scale bound
    K = shape[axis]
    g = min(group, K)
    wm = jnp.moveaxis(w, axis, 0).reshape(K // g, g, -1)
    scale = jnp.max(jnp.abs(wm), axis=1, keepdims=True) / qmax
    bound = jnp.broadcast_to(scale, wm.shape).reshape(K, -1)
    err = jnp.abs(jnp.moveaxis(deq - w, axis, 0).reshape(K, -1))
    # 0.5 rounding + f16 scale storage error (qmax * 2^-11)
    qmax_ = 7.0 if bits == 4 else 127.0
    assert bool(jnp.all(err <= (0.51 + qmax_ * 2**-11) * bound + 1e-6))


def test_pack_unpack_int4_identity():
    q = jnp.arange(-8, 8, dtype=jnp.int8).reshape(16, 1)
    w = q.astype(jnp.float32) / 7.0
    qt = quantize(w, bits=4, group=16)
    assert qt.data.dtype == jnp.uint8 and qt.data.shape == (8, 1)
    assert bool(jnp.all(jnp.abs(dequantize(qt, jnp.float32) - w) < 0.2))


def test_dequant_rows_matches_full_dequant():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    qt = quantize(w, bits=4, group=32, axis=1)
    ids = jnp.array([0, 5, 63, 5])
    rows = dequant_rows(qt, ids, jnp.float32)
    full = dequantize(qt, jnp.float32)
    assert float(jnp.max(jnp.abs(rows - full[ids]))) < 1e-6


def test_quantize_structs_matches_quantize_params_shapes():
    spec = {"wq": ParamSpec((256, 128), axes=(None, None)),
            "embed": ParamSpec((64, 256), axes=(None, None)),
            "norm": ParamSpec((128,), axes=(None,), init="ones")}
    structs = quantize_structs(spec, bits=4, group=64)
    import jax.random as jr
    from repro.models.common import init_params
    params = init_params(spec, jr.PRNGKey(0))
    qp = quantize_params(params, bits=4, group=64)
    for k in ("wq", "embed"):
        assert isinstance(structs[k], QTensor) and isinstance(qp[k], QTensor)
        assert structs[k].data.shape == qp[k].data.shape, k
        assert structs[k].scales.shape == qp[k].scales.shape, k
        assert structs[k].axis == qp[k].axis, k
    assert not isinstance(structs["norm"], QTensor)


def test_qtensor_survives_scan_slicing():
    """Stacked-layer QTensors slice correctly inside lax.scan."""
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 32), jnp.float32)
    qt = quantize(w, bits=4, group=32, axis=1)
    x = jnp.ones((1, 64), jnp.float32)

    def body(carry, q_layer):
        return carry + (x @ dequantize(q_layer, jnp.float32)).sum(), None

    total, _ = jax.lax.scan(body, 0.0, qt)
    expect = sum(float((x @ dequantize(quantize(w[i], 4, 32, 0),
                                       jnp.float32)).sum())
                 for i in range(3))
    assert abs(float(total) - expect) < 1e-2
