"""Trainer: loss decreases, exact resume, preemption, straggler events."""
import os
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.models import DecoderLM, ModelConfig, init_params
from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule


def _setup(steps, ckpt_dir=None, **kw):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4))
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    tc = TrainConfig(steps=steps, log_every=5, ckpt_every=10,
                     ckpt_dir=ckpt_dir, async_checkpoint=False, **kw)
    return Trainer(model, opt, data, tc)


def test_loss_decreases():
    out = _setup(40).run()
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


def test_resume_is_bit_identical(tmp_path):
    d = str(tmp_path / "ck")
    full = _setup(20, ckpt_dir=None).run()          # uninterrupted
    first = _setup(10, ckpt_dir=d).run()            # stop at 10 (ckpt)
    second = _setup(20, ckpt_dir=d).run(resume=True)
    assert second["step"] == 20
    combined = first["losses"] + second["losses"]
    np.testing.assert_allclose(combined, full["losses"], rtol=1e-6)


def test_preemption_checkpoints_and_stops(tmp_path):
    flag = str(tmp_path / "PREEMPT")
    d = str(tmp_path / "ck")
    open(flag, "w").write("1")
    tr = _setup(50, ckpt_dir=d, preempt_flag=flag)
    out = tr.run()
    assert out["step"] < 50
    kinds = [e.kind for e in tr.events]
    assert "PREEMPT" in kinds and "CKPT" in kinds


def test_straggler_event_detection():
    tr = _setup(10)
    # simulate: 9 fast steps, one 10x step
    for dt in [0.1] * 9:
        tr._check_straggler(dt, 0)
    tr._check_straggler(1.0, 9)
    assert any(e.kind == "STRAGGLER" for e in tr.events)


def test_grad_accumulation_equivalent_direction():
    """microbatches=2 over split batch ~ single step over full batch."""
    tr1 = _setup(1)
    tr2 = _setup(1, microbatches=2)
    o1 = tr1.run()
    o2 = tr2.run()
    assert np.isfinite(o1["losses"][0]) and np.isfinite(o2["losses"][0])
