"""Fig. 8: decoding energy-latency product vs (prefill, generated) tokens
for LLaMA3.2-3B INT8 at the alpha=0.5 optimal h*."""
import time

import numpy as np

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import EdgeCIMSimulator, run_dse


def run(csv=print):
    t0 = time.perf_counter()
    spec = PAPER_SLMS["llama3.2-3b"]
    res = run_dse(spec, alpha=0.5, w_bits=8, a_bits=8, seed=0)
    h = res.best
    sim = EdgeCIMSimulator()
    grid = {}
    for pre in (64, 128, 256, 512, 1024):
        for gen in (32, 64, 128, 256):
            rep = sim.generate(spec, h, pre, gen, 8, 8)
            grid[f"{pre}x{gen}"] = {"edp": rep.edp,
                                    "latency_s": rep.latency_s,
                                    "energy_j": rep.energy_j}
    # trends: EDP grows fast in gen, slower in prefill (paper's finding)
    gen_ratio = grid["128x256"]["edp"] / grid["128x64"]["edp"]
    pre_ratio = grid["512x128"]["edp"] / grid["128x128"]["edp"]
    us = (time.perf_counter() - t0) * 1e6
    csv(f"fig8_token_scaling,{us:.2f},"
        f"edp_gen_4x={gen_ratio:.1f};edp_prefill_4x={pre_ratio:.2f}")
    return {"h_star": str(h), "grid": grid,
            "gen_scaling_4x": gen_ratio, "prefill_scaling_4x": pre_ratio}
