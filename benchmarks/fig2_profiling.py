"""Fig. 2: decode dominates end-to-end SLM inference on edge GPUs.

The paper profiles LLaMA3.2-1B on Jetson Orin: 96.6% of time in decode
(avg over I=64..1024, O<=512, batch 1).  We reproduce with a roofline
model of the Orin GPU (prefill compute-bound at peak TOPS, decode
bandwidth-bound at memory BW) — the same first-principles argument that
motivates EdgeCIM."""
import time

import numpy as np

from repro.configs.paper_slms import PAPER_SLMS

# Jetson Orin (AGX) class: ~85 fp16 TFLOP/s effective tensor, 204.8 GB/s
ORIN_FLOPS = 85e12
ORIN_BW = 204.8e9
ORIN_EFF = 0.6          # sustained fraction


def run(csv=print):
    t0 = time.perf_counter()
    spec = PAPER_SLMS["llama3.2-1b"]
    n = spec.active_params_per_token()
    rows = []
    for I in (64, 128, 256, 512, 1024):
        for O in (64, 128, 256, 512):
            t_prefill = 2 * n * I / (ORIN_FLOPS * ORIN_EFF)
            t_decode = O * (n * 2.0) / (ORIN_BW * ORIN_EFF)   # fp16 weights
            frac = t_decode / (t_decode + t_prefill)
            rows.append({"I": I, "O": O, "decode_frac": frac})
    avg = float(np.mean([r["decode_frac"] for r in rows]))
    us = (time.perf_counter() - t0) * 1e6
    csv(f"fig2_decode_fraction,{us:.2f},avg={avg:.3f};paper=0.966")
    return {"rows": rows, "avg_decode_fraction": avg, "paper_claim": 0.966}
