"""Open-loop load benchmark for the streaming gateway (`repro.api`),
with a data-parallel fleet axis (`repro.fleet`).

Closed-loop benchmarks (serve_bench) measure the engine at its own
pace; real edge traffic does not wait its turn.  This generator fires
requests at the gateway with POISSON arrivals at a configured rate —
open loop: a slow server does NOT slow the arrival process, so queueing
delay shows up in the tail where it belongs (the coordinated-omission
trap closed-loop generators fall into).

Per (replicas, policy, rate) cell it reports the streaming client's
actual experience over real HTTP + SSE: TTFT and inter-token-latency
percentiles (measured from intended arrival, so scheduler queue time
counts), goodput, how many requests were shed as 429s by the fleet's
admission budget, and — for the fleet — the engine-level prefix hit
rate plus the router's affinity hit counters.

Two workloads:
  uniform        pairwise-independent random prompts (the scaling
                 story: goodput vs replica count at fixed offered load)
  shared-prefix  two waves; wave 2 repeats wave 1's prompts after one
                 parity-flip unique prompt, so deterministic rr
                 alternation lands every repeat on the OPPOSITE replica
                 (engine prefix hit rate ~0) while prefix-affinity
                 routes it to the holder of its committed KV pages
                 (hit rate > 0, prefill skipped).  Repeats are asserted
                 token-identical to their originals (greedy).

  PYTHONPATH=src python benchmarks/api_bench.py --scale 32 --tokens 8 \
      --requests 12 --rates 8 32 --replicas 1 2 --policies least-loaded
"""
import argparse
import asyncio
import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import save_json  # noqa: E402
from serve_bench import build_model, warm_engine  # noqa: E402

from repro.api import Gateway  # noqa: E402
from repro.api.protocol import DONE_SENTINEL  # noqa: E402
from repro.fleet import FleetRouter  # noqa: E402
from repro.quant.qarray import (dequant_counters,  # noqa: E402
                                reset_dequant_counters)
from repro.serve import (PagedServeEngine, SamplingParams,  # noqa: E402
                         ServeConfig, ServeRequest)

QUANT_GROUP = 32        # bench models are narrow; 128 wouldn't divide


def _serve_config(precision, *, batch, max_seq, page_size, max_pending,
                  policy, replicas, kv_dtype="auto",
                  tp=1) -> ServeConfig:
    return ServeConfig(
        precision=precision or "fp", kv_dtype=kv_dtype,
        quant_group=QUANT_GROUP, max_batch=batch, max_seq=max_seq,
        page_size=page_size, prefill_chunk=16, max_pending=max_pending,
        policy=policy, replicas=replicas, tp=tp)


def _kv_bytes_per_token(engine) -> float:
    """Resident KV bytes per token lane across all layers (pool bytes /
    pool token capacity) — scale pages count against the quantized
    pools, so the capacity claim is honest."""
    import jax
    total = sum(v.nbytes for v in
                jax.tree_util.tree_leaves(engine.cache.pools))
    tokens = engine.cache.allocator.n_pages * engine.cache.page_size
    return total / tokens if tokens else 0.0


def quality_probe(model, params_fp, params_q, base_cfg: ServeConfig,
                  *, tokens: int = 24, seed: int = 7) -> dict:
    """Quantization quality vs the fp stack on a fixed probe prompt:

      quality_logit_mse        MSE of the full-sequence forward logits
      quality_greedy_match_len length of the common greedy prefix
                               (engine serve path, temperature 0)
      quality_greedy_tokens    probe length (match_len's denominator)
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, model.cfg.vocab, 12).astype(np.int32)
    lf = model.forward(params_fp, {"tokens": jnp.asarray(prompt[None])})
    lq = model.forward(params_q, {"tokens": jnp.asarray(prompt[None])})
    mse = float(jnp.mean((lf.astype(jnp.float32)
                          - lq.astype(jnp.float32)) ** 2))

    def greedy(params, cfg):
        eng = PagedServeEngine(model, params, cfg)
        req = ServeRequest(prompt=prompt, max_new_tokens=tokens, rid=0,
                           sampling=SamplingParams(temperature=0.0))
        eng.run([req])
        return req.out_tokens

    fp_cfg = dataclasses.replace(base_cfg, precision="fp",
                                 kv_dtype="auto")
    tf = greedy(params_fp, fp_cfg)
    tq = greedy(params_q, base_cfg)
    match = 0
    for a, b in zip(tf, tq):
        if a != b:
            break
        match += 1
    return {"quality_logit_mse": mse,
            "quality_greedy_match_len": float(match),
            "quality_greedy_tokens": float(len(tf))}


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else float("nan")


async def _drive_one(host, port, body: dict, t_arrival: float) -> dict:
    """POST one streaming completion; parse SSE incrementally so TTFT
    and inter-token gaps are timed as bytes actually land."""
    out = {"status": 0, "ttft_s": None, "gaps": [], "tokens": 0,
           "done_s": None, "out_tokens": []}
    payload = json.dumps(body).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: bench\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n"
                      ).encode() + payload)
        await writer.drain()
        status_line = await reader.readline()
        out["status"] = int(status_line.split()[1])
        while (await reader.readline()) not in (b"\r\n", b""):
            pass                                    # drain headers
        if out["status"] != 200:
            await reader.read()
            return out
        t_last = None
        while True:
            line = await reader.readline()
            if not line:
                break
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):]
            if data.decode("utf-8", "replace") == DONE_SENTINEL:
                break
            event = json.loads(data)
            now = time.monotonic()
            if "token" in event:
                out["tokens"] += 1
                out["out_tokens"].append(event["token"])
                if out["ttft_s"] is None:
                    out["ttft_s"] = now - t_arrival
                elif t_last is not None:
                    out["gaps"].append(now - t_last)
                t_last = now
        out["done_s"] = time.monotonic() - t_arrival
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass
    return out


async def _http_get_json(host, port, path):
    """GET a JSON document from the gateway (used for /debug/trace, so
    the trace artifact exercises the real endpoint, not an in-process
    shortcut)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(f"GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n"
                     .encode())
        await writer.drain()
        status = int((await reader.readline()).split()[1])
        length = None
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b""):
                break
            key, _, val = line.decode().partition(":")
            if key.strip().lower() == "content-length":
                length = int(val)
        body = (await reader.readexactly(length) if length is not None
                else await reader.read())
        return status, json.loads(body)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


def _check_trace_correlation(doc: dict) -> None:
    """The point of the tracer is cross-layer correlation: a request id
    minted at the gateway must reappear on the router's dispatch event
    and inside the engine's decode-step spans (which ran on a different
    thread).  Assert it on the real capture."""
    events = doc["traceEvents"]
    gw_rids = {e["args"]["rid"] for e in events
               if e.get("name") == "request" and e.get("ph") == "X"}
    route_rids = {r for e in events if e.get("name") == "route_dispatch"
                  for r in e["args"].get("rids", [])}
    decode_rids = {r for e in events if e.get("name") == "decode_step"
                   for r in e["args"].get("rids", [])}
    assert gw_rids, "trace has no gateway request spans"
    shared = gw_rids & route_rids & decode_rids
    assert shared, (
        "no request id is shared across gateway/router/engine spans: "
        f"gateway={sorted(gw_rids)[:4]} router={sorted(route_rids)[:4]} "
        f"engine={sorted(decode_rids)[:4]}")


async def _fire_wave(host, port, bodies, rate, rng):
    """Open-loop Poisson wave with a coordinated-omission-safe intended
    arrival schedule fixed up front: TTFT is measured from the INTENDED
    arrival, so event-loop lateness in firing a request counts against
    the server's tail instead of silently vanishing."""
    gaps_s = rng.exponential(1.0 / rate, size=len(bodies))
    arrivals = time.monotonic() + np.cumsum(gaps_s)
    tasks = []
    for body, t_arrival in zip(bodies, arrivals):
        await asyncio.sleep(max(0.0, t_arrival - time.monotonic()))
        tasks.append(asyncio.ensure_future(
            _drive_one(host, port, body, float(t_arrival))))
    return await asyncio.gather(*tasks)


def _distinct_prompts(rng, count, length, vocab):
    seen, out = set(), []
    while len(out) < count:
        p = [int(t) for t in rng.integers(0, vocab, length)]
        if tuple(p) not in seen:        # pairwise distinct: no
            seen.add(tuple(p))          # accidental cross-prompt hits
            out.append(p)
    return out


async def run_rate(model, params, *, rate: float, n_requests: int,
                   tokens: int, n: int, batch: int, max_seq: int,
                   page_size: int, max_pending: int, prompt_lo: int,
                   prompt_hi: int, replicas: int = 1,
                   policy: str = "least-loaded",
                   shared_prefix: bool = False, seed: int = 0,
                   trace=None, precision=None, tp=None, slo=None):
    """One (replicas, policy, rate) cell.  `trace` is tri-state: None
    leaves the tracer alone and omits the `tracing` identity field
    (plain sweeps stay comparable to their committed baselines);
    True/False force the tracer on/off and label the row, so an A/B
    pair from the SAME run feeds check_bench's tracing-overhead gate.
    `precision` is tri-state the same way: None keeps the pre-quant
    row identity; "fp"/"int8"/"int4" labels the row and serves at that
    ServeConfig precision (`params` must already match — packed
    QTensors for the quantized tiers).  `tp` likewise: None keeps the
    pre-TP row identity; an int shards every engine that many ways
    (`ServeConfig.tp`) and attaches a `greedy_digest` of the completed
    token streams so check_bench's tp-identity gate can assert tp>1
    cells byte-match the tp=1 cell from the SAME run.  `slo` tri-state
    too: True serves the cell under the default SLO set with
    bench-compressed burn-rate windows (timescale 1/600) and a fast
    evaluation poll, labels the row `slo=true`, attaches alert/drift
    columns from the REAL `/debug/slo` endpoint, and returns its
    payload for the `<out>.slo.json` artifact (tools/slo_report.py).
    Returns (row, chrome_trace_doc_or_None, slo_doc_or_None)."""
    cfg = _serve_config(precision, batch=batch, max_seq=max_seq,
                        page_size=page_size, max_pending=max_pending,
                        policy=policy, replicas=replicas, tp=tp or 1)
    quantized = precision in ("int8", "int4")
    # trace-time counters: every engine jits its own step graphs, so a
    # full-weight float materialization ANYWHERE in this cell's traced
    # decode/prefill graphs would bump full_dequant
    reset_dequant_counters()
    engines = []
    for _ in range(replicas):
        eng = PagedServeEngine(model, params, cfg)
        warm_engine(eng)    # compile prefill/decode BEFORE the driver
        engines.append(eng)
    kv_bytes_per_token = _kv_bytes_per_token(engines[0])
    tracer = None
    if trace is not None:
        from repro.obs import get_tracer
        tracer = get_tracer()
        tracer.clear()
        tracer.enable() if trace else tracer.disable()
    # max_pending is PER REPLICA: the fleet's admission capacity scales
    # with the fleet, which is the scaling story being measured
    router = FleetRouter(engines, policy=policy, max_pending=max_pending)
    gw_kwargs = {}
    if slo:
        from repro.obs.slo import DEFAULT_SLOS, BurnRatePolicy
        # timescale 1/600 maps the SRE 1h page window to 6 s; the fast
        # poll gives the short windows enough evaluation ticks inside a
        # few-second smoke cell
        gw_kwargs = dict(slos=list(DEFAULT_SLOS),
                         slo_policy=BurnRatePolicy(timescale=1 / 600),
                         slo_poll_s=0.05)
    gw = Gateway(router, **gw_kwargs)
    host, port = await gw.start()
    rng = np.random.default_rng(seed)

    def body(prompt):
        return {"prompt": prompt, "max_tokens": tokens, "n": n,
                "stream": True, "temperature": 0.0}

    pairs_checked = pairs_identical = 0
    t0 = time.monotonic()
    if shared_prefix:
        # wave 1: k distinct prompts (k even keeps rr's parity flip
        # deterministic); wave 2: ONE unique prompt, then wave 1 again —
        # under rr every repeat lands on the opposite replica, under
        # prefix-affinity on the holder of its committed pages
        k = max(2, (n_requests // 2) & ~1)
        length = max(prompt_hi, 2 * page_size + page_size // 2)
        originals = _distinct_prompts(rng, k + 1, length,
                                      model.cfg.vocab)
        wave1, odd = originals[:k], originals[k]
        first = await _fire_wave(host, port, [body(p) for p in wave1],
                                 rate, rng)
        second = await _fire_wave(
            host, port, [body(odd)] + [body(p) for p in wave1], rate,
            rng)
        results = first + second
        for orig, rep in zip(first, second[1:]):
            if orig["status"] == 200 and rep["status"] == 200:
                pairs_checked += 1
                pairs_identical += \
                    orig["out_tokens"] == rep["out_tokens"]
        assert pairs_identical == pairs_checked, \
            "a prefix-adopted repeat diverged from its original stream"
    else:
        bodies = [body([int(t) for t in
                        rng.integers(0, model.cfg.vocab,
                                     int(rng.integers(prompt_lo,
                                                      prompt_hi + 1)))])
                  for _ in range(n_requests)]
        results = await _fire_wave(host, port, bodies, rate, rng)
    wall = time.monotonic() - t0
    metrics = await gw._metrics()
    trace_doc = slo_doc = None
    if trace:
        status, trace_doc = await _http_get_json(host, port,
                                                 "/debug/trace")
        assert status == 200, f"/debug/trace returned {status}"
        _check_trace_correlation(trace_doc)
    if slo:
        # let a couple more evaluation ticks land after the wave so the
        # drift auditor sees the final decode clock deltas
        await asyncio.sleep(0.2)
        status, slo_doc = await _http_get_json(host, port, "/debug/slo")
        assert status == 200, f"/debug/slo returned {status}"
    await gw.stop()
    if tracer is not None:
        tracer.disable()

    ok = [r for r in results if r["status"] == 200 and r["done_s"]]
    ttft = [r["ttft_s"] for r in ok if r["ttft_s"] is not None]
    gaps = [g for r in ok for g in r["gaps"]]
    total_tokens = sum(r["tokens"] for r in ok)
    eng_agg = metrics["engine"] or {}
    fleet = metrics["fleet"]
    dq = dequant_counters()
    if quantized:
        # the residency guarantee: no traced graph in this cell ever
        # materialized a full float weight (ISSUE-8 acceptance)
        assert dq["full_dequant"] == 0, \
            (f"{precision} cell traced {dq['full_dequant']} full-weight "
             "dequantizations — float weights leaked onto the hot path")
        assert dq["fused_dequant"] > 0, \
            "quantized cell traced no fused-dequant contraction"
    row = {
        "mode": "open-loop", "rate": float(rate),
        "workload": "shared-prefix" if shared_prefix else "uniform",
        "replicas": replicas, "policy": policy,
        **({"precision": precision} if precision is not None else {}),
        **({"tracing": bool(trace)} if trace is not None else {}),
        **({"tp": int(tp)} if tp is not None else {}),
        **({"slo": bool(slo)} if slo is not None else {}),
        "n_requests": len(results), "n": n, "batch": batch,
        "completed": len(ok),
        "rejected_429": sum(r["status"] == 429 for r in results),
        "errors": sum(r["status"] not in (200, 429) for r in results),
        "tokens": total_tokens,
        "goodput_tokens_per_s": total_tokens / wall if wall else 0.0,
        "wall_s": wall,
        "ttft_p50_s": _pct(ttft, 50), "ttft_p95_s": _pct(ttft, 95),
        "ttft_p99_s": _pct(ttft, 99),
        "itl_p50_s": _pct(gaps, 50), "itl_p95_s": _pct(gaps, 95),
        "itl_p99_s": _pct(gaps, 99),
        "prefix_hit_rate": float(eng_agg.get("prefix_hit_rate",
                                             float("nan"))),
        "prefill_tokens_skipped": float(
            eng_agg.get("prefill_tokens_skipped", 0.0)),
        "affinity_hits": fleet.get("affinity_hits"),
        "affinity_misses": fleet.get("affinity_misses"),
        "pairs_checked": pairs_checked,
        "pairs_identical": pairs_identical,
        # CIM cost-model energy attribution for the traffic this cell
        # actually served (sim_* = simulated, not measured)
        "sim_energy_j": float(eng_agg.get("sim_energy_j", 0.0)),
        "sim_tokens_per_j": float(eng_agg.get("sim_tokens_per_j", 0.0)),
    }
    if precision is not None:
        row["kv_dtype"] = cfg.as_dict()["kv_dtype_resolved"]
        row["kv_bytes_per_token"] = kv_bytes_per_token
        row["weight_full_dequants"] = float(dq["full_dequant"])
        row["weight_fused_dequants"] = float(dq["fused_dequant"])
    if slo_doc is not None:
        import math
        trans = slo_doc.get("transitions") or []
        drift = slo_doc.get("drift") or {}
        # worst-case replica: the calibrated drift ratio farthest from
        # 1.0 (JSON sanitize maps an uncalibrated NaN to None)
        ratios = [d.get("sim_drift_ratio") for d in drift.values()]
        ratios = [r for r in ratios
                  if isinstance(r, (int, float)) and math.isfinite(r)
                  and r > 0]
        row.update({
            "slo_worst": slo_doc.get("worst", "ok"),
            "slo_page_alerts": float(sum(t.get("to") == "page"
                                         for t in trans)),
            "slo_warn_alerts": float(sum(t.get("to") == "warn"
                                         for t in trans)),
            "sim_drift_ratio": (max(ratios,
                                    key=lambda r: abs(math.log(r)))
                                if ratios else float("nan")),
            "sim_drift_alarms": float(sum(
                d.get("sim_drift_alarms") or 0.0
                for d in drift.values())),
            "sim_drift_ticks": float(sum(
                d.get("sim_drift_ticks") or 0.0
                for d in drift.values())),
        })
    if tp is not None:
        # every cell serves greedily (temperature 0.0) and the arrival
        # schedule/prompts are seed-deterministic, so the completed
        # streams are comparable across tp cells of the same sweep:
        # request index i saw the same prompt in both.  The digest is
        # what check_bench's tp-identity rule byte-compares.
        import hashlib
        streams = [[i, r["out_tokens"]] for i, r in enumerate(results)
                   if r["status"] == 200]
        row["greedy_digest"] = hashlib.sha256(
            json.dumps(streams).encode()).hexdigest()[:16]
        row["sim_tp"] = float(eng_agg.get("sim_tp", 1.0))
    return row, trace_doc, slo_doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rates", type=float, nargs="+", default=[8.0, 32.0],
                    help="mean Poisson arrival rates (requests/s)")
    ap.add_argument("--n", type=int, default=1,
                    help="parallel samples per request (KV fork)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-pending", type=int, default=64,
                    help="fleet 429 threshold (samples in flight PER "
                         "replica)")
    ap.add_argument("--prompt-lo", type=int, default=4)
    ap.add_argument("--prompt-hi", type=int, default=24)
    ap.add_argument("--replicas", type=int, nargs="+", default=[1],
                    help="fleet sizes to sweep (data-parallel engine "
                         "replicas behind one gateway)")
    ap.add_argument("--policies", nargs="+", default=["least-loaded"],
                    choices=["rr", "least-loaded", "prefix"],
                    help="dispatch policies to sweep")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="two-wave repeated-prompt workload (prefix "
                         "affinity A/B) instead of uniform random")
    ap.add_argument("--precision", nargs="+", default=None,
                    choices=["fp", "int8", "int4"],
                    help="serving precisions to sweep (ServeConfig "
                         "tiers); labels rows with a `precision` "
                         "identity field, attaches quality probes "
                         "(logit MSE, greedy divergence vs fp) and the "
                         "quantized-KV capacity ratio, and asserts the "
                         "quantized cells traced no full-weight "
                         "dequantization")
    ap.add_argument("--tp", type=int, nargs="+", default=None,
                    help="tensor-parallel widths to sweep "
                         "(ServeConfig.tp); labels rows with a `tp` "
                         "identity field plus a greedy stream digest so "
                         "check_bench can assert tp>1 cells "
                         "byte-identical to tp=1 within the run; on CPU "
                         "force a host mesh with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--slo", action="store_true",
                    help="serve every cell under the default SLO set "
                         "with bench-compressed burn-rate windows; "
                         "labels rows with a `slo` identity field plus "
                         "alert/drift columns (gated by check_bench's "
                         "check_slo) and saves the final /debug/slo "
                         "payload as <out>.slo.json for "
                         "tools/slo_report.py")
    ap.add_argument("--trace", action="store_true",
                    help="run every cell twice — tracing off then on — "
                         "label rows with a `tracing` field for "
                         "check_bench's overhead gate, and save the "
                         "traced run's Chrome trace (Perfetto-loadable) "
                         "as an artifact")
    ap.add_argument("--trace-artifact", default=None, metavar="PATH",
                    help="where to write the Chrome trace JSON "
                         "(default results/benchmarks/<out>.trace.json)")
    ap.add_argument("--out", default="api_bench",
                    help="results/benchmarks/<out>.json basename")
    args = ap.parse_args()

    import jax
    from repro.quant import quantize_params
    model, params = build_model(args.scale)
    tps = args.tp or [None]
    if args.tp and max(args.tp) > 1:
        # the smoke-scale bench model runs GQA down to ONE kv head,
        # which no mesh can split — give the tp sweep an MHA variant of
        # the same shape instead (both tp cells share it, and the sweep
        # writes its own baseline file, so no other bench moves)
        need = max(args.tp)
        cfg = model.cfg
        if cfg.n_kv_heads % need or cfg.n_heads % need or cfg.d_ff % need:
            import jax.numpy as jnp
            from repro.models import DecoderLM, init_params
            cfg = cfg.replace(name=cfg.name + "-tp",
                              n_kv_heads=cfg.n_heads)
            model = DecoderLM(cfg)
            params = init_params(model.param_specs(),
                                 jax.random.PRNGKey(0),
                                 dtype_override=jnp.float32)
        model.validate_tp(need)     # non-dividing dims fail loudly here
    print(f"model: {model.n_params()/1e6:.1f}M params, "
          f"backend={jax.default_backend()}")

    # one packed copy per quantized tier, shared by every cell of that
    # tier (replicas share them too — engines see QTensor leaves and
    # skip re-quantizing)
    precisions = args.precision or [None]
    params_by_prec = {None: params, "fp": params}
    quality_by_prec, fp32_kv_bpt = {}, None
    for prec in precisions:
        if prec in ("int8", "int4"):
            params_by_prec[prec] = quantize_params(
                params, bits=4 if prec == "int4" else 8,
                group=QUANT_GROUP)
            base = _serve_config(prec, batch=1, max_seq=args.max_seq,
                                 page_size=args.page_size,
                                 max_pending=args.max_pending,
                                 policy="least-loaded", replicas=1)
            quality_by_prec[prec] = quality_probe(
                model, params, params_by_prec[prec], base,
                tokens=args.tokens)
            if fp32_kv_bpt is None:
                # f32-KV reference pool for the capacity ratio: pool
                # construction only (never run, never compiled)
                ref = PagedServeEngine(
                    model, params,
                    dataclasses.replace(base, precision="fp",
                                        kv_dtype="f32"))
                fp32_kv_bpt = _kv_bytes_per_token(ref)
            q = quality_by_prec[prec]
            print(f"quality[{prec}]: logit mse {q['quality_logit_mse']:.3e}"
                  f", greedy match {q['quality_greedy_match_len']:.0f}"
                  f"/{q['quality_greedy_tokens']:.0f}")

    print("precision,tp,replicas,policy,rate_rps,tracing,completed,"
          "shed_429,goodput_tok/s,ttft_p50_ms,ttft_p99_ms,itl_p50_ms,"
          "itl_p99_ms,prefix_hit,sim_tok/J")
    rows, trace_doc, slo_doc = [], None, None
    trace_modes = [False, True] if args.trace else [None]
    for precision in precisions:
      for tp in tps:
        for replicas in args.replicas:
            for policy in args.policies:
                for rate in args.rates:
                    for tracing in trace_modes:
                        r, doc, sdoc = asyncio.run(run_rate(
                            model, params_by_prec[precision], rate=rate,
                            n_requests=args.requests,
                            tokens=args.tokens, n=args.n,
                            batch=args.batch, max_seq=args.max_seq,
                            page_size=args.page_size,
                            max_pending=args.max_pending,
                            prompt_lo=args.prompt_lo,
                            prompt_hi=args.prompt_hi,
                            replicas=replicas, policy=policy,
                            shared_prefix=args.shared_prefix,
                            trace=tracing, precision=precision, tp=tp,
                            slo=True if args.slo else None))
                        if precision in quality_by_prec:
                            r.update(quality_by_prec[precision])
                            r["kv_lanes_ratio_vs_fp32"] = (
                                fp32_kv_bpt / r["kv_bytes_per_token"])
                        rows.append(r)
                        if doc is not None:
                            trace_doc = doc   # keep the last traced cell
                        if sdoc is not None:
                            slo_doc = sdoc    # keep the last SLO cell
                        hit = r["prefix_hit_rate"]
                        print(
                            f"{precision or '-'},{tp or '-'},"
                            f"{replicas},{policy},{r['rate']:g},"
                            f"{'-' if tracing is None else int(tracing)},"
                            f"{r['completed']},{r['rejected_429']},"
                            f"{r['goodput_tokens_per_s']:.1f},"
                            f"{r['ttft_p50_s']*1e3:.0f},"
                            f"{r['ttft_p99_s']*1e3:.0f},"
                            f"{r['itl_p50_s']*1e3:.1f},"
                            f"{r['itl_p99_s']*1e3:.1f},"
                            f"{hit if np.isfinite(hit) else float('nan'):.2f},"
                            f"{r['sim_tokens_per_j']:.1f}")
                        assert r["errors"] == 0, \
                            f"gateway returned errors at rate {rate}"
    save_json(args.out, rows)
    if trace_doc is not None:
        from common import RESULTS_DIR
        path = args.trace_artifact or os.path.join(
            RESULTS_DIR, args.out + ".trace.json")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(trace_doc, f)
        print(f"chrome trace ({len(trace_doc['traceEvents'])} events) "
              f"-> {path}")
    if slo_doc is not None:
        from common import RESULTS_DIR
        path = os.path.join(RESULTS_DIR, args.out + ".slo.json")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(slo_doc, f, indent=1)
        print(f"slo payload ({len(slo_doc.get('states', []))} alert "
              f"states) -> {path}  (report: PYTHONPATH=src python "
              f"tools/slo_report.py {path})")


if __name__ == "__main__":
    main()
