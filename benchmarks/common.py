"""Shared benchmark utilities."""
import json
import os
import time

# REPRO_RESULTS_DIR lets CI write bench output somewhere other than the
# checkout's committed baselines (tools/check_bench.py compares the two)
RESULTS_DIR = os.environ.get(
    "REPRO_RESULTS_DIR",
    os.path.join(os.path.dirname(__file__), "..", "results", "benchmarks"))


def save_json(name, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1)


def csv_row(name, us_per_call, derived):
    print(f"{name},{us_per_call:.2f},{derived}")
