"""Kernel microbench: cim_gemv / flash_decode / swiglu oracle paths.

On CPU the Pallas kernels run in interpret mode (correctness only), so
wall-times here measure the XLA reference path; the derived column
reports the modeled TPU-v5e time for the same op (bytes / 819 GB/s —
decode GEMV is bandwidth-bound, the paper's central observation)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels.ref import ref_flash_decode, ref_qmatmul
from repro.quant.qarray import quantize

HBM_BW = 819e9


def _time(f, *args, n=10):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run(csv=print):
    results = {}
    for bits in (4, 8):
        k, n = 4096, 4096
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.02
        x = jax.random.normal(jax.random.PRNGKey(1), (1, k))
        qt = quantize(w, bits=bits, group=128)
        f = jax.jit(lambda x_, d, s: ref_qmatmul(
            x_, type(qt)(d, s, qt.bits, qt.group, qt.axis, qt.orig_shape)))
        us = _time(f, x, qt.data, qt.scales)
        stream_bytes = qt.nbytes_packed()
        tpu_us = stream_bytes / HBM_BW * 1e6
        csv(f"cim_gemv_int{bits}_4096x4096,{us:.2f},"
            f"v5e_bw_bound_us={tpu_us:.2f}")
        results[f"int{bits}"] = {"cpu_us": us, "v5e_us": tpu_us}

    b, g, qpk, hd, S = 8, 8, 4, 128, 8192
    q = jax.random.normal(jax.random.PRNGKey(0), (b, g, qpk, hd))
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, S, g, hd),
                           jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, S, g, hd),
                          jnp.bfloat16)
    f = jax.jit(lambda q_, k_, v_: ref_flash_decode(q_, k_, v_,
                                                    jnp.int32(S - 1)))
    us = _time(f, q, kk, v)
    kv_bytes = 2 * b * S * g * hd * 2
    tpu_us = kv_bytes / HBM_BW * 1e6
    csv(f"flash_decode_8k_kv,{us:.2f},v5e_bw_bound_us={tpu_us:.2f}")
    results["flash_decode"] = {"cpu_us": us, "v5e_us": tpu_us}
    return results
