"""Fig. 7: latency/energy trade-off across alpha (LLaMA3.2-3B INT8,
128 prefill + 128 generated), 5 GA runs per alpha; red = run averages."""
import time

import numpy as np

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import run_dse


def run(csv=print, n_runs=5, pop=20, gens=50):
    t0 = time.perf_counter()
    spec = PAPER_SLMS["llama3.2-3b"]
    out = {}
    for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
        lat, en = [], []
        for seed in range(n_runs):
            res = run_dse(spec, alpha=alpha, w_bits=8, a_bits=8, seed=seed,
                          pop_size=pop, generations=gens)
            lat.append(res.best_report.latency_s)
            en.append(res.best_report.energy_j)
        out[alpha] = {
            "latency_mean": float(np.mean(lat)),
            "latency_std": float(np.std(lat)),
            "energy_mean": float(np.mean(en)),
            "energy_std": float(np.std(en)),
            "latency_runs": lat, "energy_runs": en,
        }
    us = (time.perf_counter() - t0) * 1e6
    lat0 = out[0.0]["latency_mean"]
    lat1 = out[1.0]["latency_mean"]
    en0 = out[0.0]["energy_mean"]
    en1 = out[1.0]["energy_mean"]
    csv(f"fig7_alpha_sweep,{us:.2f},"
        f"lat(a=0)/lat(a=1)={lat0/lat1:.2f};en(a=1)/en(a=0)={en1/en0:.2f}")
    return out
