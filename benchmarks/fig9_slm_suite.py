"""Fig. 9: throughput, energy efficiency, and area across the paper's
12-SLM suite for INT4 and INT8 at alpha=1 (per-model DSE, best of 3
seeds, as the paper fixes alpha=1 'to prioritize latency')."""
import time

import numpy as np

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import run_dse


def run(csv=print, gens=50, pop=20, seeds=3):
    t0 = time.perf_counter()
    out = {}
    for w_bits in (4, 8):
        rows = {}
        for name, spec in PAPER_SLMS.items():
            best = None
            for seed in range(seeds):
                r = run_dse(spec, alpha=1.0, w_bits=w_bits, a_bits=8,
                            seed=seed, pop_size=pop, generations=gens)
                if best is None or r.best_cost < best.best_cost:
                    best = r
            rep = best.best_report
            rows[name] = {"tokens_per_s": rep.tokens_per_s,
                          "tokens_per_j": rep.tokens_per_j,
                          "area_mm2": rep.area_mm2,
                          "h_star": str(best.best)}
        avg_tps = float(np.mean([r["tokens_per_s"] for r in rows.values()]))
        avg_tpj = float(np.mean([r["tokens_per_j"] for r in rows.values()]))
        out[f"int{w_bits}"] = {"models": rows, "avg_tokens_per_s": avg_tps,
                               "avg_tokens_per_j": avg_tpj}
    us = (time.perf_counter() - t0) * 1e6
    a4 = out["int4"]
    csv(f"fig9_slm_suite,{us:.2f},"
        f"int4_avg={a4['avg_tokens_per_s']:.1f}tok/s(paper336.4);"
        f"{a4['avg_tokens_per_j']:.1f}tok/J(paper173.0)")
    return out
