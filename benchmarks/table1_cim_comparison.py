"""Table I: normalized efficiency (TOPS/W/mm^2) vs prior CIM accelerators.

EdgeCIM h* (Table I footnote): Cv=2 Ch=3 Tv_act=2 Th_act=4 T_total=8
P^2=16.  Prior-work numbers are as published (TranCIM 3.06,
iMTransformer 1.64)."""
import time

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import EdgeCIMSimulator, HWConfig

PRIOR = {"trancim": 3.06, "imtransformer": 1.64, "edgecim_paper": 7.03}


def run(csv=print):
    t0 = time.perf_counter()
    h = HWConfig(c_v=2, c_h=3, t_act_v=2, t_act_h=4, m_mult=1, pe_count=16)
    sim = EdgeCIMSimulator()
    rep = sim.generate(PAPER_SLMS["llama3.2-3b"], h, 128, 128, 4, 8)
    ours_e2e = rep.tops_per_w_per_mm2()

    # macro-referenced accounting (as CIM papers usually normalize):
    # peak INT4 throughput at the [25] macro efficiency (89 TOPS/W INT8
    # => ~178 TOPS/W INT4), peak power = peak_tops / macro TOPS/W,
    # excluding DRAM (off-chip) like the prior-work numbers.
    from repro.core import chip_area_mm2, peak_tops
    tops4 = peak_tops(h, 4)
    p_macro = tops4 / 178.0
    area = chip_area_mm2(h)
    ours_macro = tops4 / p_macro / area

    us = (time.perf_counter() - t0) * 1e6
    csv(f"table1_cim_comparison,{us:.2f},"
        f"macro_norm={ours_macro:.2f};e2e_norm={ours_e2e:.2f};"
        f"paper=7.03;trancim=3.06")
    return {"edgecim_macro_normalized": ours_macro,
            "edgecim_end_to_end": ours_e2e,
            "peak_tops_int4": tops4, "area_mm2": area,
            "avg_power_w_e2e": rep.energy_j / rep.latency_s,
            "prior": PRIOR,
            "note": ("prior-work TOPS/W/mm^2 figures are macro-level peak "
                     "numbers excluding DRAM; our end-to-end number "
                     "includes DRAM interface energy, hence lower. Both "
                     "accountings reported; see EXPERIMENTS.md.")}
