"""Fig. 10 + Table II: EdgeCIM vs commercial edge GPUs and NPUs (INT4).

Baseline numbers are the published measurements the paper compares
against (Jetson AI Lab benchmarks [42], Qualcomm AI Hub [43])."""
import time

import numpy as np

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import run_dse

# published INT4 throughput (tokens/s) / efficiency (tokens/J)
BASELINES_TPS = {
    "llama3.2-1b": {"jetson-orin-nano": 54.8, "jetson-agx-orin": 163.9},
    "smollm2-1.7b": {"jetson-orin-nano": 41.0,
                     "jetson-orin-nano-super": 64.5},
    "llama3.2-3b": {"jetson-orin-nano": 27.7,
                    "jetson-orin-nano-super": 43.07,
                    "jetson-agx-orin": 80.4, "qualcomm-sa8255p": 14.0,
                    "snapdragon-x-elite": 18.4,
                    "snapdragon-8-elite": 23.5},
}
BASELINES_TPJ = {"llama3.2-1b": {"jetson-orin-nano": 3.65}}


def run(csv=print):
    t0 = time.perf_counter()
    ours = {}
    for name in BASELINES_TPS:
        best = None
        for seed in range(3):
            r = run_dse(PAPER_SLMS[name], alpha=1.0, w_bits=4, a_bits=8,
                        seed=seed)
            if best is None or r.best_cost < best.best_cost:
                best = r
        ours[name] = {"tokens_per_s": best.best_report.tokens_per_s,
                      "tokens_per_j": best.best_report.tokens_per_j}
    table = {}
    for name, base in BASELINES_TPS.items():
        table[name] = {
            "edgecim_tps": ours[name]["tokens_per_s"],
            "speedups": {k: ours[name]["tokens_per_s"] / v
                         for k, v in base.items()},
        }
        if name in BASELINES_TPJ:
            table[name]["efficiency_gains"] = {
                k: ours[name]["tokens_per_j"] / v
                for k, v in BASELINES_TPJ[name].items()}
    s1 = table["llama3.2-1b"]["speedups"]["jetson-orin-nano"]
    e1 = table["llama3.2-1b"]["efficiency_gains"]["jetson-orin-nano"]
    s3 = table["llama3.2-3b"]["speedups"]["qualcomm-sa8255p"]
    us = (time.perf_counter() - t0) * 1e6
    csv(f"fig10_tableII_edge_comparison,{us:.2f},"
        f"1b_vs_orin_nano={s1:.1f}x(paper7.3);"
        f"1b_eff={e1:.1f}x(paper49.6);3b_vs_sa8255p={s3:.1f}x(paper9.95)")
    return table
