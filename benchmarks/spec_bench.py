"""Speculative-decoding benchmark: drafter x k x workload sweep.

Runs the paged engine with and without speculation on workloads at both
ends of the draftability spectrum and reports, per cell:

  * acceptance rate and mean tokens emitted per verify step
  * decode-graph tokens/s vs the non-speculative baseline
  * the analytical SpecKnob speedup the measured acceptance rate
    implies for the paper's accelerator (ties the runtime measurement
    back to the DSE cost model)

Workloads:
  repetitive   prompts with strong n-gram structure (extractive /
               templated traffic — where prompt-lookup shines)
  random       uniform random prompts (worst case: model drafter only)

  PYTHONPATH=src python benchmarks/spec_bench.py [--scale 8] [--tokens 24]
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import save_json  # noqa: E402
from serve_bench import warm_engine  # noqa: E402

from repro.core import EdgeCIMSimulator, SpecKnob  # noqa: E402
from repro.core.hw import HWConfig  # noqa: E402
from repro.core.workload import make_dense_spec  # noqa: E402
from repro.models import DecoderLM, ModelConfig, init_params  # noqa: E402
from repro.serve import PagedServeEngine, ServeRequest  # noqa: E402
from repro.spec import SpecConfig  # noqa: E402

VOCAB = 512


def build_model(scale: int, n_layers: int, seed: int = 0):
    cfg = ModelConfig(name="bench", family="dense", n_layers=n_layers,
                      d_model=2048 // scale, n_heads=max(32 // scale, 1),
                      n_kv_heads=8 // min(scale, 8) or 1,
                      d_ff=8192 // scale, vocab=VOCAB, head_dim=64,
                      dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(seed),
                         dtype_override=jnp.float32)
    return model, params


def make_requests(workload: str, n_requests: int, tokens: int):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_requests):
        if workload == "repetitive":
            motif = rng.integers(0, VOCAB, 4).astype(np.int32)
            reps = int(rng.integers(3, 6))
            prompt = np.tile(motif, reps)
        else:
            prompt = rng.integers(0, VOCAB,
                                  int(rng.integers(8, 24))).astype(np.int32)
        reqs.append(ServeRequest(prompt=prompt, max_new_tokens=tokens,
                                 rid=i))
    return reqs


def run_one(model, params, spec_cfg, *, workload: str, n_requests: int,
            tokens: int, batch: int, max_seq: int):
    reqs = make_requests(workload, n_requests, tokens)
    eng = PagedServeEngine(model, params, max_batch=batch, max_seq=max_seq,
                           page_size=8, prefill_chunk=16, spec=spec_cfg)
    warm_engine(eng, vocab=VOCAB)
    t0 = time.monotonic()
    eng.run(reqs)
    wall = time.monotonic() - t0
    assert all(r.done for r in reqs)
    m = eng.summary()
    return {
        "wall_s": wall,
        "tokens": m["tokens"],
        "decode_steps": m["decode_steps"],
        "tokens_per_s_decode": eng.throughput(),
        "tokens_per_step": m["tokens_per_decode_step"],
        "acceptance_rate": m["spec_acceptance_rate"],
        "drafted": m["spec_drafted"],
        "accepted": m["spec_accepted"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--ks", type=int, nargs="+", default=[2, 4])
    # "self" drafts with the TARGET model: random-weight draft models
    # can't agree with a random-weight target, so this cell calibrates
    # the acceptance upper bound (~1.0) the verify pipeline supports at
    # the worst-case draft cost (ratio 1.0)
    ap.add_argument("--drafters", nargs="+",
                    default=["ngram", "model", "self"])
    args = ap.parse_args()

    model, params = build_model(args.scale, args.layers)
    # draft model: same family, 1 layer and half width (~8x fewer params)
    draft_model, draft_params = build_model(args.scale * 2, 1, seed=7)
    print(f"target: {model.n_params()/1e6:.1f}M params, draft: "
          f"{draft_model.n_params()/1e6:.1f}M, "
          f"backend={jax.default_backend()}")
    draft_ratio = draft_model.n_params() / model.n_params()

    sim = EdgeCIMSimulator()
    slm = make_dense_spec("bench", 24, 2048, 16, 8, 5632, 32000)
    hw = HWConfig()
    base_lat = sim.generate(slm, hw, 128, 128).latency_s

    rows = []
    print("workload,drafter,k,acc_rate,tok/step,tok/s,baseline_tok/s,"
          "speedup,sim_speedup")
    for workload in ("repetitive", "random"):
        base = run_one(model, params, None, workload=workload,
                       n_requests=args.requests, tokens=args.tokens,
                       batch=args.batch, max_seq=args.max_seq)
        for drafter in args.drafters:
            for k in args.ks:
                if drafter == "model":
                    sc = SpecConfig(k=k, drafter="model",
                                    draft_model=draft_model,
                                    draft_params=draft_params,
                                    draft_page_size=8)
                elif drafter == "self":
                    sc = SpecConfig(k=k, drafter="model",
                                    draft_model=model,
                                    draft_params=params,
                                    draft_page_size=8)
                else:
                    sc = SpecConfig(k=k, drafter="ngram")
                r = run_one(model, params, sc, workload=workload,
                            n_requests=args.requests, tokens=args.tokens,
                            batch=args.batch, max_seq=args.max_seq)
                acc = r["acceptance_rate"]
                knob = SpecKnob(
                    k=k, accept_rate=0.0 if np.isnan(acc) else acc,
                    draft_cost_ratio={"model": draft_ratio,
                                      "self": 1.0}.get(drafter, 0.0))
                sim_speedup = base_lat / sim.generate(
                    slm, hw, 128, 128, spec_decode=knob).latency_s
                row = {"workload": workload, "drafter": drafter, "k": k,
                       "baseline_tokens_per_s": base["tokens_per_s_decode"],
                       "sim_speedup": sim_speedup, **r}
                rows.append(row)
                print(f"{workload},{drafter},{k},{acc:.2f},"
                      f"{r['tokens_per_step']:.2f},"
                      f"{r['tokens_per_s_decode']:.1f},"
                      f"{base['tokens_per_s_decode']:.1f},"
                      f"{r['tokens_per_s_decode']/base['tokens_per_s_decode']:.2f},"
                      f"{sim_speedup:.2f}")
    save_json("spec_bench", rows)


if __name__ == "__main__":
    main()
