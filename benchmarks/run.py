"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; details saved to
results/benchmarks/*.json.  --quick shrinks GA budgets for CI."""
import argparse
import sys

from . import (fig2_profiling, fig7_alpha_sweep, fig8_token_scaling,
               fig9_slm_suite, fig10_edge_comparison, table1_cim_comparison,
               kernel_bench)
from .common import csv_row, save_json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced GA budgets (CI)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    jobs = {
        "fig2": lambda: fig2_profiling.run(),
        "fig7": lambda: fig7_alpha_sweep.run(
            n_runs=2 if args.quick else 5,
            gens=10 if args.quick else 50),
        "fig8": lambda: fig8_token_scaling.run(),
        "fig9": lambda: fig9_slm_suite.run(
            gens=10 if args.quick else 50,
            seeds=1 if args.quick else 3),
        "fig10_tableII": lambda: fig10_edge_comparison.run(),
        "table1": lambda: table1_cim_comparison.run(),
        "kernels": lambda: kernel_bench.run(),
    }
    for name, job in jobs.items():
        if args.only and args.only != name:
            continue
        out = job()
        save_json(name, out)


if __name__ == "__main__":
    main()
