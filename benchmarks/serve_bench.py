"""Serving benchmark: batch-size x prompt-mix sweep on the paged engine.

Measures what the paper simulates — decode throughput and latency of a
batched SLM under a mixed-length request stream — on the real runtime:

  * tokens/s (decode-graph time and wall clock)
  * TTFT / TPOT p50 and p99
  * peak KV pages vs the dense (n_slots, max_seq) cache the seed engine
    allocated for the same workload

  PYTHONPATH=src python benchmarks/serve_bench.py [--scale 8] [--tokens 16]
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import save_json  # noqa: E402

from repro.models import DecoderLM, ModelConfig, init_params  # noqa: E402
from repro.serve import PagedServeEngine, ServeRequest  # noqa: E402

PROMPT_MIXES = {
    "short": (4, 12),        # uniform prompt-length range
    "mixed": (4, 48),
}


def build_model(scale: int):
    cfg = ModelConfig(name="bench", family="dense", n_layers=4,
                      d_model=2048 // scale, n_heads=32 // scale,
                      n_kv_heads=8 // min(scale, 8) or 1,
                      d_ff=8192 // scale, vocab=2048, head_dim=64,
                      dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


def run_one(model, params, *, batch: int, mix: str, n_requests: int,
            tokens: int, max_seq: int, page_size: int):
    lo, hi = PROMPT_MIXES[mix]
    rng = np.random.default_rng(0)
    lens = rng.integers(lo, hi + 1, size=n_requests)
    reqs = [ServeRequest(prompt=rng.integers(0, 2048, int(n)
                                             ).astype(np.int32),
                         max_new_tokens=tokens, rid=i)
            for i, n in enumerate(lens)]
    # pool sized to the workload: peak tokens in flight across `batch`
    # concurrent lanes, not worst-case batch * max_seq
    peak_tokens = sum(sorted(int(n) + tokens for n in lens)[-batch:])
    n_pages = -(-peak_tokens // page_size) + batch
    eng = PagedServeEngine(model, params, max_batch=batch, max_seq=max_seq,
                           page_size=page_size, n_pages=n_pages,
                           prefill_chunk=16)
    t0 = time.monotonic()
    eng.run(reqs)
    wall = time.monotonic() - t0
    m = eng.summary()

    row_bytes = eng.cache.kv_bytes() // (n_pages * page_size)
    paged_bytes = eng.cache.kv_bytes()
    dense_bytes = batch * max_seq * row_bytes
    return {
        "batch": batch, "mix": mix, "n_requests": n_requests,
        "wall_s": wall,
        "tokens_per_s_wall": m["tokens"] / wall,
        "tokens_per_s_decode": eng.throughput(),
        "ttft_p50_s": m["ttft_p50_s"], "ttft_p99_s": m["ttft_p99_s"],
        "tpot_p50_s": m["tpot_p50_s"], "tpot_p99_s": m["tpot_p99_s"],
        "queue_p50_s": m["queue_p50_s"],
        "kv_occupancy_peak": m["kv_occupancy_peak"],
        "kv_pages": n_pages,
        "kv_bytes_paged": paged_bytes,
        "kv_bytes_dense_equiv": dense_bytes,
        "kv_savings": 1.0 - paged_bytes / dense_bytes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--batches", type=int, nargs="+", default=[2, 4])
    args = ap.parse_args()

    model, params = build_model(args.scale)
    print(f"model: {model.n_params()/1e6:.1f}M params, "
          f"backend={jax.default_backend()}")
    print("batch,mix,tok/s(decode),tok/s(wall),ttft_p50_ms,ttft_p99_ms,"
          "tpot_p50_ms,tpot_p99_ms,kv_peak_occ,kv_savings_vs_dense")
    rows = []
    for batch in args.batches:
        for mix in PROMPT_MIXES:
            r = run_one(model, params, batch=batch, mix=mix,
                        n_requests=args.requests, tokens=args.tokens,
                        max_seq=args.max_seq, page_size=args.page_size)
            rows.append(r)
            print(f"{r['batch']},{r['mix']},"
                  f"{r['tokens_per_s_decode']:.1f},"
                  f"{r['tokens_per_s_wall']:.1f},"
                  f"{r['ttft_p50_s']*1e3:.0f},{r['ttft_p99_s']*1e3:.0f},"
                  f"{r['tpot_p50_s']*1e3:.1f},{r['tpot_p99_s']*1e3:.1f},"
                  f"{r['kv_occupancy_peak']:.2f},"
                  f"{r['kv_savings']*100:.0f}%")
    save_json("serve_bench", rows)


if __name__ == "__main__":
    main()
