"""Serving benchmark: batch-size x prompt-mix sweep on the paged engine.

Measures what the paper simulates — decode throughput and latency of a
batched SLM under a mixed-length request stream — on the real runtime:

  * tokens/s (decode-graph time and wall clock)
  * TTFT / TPOT p50 and p99
  * peak KV pages vs the dense (n_slots, max_seq) cache the seed engine
    allocated for the same workload

`--shared-prefix` adds an A/B run of a chat-template-style workload
(every prompt shares a long common prefix) with the radix-trie prefix
cache off vs on: it checks greedy outputs are byte-identical, that
prefill tokens were actually skipped, and reports the TTFT reduction —
the paper's time-to-first-token axis on edge traffic.

`--family {mamba2,xlstm,zamba}` benches the unified decode-state
runtime on a recurrent or hybrid model instead: a mixed-length workload
under continuous admission (per-lane StateArena slots, no equal-length
lockstep grouping), gated on byte-identical greedy output vs serving
each request alone.  Results land in `serve_bench_<family>.json` so CI
gates every family row independently.

  PYTHONPATH=src python benchmarks/serve_bench.py [--scale 8] [--tokens 16]
"""
import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(__file__))
from common import save_json  # noqa: E402

from repro.models import DecoderLM, ModelConfig, init_params  # noqa: E402
from repro.serve import PagedServeEngine, ServeRequest  # noqa: E402
from repro.serve.telemetry import Telemetry  # noqa: E402


def warm_engine(eng, vocab=2048):
    """Compile the engine's prefill/decode graphs on a throwaway
    request, then reset telemetry: each engine jit-compiles its own
    graphs, and that one-off second of compile time would otherwise
    dominate every gated TTFT/wall number at smoke scale.  The prompt
    is a repeated motif so an n-gram drafter proposes and the spec
    verify graph compiles too."""
    motif = np.random.default_rng(99).integers(0, vocab, 4)
    warm = np.tile(motif, 5).astype(np.int32)[:17]
    eng.run([ServeRequest(prompt=warm, max_new_tokens=2, rid=-1)])
    eng.telemetry = Telemetry()
    eng.energy.reset()      # tokens/J covers only the measured window

PROMPT_MIXES = {
    "short": (4, 12),        # uniform prompt-length range
    "mixed": (4, 48),
}


def build_model(scale: int, family: str = "dense"):
    from repro.models.config import SSMConfig, ZambaConfig
    d = 2048 // scale
    if family == "dense":
        cfg = ModelConfig(name="bench", family="dense", n_layers=4,
                          d_model=d, n_heads=32 // scale,
                          n_kv_heads=8 // min(scale, 8) or 1,
                          d_ff=8192 // scale, vocab=2048, head_dim=64,
                          dtype="float32", remat=False)
    elif family == "xlstm":
        cfg = ModelConfig(name="bench-xlstm", family="xlstm", n_layers=4,
                          d_model=d, n_heads=4, n_kv_heads=4,
                          d_ff=4 * d, vocab=2048, head_dim=d // 4,
                          dtype="float32", remat=False,
                          ssm=SSMConfig(mlstm_heads=4, slstm_every=2))
    elif family == "mamba2":
        # pure-mamba shape: zamba config whose shared-attention period
        # exceeds n_layers (zero attention groups -> StateArena only)
        cfg = ModelConfig(name="bench-mamba2", family="zamba", n_layers=4,
                          d_model=d, n_heads=4, n_kv_heads=2,
                          d_ff=4 * d, vocab=2048, head_dim=d // 4,
                          dtype="float32", remat=False,
                          ssm=SSMConfig(d_state=32, head_dim=d // 2,
                                        expand=2),
                          zamba=ZambaConfig(shared_every=8, lora_rank=16,
                                            shared_d_ff=4 * d))
    elif family == "zamba":
        cfg = ModelConfig(name="bench-zamba", family="zamba", n_layers=4,
                          d_model=d, n_heads=4, n_kv_heads=2,
                          d_ff=4 * d, vocab=2048, head_dim=d // 4,
                          dtype="float32", remat=False,
                          ssm=SSMConfig(d_state=32, head_dim=d // 2,
                                        expand=2),
                          zamba=ZambaConfig(shared_every=2, lora_rank=16,
                                            shared_d_ff=4 * d))
    else:
        raise ValueError(family)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    return model, params


def run_one(model, params, *, batch: int, mix: str, n_requests: int,
            tokens: int, max_seq: int, page_size: int):
    lo, hi = PROMPT_MIXES[mix]
    rng = np.random.default_rng(0)
    lens = rng.integers(lo, hi + 1, size=n_requests)
    reqs = [ServeRequest(prompt=rng.integers(0, 2048, int(n)
                                             ).astype(np.int32),
                         max_new_tokens=tokens, rid=i)
            for i, n in enumerate(lens)]
    # pool sized to the workload: peak tokens in flight across `batch`
    # concurrent lanes, not worst-case batch * max_seq
    peak_tokens = sum(sorted(int(n) + tokens for n in lens)[-batch:])
    n_pages = -(-peak_tokens // page_size) + batch
    eng = PagedServeEngine(model, params, max_batch=batch, max_seq=max_seq,
                           page_size=page_size, n_pages=n_pages,
                           prefill_chunk=16)
    warm_engine(eng)
    t0 = time.monotonic()
    eng.run(reqs)
    wall = time.monotonic() - t0
    m = eng.summary()

    row_bytes = eng.cache.kv_bytes() // (n_pages * page_size)
    paged_bytes = eng.cache.kv_bytes()
    dense_bytes = batch * max_seq * row_bytes
    return {
        "batch": batch, "mix": mix, "n_requests": n_requests,
        "wall_s": wall,
        "tokens_per_s_wall": m["tokens"] / wall,
        "tokens_per_s_decode": eng.throughput(),
        "ttft_p50_s": m["ttft_p50_s"], "ttft_p99_s": m["ttft_p99_s"],
        "tpot_p50_s": m["tpot_p50_s"], "tpot_p99_s": m["tpot_p99_s"],
        "queue_p50_s": m["queue_p50_s"],
        "kv_occupancy_peak": m["kv_occupancy_peak"],
        "kv_pages": n_pages,
        "kv_bytes_paged": paged_bytes,
        "kv_bytes_dense_equiv": dense_bytes,
        "kv_savings": 1.0 - paged_bytes / dense_bytes,
    }


def run_shared_prefix(model, params, *, batch: int, n_requests: int,
                      tokens: int, max_seq: int, page_size: int,
                      prefix_len: int):
    """A/B: identical shared-prefix workload with the prefix cache off
    vs on.  Dies loudly if outputs diverge or nothing was skipped —
    these are the PR's correctness bars, not tunables."""
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, 2048, prefix_len).astype(np.int32)
    prompts = [np.concatenate(
        [prefix, rng.integers(0, 2048, int(s)).astype(np.int32)])
        for s in rng.integers(4, 9, size=n_requests)]

    def serve(prefix_cache: bool):
        reqs = [ServeRequest(prompt=p.copy(), max_new_tokens=tokens,
                             rid=i) for i, p in enumerate(prompts)]
        eng = PagedServeEngine(model, params, max_batch=batch,
                               max_seq=max_seq, page_size=page_size,
                               prefill_chunk=16,
                               prefix_cache=prefix_cache)
        warm_engine(eng)        # the warm prompt is disjoint from the
        t0 = time.monotonic()   # shared prefix, so it seeds no match
        eng.run(reqs)
        return reqs, eng.summary(), time.monotonic() - t0

    base_reqs, mb, wall_b = serve(prefix_cache=False)
    shared_reqs, ms, wall_s = serve(prefix_cache=True)

    identical = all(b.out_tokens == s.out_tokens
                    for b, s in zip(base_reqs, shared_reqs))
    assert identical, "prefix sharing changed greedy decode output"
    skipped = ms["prefill_tokens_skipped"]
    assert skipped > 0, "shared-prefix workload skipped no prefill"

    return {
        "mode": "shared-prefix", "batch": batch,
        "n_requests": n_requests, "prefix_len": prefix_len,
        "outputs_byte_identical": identical,
        "prefill_tokens_skipped": skipped,
        "prefix_hit_rate": ms["prefix_hit_rate"],
        "kv_pages_shared": ms["kv_pages_shared"],
        "cow_copies": ms["cow_copies"],
        "prefill_tokens_unshared": mb["prefill_tokens"],
        "prefill_tokens_shared": ms["prefill_tokens"],
        "ttft_mean_s_unshared": mb["ttft_mean_s"],
        "ttft_mean_s_shared": ms["ttft_mean_s"],
        "ttft_speedup": mb["ttft_mean_s"] / ms["ttft_mean_s"],
        "wall_s_unshared": wall_b, "wall_s_shared": wall_s,
    }


def run_family(model, params, *, family: str, batch: int, n_requests: int,
               tokens: int, max_seq: int, page_size: int):
    """Unified decode-state workload: mixed-length prompts under
    continuous admission, gated on byte-identical greedy output vs
    serving every request alone (same engine shape).  The identity gate
    is the PR's correctness bar — continuous batching of recurrent
    state must be invisible in the emitted tokens."""
    rng = np.random.default_rng(0)
    lens = rng.integers(4, 33, size=n_requests)

    def engine():
        return PagedServeEngine(model, params, max_batch=batch,
                                max_seq=max_seq, page_size=page_size,
                                prefill_chunk=16)

    reqs = [ServeRequest(prompt=rng.integers(0, 2048, int(n)
                                             ).astype(np.int32),
                         max_new_tokens=tokens, rid=i)
            for i, n in enumerate(lens)]
    prompts = [r.prompt.copy() for r in reqs]
    eng = engine()
    warm_engine(eng)
    t0 = time.monotonic()
    eng.run(reqs)
    wall = time.monotonic() - t0
    m = eng.summary()

    # reference: one engine, one request at a time (identical graph
    # shapes; reused so the jitted step compiles once)
    ref_eng = engine()
    warm_engine(ref_eng)
    identical = True
    for req, prompt in zip(reqs, prompts):
        solo = ServeRequest(prompt=prompt, max_new_tokens=tokens, rid=0)
        ref_eng.run([solo])
        identical &= req.out_tokens == solo.out_tokens
    assert identical, (f"{family}: continuous batching changed greedy "
                       "output vs single-request serving")

    return {
        "mode": "family", "family": family, "batch": batch,
        "n_requests": n_requests,
        "outputs_byte_identical": identical,
        "wall_s": wall,
        "tokens_per_s_wall": m["tokens"] / wall,
        "tokens_per_s_decode": eng.throughput(),
        "ttft_p50_s": m["ttft_p50_s"], "ttft_p99_s": m["ttft_p99_s"],
        "tpot_p50_s": m["tpot_p50_s"], "tpot_p99_s": m["tpot_p99_s"],
        "state_slot_occupancy_peak": m["state_slot_occupancy_peak"],
        "state_bytes": m["state_bytes"],
        "lane_steps": m[f"lane_steps_{model.cfg.family}"],
        "kv_bytes_paged": eng.cache.kv_bytes(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--batches", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--shared-prefix", action="store_true",
                    help="add the prefix-cache A/B workload")
    ap.add_argument("--prefix-len", type=int, default=64,
                    help="common prefix tokens for --shared-prefix")
    ap.add_argument("--family", default="dense",
                    choices=["dense", "mamba2", "xlstm", "zamba"],
                    help="bench the unified decode-state runtime on a "
                         "recurrent/hybrid family (writes "
                         "serve_bench_<family>.json)")
    args = ap.parse_args()

    if args.family != "dense":
        model, params = build_model(args.scale, args.family)
        print(f"model[{args.family}]: {model.n_params()/1e6:.1f}M params, "
              f"backend={jax.default_backend()}")
        rows = []
        for batch in args.batches:
            r = run_family(model, params, family=args.family, batch=batch,
                           n_requests=args.requests, tokens=args.tokens,
                           max_seq=args.max_seq, page_size=args.page_size)
            rows.append(r)
            print(f"{args.family},batch={batch}: "
                  f"{r['tokens_per_s_decode']:.1f} tok/s decode, "
                  f"ttft_p50 {r['ttft_p50_s']*1e3:.0f} ms, "
                  f"tpot_p50 {r['tpot_p50_s']*1e3:.1f} ms, "
                  f"state slots peak "
                  f"{r['state_slot_occupancy_peak']*100:.0f}%, "
                  f"outputs byte-identical")
        save_json(f"serve_bench_{args.family}", rows)
        return

    model, params = build_model(args.scale)
    print(f"model: {model.n_params()/1e6:.1f}M params, "
          f"backend={jax.default_backend()}")
    print("batch,mix,tok/s(decode),tok/s(wall),ttft_p50_ms,ttft_p99_ms,"
          "tpot_p50_ms,tpot_p99_ms,kv_peak_occ,kv_savings_vs_dense")
    rows = []
    for batch in args.batches:
        for mix in PROMPT_MIXES:
            r = run_one(model, params, batch=batch, mix=mix,
                        n_requests=args.requests, tokens=args.tokens,
                        max_seq=args.max_seq, page_size=args.page_size)
            rows.append(r)
            print(f"{r['batch']},{r['mix']},"
                  f"{r['tokens_per_s_decode']:.1f},"
                  f"{r['tokens_per_s_wall']:.1f},"
                  f"{r['ttft_p50_s']*1e3:.0f},{r['ttft_p99_s']*1e3:.0f},"
                  f"{r['tpot_p50_s']*1e3:.1f},{r['tpot_p99_s']*1e3:.1f},"
                  f"{r['kv_occupancy_peak']:.2f},"
                  f"{r['kv_savings']*100:.0f}%")
    if args.shared_prefix:
        r = run_shared_prefix(model, params, batch=max(args.batches),
                              n_requests=args.requests,
                              tokens=args.tokens, max_seq=args.max_seq,
                              page_size=args.page_size,
                              prefix_len=args.prefix_len)
        rows.append(r)
        print(f"shared-prefix: {int(r['prefill_tokens_skipped'])} prefill "
              f"tokens skipped (hit rate "
              f"{r['prefix_hit_rate']*100:.0f}%), ttft mean "
              f"{r['ttft_mean_s_unshared']*1e3:.0f} -> "
              f"{r['ttft_mean_s_shared']*1e3:.0f} ms "
              f"({r['ttft_speedup']:.2f}x), outputs byte-identical")
    save_json("serve_bench", rows)


if __name__ == "__main__":
    main()
