"""Training driver with the fault-tolerance substrate in action.

Trains a small decoder LM with grad accumulation + periodic checkpoints,
then kills and resumes mid-run to demonstrate bit-identical recovery
(the multi-pod story at CPU scale).

  PYTHONPATH=src python examples/train_slm.py [--steps 200] [--d-model 256]
"""
import argparse
import os
import shutil

import numpy as np

from repro.data import DataConfig, SyntheticLM
from repro.models import DecoderLM, ModelConfig
from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_slm")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = ModelConfig(name="slm", family="dense", n_layers=args.layers,
                      d_model=args.d_model, n_heads=4, n_kv_heads=2,
                      d_ff=4 * args.d_model, vocab=256, head_dim=32,
                      dtype="float32", remat=False)
    model = DecoderLM(cfg)
    print(f"training {model.n_params()/1e6:.1f}M-param decoder LM")
    data = SyntheticLM(DataConfig(vocab=256, seq_len=128, global_batch=8))
    opt = AdamW(lr=cosine_schedule(1e-3, 20, args.steps))

    def mk(steps):
        return Trainer(model, opt, data,
                       TrainConfig(steps=steps, log_every=20, ckpt_every=25,
                                   ckpt_dir=args.ckpt_dir,
                                   async_checkpoint=False,
                                   microbatches=2),
                       event_hook=lambda e: print(f"  {e.kind} @{e.step} "
                                                  f"{e.payload}"))

    half = args.steps // 2
    print(f"-- phase 1: run to step {half}, then simulate failure --")
    mk(half).run()
    print("-- phase 2: restart from checkpoint (exact resume) --")
    out = mk(args.steps).run(resume=True)
    print(f"final loss {out['losses'][-1]:.3f} "
          f"(bigram floor {data.bigram_entropy():.3f})")


if __name__ == "__main__":
    main()
