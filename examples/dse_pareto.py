"""Design-space exploration walkthrough: alpha sweep -> Pareto front.

Reproduces the paper's Fig. 7 flow on any of the 12 benchmark SLMs:
  PYTHONPATH=src python examples/dse_pareto.py --model qwen2.5-0.5b
"""
import argparse

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import pareto_front, run_dse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama3.2-3b",
                    choices=sorted(PAPER_SLMS))
    ap.add_argument("--w-bits", type=int, default=8, choices=[4, 8])
    ap.add_argument("--runs", type=int, default=3)
    args = ap.parse_args()
    spec = PAPER_SLMS[args.model]

    points = []
    print(f"alpha sweep for {args.model} (INT{args.w_bits}):")
    print(f"{'alpha':>6} {'latency_s':>12} {'energy_J':>10} "
          f"{'tok/s':>8} {'area':>7}  h*")
    for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
        best = None
        for seed in range(args.runs):
            r = run_dse(spec, alpha=alpha, w_bits=args.w_bits, seed=seed)
            if best is None or r.best_cost < best.best_cost:
                best = r
        rep = best.best_report
        points.append((rep.latency_s, rep.energy_j, alpha, best.best))
        print(f"{alpha:>6.2f} {rep.latency_s:>12.4f} {rep.energy_j:>10.4f} "
              f"{rep.tokens_per_s:>8.1f} {rep.area_mm2:>7.1f}  {best.best}")

    front = pareto_front([(p[0], p[1]) for p in points])
    print("\nPareto-optimal alphas:", [points[i][2] for i in front])


if __name__ == "__main__":
    main()
