"""End-to-end serving driver (the paper's workload: decoder-only decode).

Builds a LLaMA-family SLM (reduced width for CPU), quantizes weights to
INT8 and INT4, and serves a mixed-length batch of requests through the
paged-KV continuous-batching engine — reporting measured tokens/s,
TTFT/TPOT percentiles, and KV-page occupancy alongside the EdgeCIM-
simulator projection for the same model at full scale: software and
hardware sides of the co-design in one script.

  PYTHONPATH=src python examples/serve_slm.py [--scale 4] [--tokens 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_slms import PAPER_SLMS
from repro.core import run_dse
from repro.models import DecoderLM, ModelConfig, init_params
from repro.quant import quantize_params, quantized_fraction
from repro.serve import PagedServeEngine, SamplingParams, ServeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8,
                    help="width divisor vs llama3.2-1b (CPU-friendly)")
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args()

    s = args.scale
    cfg = ModelConfig(name="llama-mini", family="dense",
                      n_layers=4, d_model=2048 // s, n_heads=32 // s,
                      n_kv_heads=8 // min(s, 8) or 1, d_ff=8192 // s,
                      vocab=2048, head_dim=64, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    print(f"model: {model.n_params()/1e6:.1f}M params "
          f"(llama3.2-1b family / {s})")

    rng = np.random.default_rng(0)
    lens = rng.integers(4, 24, size=args.requests)       # mixed-length mix
    prompts = [rng.integers(0, cfg.vocab, size=int(n)).astype(np.int32)
               for n in lens]
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k)

    for label, p in [
            ("bf16", params),
            ("int8", quantize_params(params, bits=8)),
            ("int4", quantize_params(params, bits=4))]:
        eng = PagedServeEngine(model, p, max_batch=4, max_seq=64,
                               page_size=8, prefill_chunk=16)
        reqs = [ServeRequest(prompt=pr, max_new_tokens=args.tokens,
                             rid=i, sampling=sampling)
                for i, pr in enumerate(prompts)]
        t0 = time.monotonic()
        eng.run(reqs)
        dt = time.monotonic() - t0
        m = eng.summary()
        frac = quantized_fraction(p) if label != "bf16" else 0.0
        print(f"[{label}] {int(m['tokens'])} tokens in {dt:.1f}s  "
              f"({eng.throughput():.0f} tok/s decode, "
              f"ttft p50/p99 {m['ttft_p50_s']*1e3:.0f}/"
              f"{m['ttft_p99_s']*1e3:.0f} ms, "
              f"kv occupancy peak {m['kv_occupancy_peak']*100:.0f}%, "
              f"{frac*100:.0f}% bytes quantized)")

    # hardware side: what the EdgeCIM accelerator would do at full scale
    res = run_dse(PAPER_SLMS["llama3.2-1b"], alpha=1.0, w_bits=4, seed=0)
    rep = res.best_report
    print(f"[EdgeCIM sim] llama3.2-1b INT4 on h*: {rep.tokens_per_s:.0f} "
          f"tok/s, {rep.tokens_per_j:.0f} tok/J, {rep.area_mm2:.1f} mm^2")


if __name__ == "__main__":
    main()
