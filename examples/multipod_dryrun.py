"""Multi-pod dry-run walkthrough: lower one cell on the 512-chip mesh and
print its roofline terms.  (The full 40-cell suite is
scripts/run_dryrun_suite.sh; results land in results/dryrun/.)

  PYTHONPATH=src python examples/multipod_dryrun.py --arch gemma3-4b \
      --shape decode_32k --quant int4
"""
import argparse
import subprocess
import sys
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--quant", default="int4")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--quant", args.quant]
    if args.multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
