"""Quickstart: the whole stack in two minutes on a laptop CPU.

1. EdgeCIM DSE: find the optimal CIM config for an SLM (the paper's flow).
2. Train a tiny decoder LM on the synthetic Markov stream.
3. Quantize it to INT4 and serve batched requests.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax


def main():
    # ---- 1. hardware-software co-design (the paper's contribution) -----
    from repro.configs.paper_slms import PAPER_SLMS
    from repro.core import run_dse
    res = run_dse(PAPER_SLMS["llama3.2-1b"], alpha=1.0, w_bits=4, seed=0)
    rep = res.best_report
    print(f"[DSE] LLaMA3.2-1B INT4 optimal h*: {res.best}")
    print(f"[DSE] {rep.tokens_per_s:.1f} tok/s, {rep.tokens_per_j:.1f} "
          f"tok/J, {rep.area_mm2:.1f} mm^2 (paper: ~400 tok/s, ~181 tok/J)")

    # ---- 2. train a tiny LM --------------------------------------------
    from repro.data import DataConfig, SyntheticLM
    from repro.models import DecoderLM, ModelConfig
    from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                      head_dim=16, dtype="float32", remat=False)
    model = DecoderLM(cfg)
    data = SyntheticLM(DataConfig(vocab=64, seq_len=64, global_batch=8))
    tr = Trainer(model, AdamW(lr=cosine_schedule(3e-3, 10, 100)), data,
                 TrainConfig(steps=100, log_every=25))
    out = tr.run()
    print(f"[train] loss {out['losses'][0]:.2f} -> {out['losses'][-1]:.2f} "
          f"(bigram floor {data.bigram_entropy():.2f})")

    # ---- 3. quantize + serve -------------------------------------------
    from repro.quant import quantize_params
    from repro.serve import Request, ServeEngine
    qparams = quantize_params(out["params"], bits=4, group=16)
    eng = ServeEngine(model, qparams, n_slots=4, max_seq=128)
    prompts = [data.batch(1000 + i)["tokens"][0, :8].astype(np.int32)
               for i in range(6)]
    reqs = eng.run([Request(prompt=p, max_new_tokens=16, rid=i)
                    for i, p in enumerate(prompts)])
    print(f"[serve] {len(reqs)} requests, INT4 weights, "
          f"{eng.throughput():.0f} tok/s on {jax.default_backend()}")
    print("[serve] sample:", reqs[0].out_tokens)


if __name__ == "__main__":
    main()
