#!/usr/bin/env python
"""Offline viewer for Chrome trace-event dumps (`/debug/trace`).

Perfetto answers "show me the timeline"; this answers the two questions
you ask before opening a UI at all:

  * which requests were slowest, and where did their time go
    (queue vs prefill vs decode), and
  * what does a decode step cost per phase across the whole capture.

Usage:
    python tools/trace_view.py trace.json [--top 10]
    curl -s localhost:8151/debug/trace | python tools/trace_view.py -

Works on the exact JSON the gateway serves (or api_bench --trace
saves): request correlation uses the `rid`/`rids` args every span
carries, so a request's engine time is attributed even though its spans
ran on a different thread than its gateway lifecycle.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List


def load_events(path: str) -> List[Dict]:
    fh = sys.stdin if path == "-" else open(path)
    try:
        doc = json.load(fh)
    finally:
        if fh is not sys.stdin:
            fh.close()
    return doc["traceEvents"] if isinstance(doc, dict) else doc


def _ms(us: float) -> str:
    return f"{us / 1e3:10.3f}ms"


def phase_breakdown(events: List[Dict]) -> Dict[str, Dict[str, float]]:
    """Aggregate complete spans by name: count, total ms, mean us."""
    agg: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"n": 0, "total_us": 0.0})
    for ev in events:
        if ev.get("ph") == "X":
            a = agg[ev["name"]]
            a["n"] += 1
            a["total_us"] += ev.get("dur", 0.0)
    return agg


def per_request(events: List[Dict]) -> Dict[int, Dict]:
    """Roll spans up per request id.

    The gateway's `request` span gives wall time; engine spans carrying
    this rid in `args.rids` contribute their duration split by name.
    An engine span shared by k requests (one batched decode step) is
    charged to each in full — it is wall time the request spent inside
    that phase, not an exclusive-cost accounting.
    """
    reqs: Dict[int, Dict] = {}

    def entry(rid: int) -> Dict:
        return reqs.setdefault(rid, {"wall_us": None, "status": "?",
                                     "tokens": 0,
                                     "phases": defaultdict(float)})

    for ev in events:
        args = ev.get("args") or {}
        if ev.get("ph") == "X" and ev.get("cat") == "gateway" \
                and ev.get("name") == "request":
            e = entry(args.get("rid", -1))
            e["wall_us"] = ev.get("dur", 0.0)
            e["status"] = args.get("status", "?")
            e["tokens"] = args.get("tokens", 0)
        elif ev.get("ph") == "X" and "rids" in args:
            for rid in args["rids"]:
                entry(rid)["phases"][ev["name"]] += ev.get("dur", 0.0)
    return reqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON path, or - for stdin")
    ap.add_argument("--top", type=int, default=10,
                    help="slowest requests to list (default 10)")
    args = ap.parse_args(argv)

    events = load_events(args.trace)
    if not events:
        print("empty trace")
        return 1

    print(f"{len(events)} events")
    print("\n== per-phase span breakdown ==")
    print(f"{'span':<16}{'count':>8}{'total':>14}{'mean':>14}")
    agg = phase_breakdown(events)
    for name in sorted(agg, key=lambda n: -agg[n]["total_us"]):
        a = agg[name]
        print(f"{name:<16}{int(a['n']):>8}{_ms(a['total_us']):>14}"
              f"{_ms(a['total_us'] / a['n']):>14}")

    reqs = {rid: r for rid, r in per_request(events).items()
            if r["wall_us"] is not None}
    if reqs:
        print(f"\n== top {args.top} slowest requests "
              f"(of {len(reqs)} with a gateway span) ==")
        print(f"{'rid':>6} {'status':<11}{'tokens':>7}{'wall':>13}"
              f"   phase time")
        by_wall = sorted(reqs.items(), key=lambda kv: -kv[1]["wall_us"])
        for rid, r in by_wall[:args.top]:
            phases = "  ".join(
                f"{n}={p / 1e3:.2f}ms"
                for n, p in sorted(r["phases"].items(),
                                   key=lambda kv: -kv[1]))
            print(f"{rid:>6} {r['status']:<11}{r['tokens']:>7}"
                  f"{_ms(r['wall_us']):>13}   {phases}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
