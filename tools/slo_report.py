#!/usr/bin/env python
"""Offline SLO/drift report for `/debug/slo` dumps.

`/metrics` answers "what is the alert level right now"; this answers
the two questions you ask during (or after) an incident:

  * which SLO burned, in which scope, and how the page/warn levels
    evolved over the run (the transition timeline with burn rates), and
  * is the digital twin still honest — per replica, how far simulator-
    predicted decode time drifted from measured, and when the CUSUM
    tripped.

Usage:
    python tools/slo_report.py results/benchmarks/api_bench_slo.slo.json
    curl -s localhost:8151/debug/slo | python tools/slo_report.py -

Works on the exact JSON the gateway serves at GET /debug/slo (or
api_bench --slo saves as `<out>.slo.json`).  Exit code is 0 whenever
the document parses; pass --strict to exit 1 if any scope sits at
`page` or any replica's drift alarm is latched — handy as a cheap gate
outside the full check_bench run.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List


def load(path: str) -> Dict:
    fh = sys.stdin if path == "-" else open(path)
    try:
        return json.load(fh)
    finally:
        if fh is not sys.stdin:
            fh.close()


def _num(v, fmt: str = "{:.3f}") -> str:
    if v is None:
        return "-"
    try:
        f = float(v)
    except (TypeError, ValueError):
        return str(v)
    if math.isnan(f):
        return "-"
    return fmt.format(f)


def print_slos(doc: Dict) -> None:
    slos = doc.get("slos") or []
    pol = doc.get("policy") or {}
    print(f"== {len(slos)} SLO(s), timescale "
          f"{_num(pol.get('timescale'), '{:g}')} ==")
    for s in slos:
        print(f"  {s['name']:<16} {s.get('kind', '?'):<10} "
              f"spec: {s.get('spec', '?')}   "
              f"budget {_num(s.get('budget'), '{:.4g}')}")
    wins = (pol.get("windows") or {})
    for lvl in ("page", "warn"):
        w = wins.get(lvl)
        if w:
            print(f"  {lvl}: burn >= {_num(w.get('burn'), '{:g}')} over "
                  f"{_num(w.get('long_s'), '{:g}')}s AND "
                  f"{_num(w.get('short_s'), '{:g}')}s windows")


def print_states(doc: Dict) -> None:
    states = doc.get("states") or []
    print(f"\n== alert states (worst: {doc.get('worst', '?')}) ==")
    if not states:
        print("  (no scopes ingested yet)")
        return
    print(f"  {'scope':<14}{'slo':<16}{'level':<7}"
          f"{'burn_pg_long':>13}{'burn_pg_short':>14}"
          f"{'bad/events':>16}")
    order = {"page": 0, "warn": 1, "ok": 2}
    for st in sorted(states, key=lambda s: (order.get(s.get("level"), 3),
                                            s.get("scope", ""),
                                            s.get("slo", ""))):
        burn = st.get("burn") or {}
        print(f"  {st.get('scope', '?'):<14}{st.get('slo', '?'):<16}"
              f"{st.get('level', '?'):<7}"
              f"{_num(burn.get('page_long')):>13}"
              f"{_num(burn.get('page_short')):>14}"
              f"{_num(st.get('bad_total'), '{:g}'):>9}/"
              f"{_num(st.get('events_total'), '{:g}')}")


def print_transitions(doc: Dict, top: int) -> None:
    trans = doc.get("transitions") or []
    print(f"\n== {len(trans)} alert transition(s)"
          + (f" (last {top})" if len(trans) > top else "") + " ==")
    for ev in trans[-top:]:
        print(f"  t={_num(ev.get('t_s'), '{:.2f}')}s  "
              f"{ev.get('scope', '?')}/{ev.get('slo', '?')}: "
              f"{ev.get('from', '?')} -> {ev.get('to', '?')}  "
              f"(burn long {_num(ev.get('burn_long'))}, "
              f"short {_num(ev.get('burn_short'))})")


def print_drift(doc: Dict, top: int) -> List[str]:
    """Per-replica twin-audit verdicts; returns replica ids whose alarm
    is latched."""
    drift = doc.get("drift") or {}
    alarmed: List[str] = []
    print(f"\n== sim-vs-measured drift ({len(drift)} replica(s)) ==")
    if not drift:
        print("  (no replicas reporting)")
        return alarmed
    for rid in sorted(drift):
        d = drift[rid]
        ratio = d.get("sim_drift_ratio")
        alarm = bool(d.get("sim_drift_alarm"))
        if alarm:
            alarmed.append(rid)
        try:
            calibrated = ratio is not None and not math.isnan(float(ratio))
        except (TypeError, ValueError):
            calibrated = False
        verdict = ("ALARM" if alarm
                   else "ok" if calibrated else "uncalibrated")
        print(f"  replica {rid}: {verdict:<13}"
              f"ratio {_num(ratio):<8}"
              f"cusum {_num(d.get('sim_drift_cusum')):<8}"
              f"alarms {_num(d.get('sim_drift_alarms'), '{:g}'):<4}"
              f"ticks {_num(d.get('sim_drift_ticks'), '{:g}')}")
        for ev in (d.get("events") or [])[-top:]:
            print(f"    t={_num(ev.get('t_s'), '{:.2f}')}s  "
                  f"{ev.get('direction', '?')}  "
                  f"ratio {_num(ev.get('ratio'))}  "
                  f"cusum {_num(ev.get('cusum'))}")
    return alarmed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("payload",
                    help="/debug/slo JSON path (api_bench --slo writes "
                         "<out>.slo.json), or - for stdin")
    ap.add_argument("--top", type=int, default=20,
                    help="transitions / drift events to list (default 20)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any scope is at page level or any "
                         "replica's drift alarm is latched")
    args = ap.parse_args(argv)

    doc = load(args.payload)
    print_slos(doc)
    print_states(doc)
    print_transitions(doc, args.top)
    alarmed = print_drift(doc, args.top)

    paged = doc.get("worst") == "page"
    print(f"\nverdict: worst alert level {doc.get('worst', '?')}, "
          f"{len(alarmed)} replica(s) with latched drift alarm")
    if args.strict and (paged or alarmed):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
