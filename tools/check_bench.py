"""CI benchmark regression gate.

Compares bench-smoke JSON output against the committed baselines in
`results/benchmarks/` and fails (exit 1) when a metric regresses beyond
tolerance — so a TTFT/TPOT, tokens/step, acceptance-rate, or
prefix-cache regression can no longer merge silently.

Rows are matched on their identity fields (workload/drafter/k for
spec_bench, batch/mix/mode for serve_bench); metrics are classified as

  quality  deterministic given seed + config (acceptance rate, tokens
           per step, KV savings, prefill tokens skipped, hit rate) —
           tight tolerance, and a DROPPED row is itself a failure
  timing   machine-dependent (TTFT, TPOT, tokens/s, wall) — loose
           tolerance sized for noisy shared CI runners

Usage:
  python tools/check_bench.py --current /tmp/bench-out \
      [--baseline results/benchmarks] [--timing-tol 1.0]
      [--quality-tol 0.15] [--update]

`--update` rewrites the baselines from --current instead of checking
(run locally after an intentional perf change, then commit).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import sys
from typing import Dict, List, Tuple

# identity fields: define WHICH row we compare, never gated themselves
IDENTITY = ("mode", "family", "mix", "workload", "drafter", "k", "batch",
            "n_requests", "prefix_len", "rate", "n", "replicas", "policy",
            "tracing")

# (substring, direction, class); first match wins.  direction "higher"
# means bigger is better.  Metrics matching nothing are informational.
METRIC_RULES: List[Tuple[str, str, str]] = [
    ("outputs_byte_identical", "higher", "quality"),
    ("acceptance_rate", "higher", "quality"),
    ("tokens_per_step", "higher", "quality"),
    ("kv_savings", "higher", "quality"),
    ("prefill_tokens_skipped", "higher", "quality"),
    ("prefix_hit_rate", "higher", "quality"),
    ("pairs_identical", "higher", "quality"),
    ("affinity_hits", "higher", "quality"),
    ("sim_speedup", "higher", "quality"),
    ("completed", "higher", "quality"),
    ("ttft_speedup", "higher", "timing"),
    ("goodput", "higher", "timing"),    # before tokens_per_s: it also
    ("tokens_per_s", "higher", "timing"),   # substring-matches goodput_*
    ("ttft", "lower", "timing"),
    ("tpot", "lower", "timing"),
    ("itl", "lower", "timing"),
    ("queue", "lower", "timing"),
    ("wall_s", "lower", "timing"),
]


def classify(name: str):
    for pat, direction, klass in METRIC_RULES:
        if pat in name:
            return direction, klass
    return None


def row_key(row: Dict) -> Tuple:
    return tuple((k, row[k]) for k in IDENTITY if k in row)


def fmt(v: float) -> str:
    return f"{v:.4g}"


def check_file(name: str, baseline: List[Dict], current: List[Dict],
               tols: Dict[str, float]) -> List[str]:
    failures: List[str] = []
    cur_by_key = {row_key(r): r for r in current}
    for brow in baseline:
        key = row_key(brow)
        label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
        crow = cur_by_key.get(key)
        if crow is None:
            failures.append(f"{label}: row missing from current run")
            continue
        for metric, bval in brow.items():
            rule = classify(metric)
            if rule is None or not isinstance(bval, (int, float, bool)):
                continue
            direction, klass = rule
            cval = crow.get(metric)
            if cval is None:
                failures.append(f"{label}.{metric}: metric disappeared")
                continue
            b, c = float(bval), float(cval)
            if math.isnan(b):
                continue
            if math.isnan(c):
                # a metric that WAS measurable degrading to NaN (e.g.
                # acceptance rate with zero drafts) is a regression,
                # not a skip
                failures.append(
                    f"{label}.{metric}: NaN vs baseline {fmt(b)}")
                continue
            tol = tols[klass]
            # symmetric ratio band with a small absolute floor so
            # near-zero baselines don't demand exact equality: tol=1.0
            # tolerates a 2x-worse current in EITHER direction
            # (lower-better: c <= 2b; higher-better: c >= b/2) — an
            # additive band would make higher-is-better metrics
            # ungateable at tol >= 1.0
            bad = (c < b / (1.0 + tol) - 1e-9) if direction == "higher" \
                else (c > b * (1.0 + tol) + 1e-9)
            if bad:
                arrow = "<" if direction == "higher" else ">"
                failures.append(
                    f"{label}.{metric}: {fmt(c)} {arrow} baseline "
                    f"{fmt(b)} beyond {klass} tol {tol:.0%}")
    return failures


def check_scaling(name: str, current: List[Dict],
                  scaling_min: float) -> List[str]:
    """Fleet goodput-scaling gate, judged WITHIN the current run (no
    baseline involved): rows that differ only in `replicas` must show
    N-replica goodput >= scaling_min x the 1-replica goodput at the
    same offered load.  Catches a routing/dispatch regression that
    makes extra replicas useless while every per-row metric still looks
    individually healthy."""
    failures: List[str] = []
    groups: Dict[Tuple, Dict[int, Dict]] = {}
    for r in current:
        if "replicas" not in r or "goodput_tokens_per_s" not in r:
            continue
        key = tuple((k, r[k]) for k in IDENTITY
                    if k in r and k != "replicas")
        groups.setdefault(key, {})[int(r["replicas"])] = r
    for key, by_rep in groups.items():
        base_row = by_rep.get(1)
        if base_row is None or not base_row["goodput_tokens_per_s"]:
            continue
        base = float(base_row["goodput_tokens_per_s"])
        label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
        for nrep in sorted(by_rep):
            if nrep == 1:
                continue
            ratio = float(by_rep[nrep]["goodput_tokens_per_s"]) / base
            if ratio < scaling_min - 1e-9:
                failures.append(
                    f"{label}: {nrep}-replica goodput only "
                    f"{ratio:.2f}x the 1-replica run "
                    f"(need >= {scaling_min:g}x)")
    return failures


def check_tracing_overhead(name: str, current: List[Dict],
                           overhead_max: float) -> List[str]:
    """Tracing-overhead gate, judged WITHIN the current run: rows that
    differ only in `tracing` (api_bench --trace emits each cell as an
    off/on pair) must show traced goodput within `overhead_max` of the
    untraced goodput.  The tracer bills itself as near-zero-cost when
    enabled and free when disabled — this is where that claim is
    enforced, on the same machine in the same run, so runner speed
    cancels out."""
    failures: List[str] = []
    groups: Dict[Tuple, Dict[bool, Dict]] = {}
    for r in current:
        if "tracing" not in r or "goodput_tokens_per_s" not in r:
            continue
        key = tuple((k, r[k]) for k in IDENTITY
                    if k in r and k != "tracing")
        groups.setdefault(key, {})[bool(r["tracing"])] = r
    for key, by_mode in groups.items():
        off, on = by_mode.get(False), by_mode.get(True)
        if off is None or on is None:
            continue
        base = float(off["goodput_tokens_per_s"])
        if not base or math.isnan(base):
            continue
        ratio = float(on["goodput_tokens_per_s"]) / base
        if ratio < 1.0 - overhead_max - 1e-9:
            label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
            failures.append(
                f"{label}: tracing costs {(1.0 - ratio):.1%} goodput "
                f"({fmt(float(on['goodput_tokens_per_s']))} vs "
                f"{fmt(base)} untraced; allowed {overhead_max:.0%})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "results", "benchmarks"))
    ap.add_argument("--current", required=True,
                    help="directory holding this run's bench JSON")
    ap.add_argument("--names", nargs="+", default=None,
                    help="bench names to gate (default: every baseline "
                         "JSON present in --current)")
    ap.add_argument("--timing-tol", type=float, default=1.0,
                    help="allowed relative worsening for timing metrics "
                         "(1.0 = 2x worse still passes — 2x slower for "
                         "lower-is-better, half throughput for "
                         "higher-is-better; CI runners are noisy)")
    ap.add_argument("--quality-tol", type=float, default=0.15,
                    help="allowed relative worsening for deterministic "
                         "quality metrics")
    ap.add_argument("--scaling-min", type=float, default=1.5,
                    help="minimum N-replica/1-replica goodput ratio for "
                         "fleet bench rows differing only in `replicas` "
                         "(judged within the current run; lower it on "
                         "single-core runners, where scaling comes from "
                         "admission capacity alone, not parallel "
                         "compute)")
    ap.add_argument("--trace-overhead-max", type=float, default=0.05,
                    help="max goodput lost to tracing, judged within "
                         "the current run on rows differing only in "
                         "`tracing` (api_bench --trace off/on pairs)")
    ap.add_argument("--update", action="store_true",
                    help="overwrite baselines from --current")
    args = ap.parse_args()

    names = args.names
    if names is None:
        # every committed baseline is gated: a bench that stopped
        # producing output must FAIL below, not silently drop out of
        # the comparison set
        # *.trace.json are Chrome trace artifacts riding alongside the
        # row JSON (api_bench --trace), not gateable bench output
        names = sorted(f[:-5] for f in os.listdir(args.baseline)
                       if f.endswith(".json")
                       and not f.endswith(".trace.json"))
        if args.update:
            # adopt benches that have no baseline yet (a new bench's
            # first --update run commits its initial rows)
            names = sorted(set(names)
                           | {f[:-5] for f in os.listdir(args.current)
                              if f.endswith(".json")
                              and not f.endswith(".trace.json")})
    if not names:
        print("check_bench: no baseline bench JSON found", file=sys.stderr)
        return 1

    if args.update:
        for n in names:
            src = os.path.join(args.current, n + ".json")
            if not os.path.exists(src):
                print(f"check_bench: {n}.json not in --current, baseline "
                      "kept")
                continue
            shutil.copy(src, os.path.join(args.baseline, n + ".json"))
            print(f"check_bench: baseline {n}.json updated")
        return 0

    tols = {"quality": args.quality_tol, "timing": args.timing_tol}
    all_failures: List[str] = []
    for n in names:
        with open(os.path.join(args.baseline, n + ".json")) as f:
            baseline = json.load(f)
        cur_path = os.path.join(args.current, n + ".json")
        if not os.path.exists(cur_path):
            all_failures.append(f"{n}: bench produced no JSON this run")
            print(f"check_bench: {n}: MISSING from current run [FAIL]")
            continue
        with open(cur_path) as f:
            current = json.load(f)
        fails = check_file(n, baseline, current, tols)
        fails += check_scaling(n, current, args.scaling_min)
        fails += check_tracing_overhead(n, current,
                                        args.trace_overhead_max)
        status = "FAIL" if fails else "ok"
        print(f"check_bench: {n}: {len(baseline)} baseline rows, "
              f"{len(fails)} regressions [{status}]")
        all_failures.extend(fails)

    for f in all_failures:
        print(f"  REGRESSION {f}", file=sys.stderr)
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main())
