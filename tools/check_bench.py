"""CI benchmark regression gate.

Compares bench-smoke JSON output against the committed baselines in
`results/benchmarks/` and fails (exit 1) when a metric regresses beyond
tolerance — so a TTFT/TPOT, tokens/step, acceptance-rate, or
prefix-cache regression can no longer merge silently.

Rows are matched on their identity fields (workload/drafter/k for
spec_bench, batch/mix/mode for serve_bench); metrics are classified as

  quality  deterministic given seed + config (acceptance rate, tokens
           per step, KV savings, prefill tokens skipped, hit rate) —
           tight tolerance, and a DROPPED row is itself a failure
  timing   machine-dependent (TTFT, TPOT, tokens/s, wall) — loose
           tolerance sized for noisy shared CI runners

Usage:
  python tools/check_bench.py --current /tmp/bench-out \
      [--baseline results/benchmarks] [--timing-tol 1.0]
      [--quality-tol 0.15] [--update]

`--update` rewrites the baselines from --current instead of checking
(run locally after an intentional perf change, then commit).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import sys
from typing import Dict, List, Tuple

# identity fields: define WHICH row we compare, never gated themselves
IDENTITY = ("mode", "family", "mix", "workload", "drafter", "k", "batch",
            "n_requests", "prefix_len", "rate", "n", "replicas", "policy",
            "tracing", "precision", "tp", "slo")

# (substring, direction, class); first match wins.  direction "higher"
# means bigger is better.  Metrics matching nothing are informational.
METRIC_RULES: List[Tuple[str, str, str]] = [
    ("outputs_byte_identical", "higher", "quality"),
    ("acceptance_rate", "higher", "quality"),
    ("tokens_per_step", "higher", "quality"),
    ("kv_savings", "higher", "quality"),
    ("prefill_tokens_skipped", "higher", "quality"),
    ("prefix_hit_rate", "higher", "quality"),
    ("pairs_identical", "higher", "quality"),
    ("affinity_hits", "higher", "quality"),
    ("sim_speedup", "higher", "quality"),
    ("completed", "higher", "quality"),
    # quantized serving (api_bench --precision): deterministic given
    # seed + config, so they gate at quality tolerance
    ("quality_logit_mse", "lower", "quality"),
    ("quality_greedy_match_len", "higher", "quality"),
    ("kv_lanes_ratio", "higher", "quality"),
    # baseline is 0: ANY traced full-weight dequant on a quantized row
    # fails (the symmetric band collapses to equality at b == 0)
    ("weight_full_dequants", "lower", "quality"),
    ("ttft_speedup", "higher", "timing"),
    ("goodput", "higher", "timing"),    # before tokens_per_s: it also
    ("tokens_per_s", "higher", "timing"),   # substring-matches goodput_*
    ("ttft", "lower", "timing"),
    ("tpot", "lower", "timing"),
    ("itl", "lower", "timing"),
    ("queue", "lower", "timing"),
    ("wall_s", "lower", "timing"),
]


def classify(name: str):
    for pat, direction, klass in METRIC_RULES:
        if pat in name:
            return direction, klass
    return None


def row_key(row: Dict) -> Tuple:
    return tuple((k, row[k]) for k in IDENTITY if k in row)


def fmt(v: float) -> str:
    return f"{v:.4g}"


def check_file(name: str, baseline: List[Dict], current: List[Dict],
               tols: Dict[str, float]) -> List[str]:
    failures: List[str] = []
    cur_by_key = {row_key(r): r for r in current}
    for brow in baseline:
        key = row_key(brow)
        label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
        crow = cur_by_key.get(key)
        if crow is None:
            failures.append(f"{label}: row missing from current run")
            continue
        for metric, bval in brow.items():
            rule = classify(metric)
            if rule is None or not isinstance(bval, (int, float, bool)):
                continue
            direction, klass = rule
            if (isinstance(bval, float) and math.isnan(bval)):
                # baseline never measured this metric; an absent current
                # value is the expected encoding (exporters drop
                # unmeasured series rather than emit NaN), not a
                # disappearance
                continue
            cval = crow.get(metric)
            if cval is None:
                failures.append(f"{label}.{metric}: metric disappeared")
                continue
            b, c = float(bval), float(cval)
            if math.isnan(c):
                # a metric that WAS measurable degrading to NaN (e.g.
                # acceptance rate with zero drafts) is a regression,
                # not a skip
                failures.append(
                    f"{label}.{metric}: NaN vs baseline {fmt(b)}")
                continue
            tol = tols[klass]
            # symmetric ratio band with a small absolute floor so
            # near-zero baselines don't demand exact equality: tol=1.0
            # tolerates a 2x-worse current in EITHER direction
            # (lower-better: c <= 2b; higher-better: c >= b/2) — an
            # additive band would make higher-is-better metrics
            # ungateable at tol >= 1.0
            bad = (c < b / (1.0 + tol) - 1e-9) if direction == "higher" \
                else (c > b * (1.0 + tol) + 1e-9)
            if bad:
                arrow = "<" if direction == "higher" else ">"
                failures.append(
                    f"{label}.{metric}: {fmt(c)} {arrow} baseline "
                    f"{fmt(b)} beyond {klass} tol {tol:.0%}")
    return failures


def check_scaling(name: str, current: List[Dict],
                  scaling_min: float) -> List[str]:
    """Fleet goodput-scaling gate, judged WITHIN the current run (no
    baseline involved): rows that differ only in `replicas` must show
    N-replica goodput >= scaling_min x the 1-replica goodput at the
    same offered load.  Catches a routing/dispatch regression that
    makes extra replicas useless while every per-row metric still looks
    individually healthy."""
    failures: List[str] = []
    groups: Dict[Tuple, Dict[int, Dict]] = {}
    for r in current:
        if "replicas" not in r or "goodput_tokens_per_s" not in r:
            continue
        key = tuple((k, r[k]) for k in IDENTITY
                    if k in r and k != "replicas")
        groups.setdefault(key, {})[int(r["replicas"])] = r
    for key, by_rep in groups.items():
        base_row = by_rep.get(1)
        if base_row is None or not base_row["goodput_tokens_per_s"]:
            continue
        base = float(base_row["goodput_tokens_per_s"])
        label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
        for nrep in sorted(by_rep):
            if nrep == 1:
                continue
            ratio = float(by_rep[nrep]["goodput_tokens_per_s"]) / base
            if ratio < scaling_min - 1e-9:
                failures.append(
                    f"{label}: {nrep}-replica goodput only "
                    f"{ratio:.2f}x the 1-replica run "
                    f"(need >= {scaling_min:g}x)")
    return failures


def check_tracing_overhead(name: str, current: List[Dict],
                           overhead_max: float) -> List[str]:
    """Tracing-overhead gate, judged WITHIN the current run: rows that
    differ only in `tracing` (api_bench --trace emits each cell as an
    off/on pair) must show traced goodput within `overhead_max` of the
    untraced goodput.  The tracer bills itself as near-zero-cost when
    enabled and free when disabled — this is where that claim is
    enforced, on the same machine in the same run, so runner speed
    cancels out."""
    failures: List[str] = []
    groups: Dict[Tuple, Dict[bool, Dict]] = {}
    for r in current:
        if "tracing" not in r or "goodput_tokens_per_s" not in r:
            continue
        key = tuple((k, r[k]) for k in IDENTITY
                    if k in r and k != "tracing")
        groups.setdefault(key, {})[bool(r["tracing"])] = r
    for key, by_mode in groups.items():
        off, on = by_mode.get(False), by_mode.get(True)
        if off is None or on is None:
            continue
        base = float(off["goodput_tokens_per_s"])
        if not base or math.isnan(base):
            continue
        ratio = float(on["goodput_tokens_per_s"]) / base
        if ratio < 1.0 - overhead_max - 1e-9:
            label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
            failures.append(
                f"{label}: tracing costs {(1.0 - ratio):.1%} goodput "
                f"({fmt(float(on['goodput_tokens_per_s']))} vs "
                f"{fmt(base)} untraced; allowed {overhead_max:.0%})")
    return failures


def check_quant_quality(name: str, current: List[Dict],
                        match_min: float, mse_max: float) -> List[str]:
    """Quantization quality gate, judged WITHIN the current run on rows
    labeled with a quantized `precision`: the greedy decode must track
    the fp stack for at least `match_min` of the probe window, and the
    probe logit MSE must stay under `mse_max`.  Absolute floors (not
    baseline ratios): a quantization bug that halves quality along with
    its own baseline would sail through a relative check."""
    failures: List[str] = []
    for r in current:
        if r.get("precision") not in ("int8", "int4"):
            continue
        label = name + "[" + ",".join(
            f"{k}={v}" for k, v in row_key(r)) + "]"
        total = float(r.get("quality_greedy_tokens", 0.0))
        match = float(r.get("quality_greedy_match_len", float("nan")))
        if total and match / total < match_min - 1e-9:
            failures.append(
                f"{label}: greedy decode diverged from fp after "
                f"{match:.0f}/{total:.0f} tokens (floor "
                f"{match_min:.0%} of the probe window)")
        mse = float(r.get("quality_logit_mse", float("nan")))
        if not math.isnan(mse) and mse > mse_max + 1e-12:
            failures.append(
                f"{label}: probe logit MSE {fmt(mse)} exceeds ceiling "
                f"{fmt(mse_max)}")
    return failures


def check_quant_energy(name: str, current: List[Dict],
                       energy_min: float) -> List[str]:
    """INT4 efficiency gate, judged WITHIN the current run: rows
    differing only in `precision` must show int4 sim_tokens_per_j at
    least `energy_min` x the fp row's — the paper's headline claim
    (the INT4 CIM operating point buys real energy efficiency), kept
    true by construction in the cost model and enforced here against
    accounting regressions (e.g. the meter silently refitting every
    precision at the same bit width)."""
    failures: List[str] = []
    groups: Dict[Tuple, Dict[str, Dict]] = {}
    for r in current:
        if "precision" not in r or "sim_tokens_per_j" not in r:
            continue
        key = tuple((k, r[k]) for k in IDENTITY
                    if k in r and k != "precision")
        groups.setdefault(key, {})[r["precision"]] = r
    for key, by_prec in groups.items():
        fp, q4 = by_prec.get("fp"), by_prec.get("int4")
        if fp is None or q4 is None:
            continue
        base = float(fp["sim_tokens_per_j"])
        if not base or math.isnan(base):
            continue
        ratio = float(q4["sim_tokens_per_j"]) / base
        if ratio < energy_min - 1e-9:
            label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
            failures.append(
                f"{label}: int4 sim_tokens_per_j only {ratio:.2f}x fp "
                f"(need >= {energy_min:g}x)")
    return failures


def check_tp_identity(name: str, current: List[Dict],
                      goodput_min: float) -> List[str]:
    """Tensor-parallel identity gate, judged WITHIN the current run:
    rows differing only in `tp` (api_bench --tp sweep) must serve
    byte-identical greedy streams — `greedy_digest` hashes every
    completed request's token list against its request index, and the
    arrival schedule is seed-deterministic, so tp=1 and tp=2 cells of
    the same sweep hash the same traffic.  Goodput must also stay
    within `goodput_min` x the tp=1 row: on the forced host-CPU mesh
    the collectives serialize, so the gate is identity + no collapse,
    not acceleration.  Skipped when either cell shed 429s (the shed
    sets are timing-dependent, so the digests stop being comparable —
    but a shed in the smoke cell already fails the `completed` gate)."""
    failures: List[str] = []
    groups: Dict[Tuple, Dict[int, Dict]] = {}
    for r in current:
        if "tp" not in r:
            continue
        key = tuple((k, r[k]) for k in IDENTITY if k in r and k != "tp")
        groups.setdefault(key, {})[int(r["tp"])] = r
    for key, by_tp in groups.items():
        base = by_tp.get(1)
        if base is None:
            continue
        label = name + "[" + ",".join(f"{k}={v}" for k, v in key) + "]"
        for ntp in sorted(by_tp):
            if ntp == 1:
                continue
            row = by_tp[ntp]
            if row.get("rejected_429") or base.get("rejected_429"):
                continue
            bd, cd = base.get("greedy_digest"), row.get("greedy_digest")
            if bd is None or cd is None:
                failures.append(
                    f"{label}: tp sweep rows carry no greedy_digest")
                continue
            if bd != cd:
                failures.append(
                    f"{label}: tp={ntp} greedy streams diverged from "
                    f"tp=1 (digest {cd} != {bd}) — tensor parallelism "
                    "changed served bytes")
            bg = float(base.get("goodput_tokens_per_s", 0.0))
            if bg and not math.isnan(bg):
                ratio = float(row["goodput_tokens_per_s"]) / bg
                if ratio < goodput_min - 1e-9:
                    failures.append(
                        f"{label}: tp={ntp} goodput only {ratio:.2f}x "
                        f"the tp=1 run (need >= {goodput_min:g}x)")
    return failures


def check_slo(name: str, current: List[Dict],
              drift_max: float) -> List[str]:
    """SLO/drift gate, judged WITHIN the current run on rows labeled
    `slo` (api_bench --slo): the smoke cell runs under the default SLOs
    at a compressed burn-rate timescale, so a healthy engine must
    finish with no page-level alert fired, and the digital-twin audit's
    worst-replica `sim_drift_ratio` must stay inside
    [1/drift_max, drift_max] — a cost-model regression (simulator
    predictions walking away from measured decode time) or a latency
    collapse severe enough to page can no longer merge silently.  A NaN
    drift ratio means no replica calibrated (too few decode ticks) and
    is skipped, not failed: the page gate still covers that cell."""
    failures: List[str] = []
    for r in current:
        if not r.get("slo"):
            continue
        label = name + "[" + ",".join(
            f"{k}={v}" for k, v in row_key(r)) + "]"
        pages = int(r.get("slo_page_alerts", 0) or 0)
        if pages > 0:
            failures.append(
                f"{label}: {pages} page-level SLO alert(s) fired in the "
                f"smoke cell (worst level: {r.get('slo_worst', '?')})")
        ratio = float(r.get("sim_drift_ratio", float("nan")))
        if not math.isnan(ratio) and not (
                1.0 / drift_max - 1e-9 <= ratio <= drift_max + 1e-9):
            failures.append(
                f"{label}: sim_drift_ratio {fmt(ratio)} outside "
                f"[{fmt(1.0 / drift_max)}, {fmt(drift_max)}] — simulator "
                "predictions drifted from measured decode time")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__), "..",
                                         "results", "benchmarks"))
    ap.add_argument("--current", required=True,
                    help="directory holding this run's bench JSON")
    ap.add_argument("--names", nargs="+", default=None,
                    help="bench names to gate (default: every baseline "
                         "JSON present in --current)")
    ap.add_argument("--timing-tol", type=float, default=1.0,
                    help="allowed relative worsening for timing metrics "
                         "(1.0 = 2x worse still passes — 2x slower for "
                         "lower-is-better, half throughput for "
                         "higher-is-better; CI runners are noisy)")
    ap.add_argument("--quality-tol", type=float, default=0.15,
                    help="allowed relative worsening for deterministic "
                         "quality metrics")
    ap.add_argument("--scaling-min", type=float, default=1.5,
                    help="minimum N-replica/1-replica goodput ratio for "
                         "fleet bench rows differing only in `replicas` "
                         "(judged within the current run; lower it on "
                         "single-core runners, where scaling comes from "
                         "admission capacity alone, not parallel "
                         "compute)")
    ap.add_argument("--trace-overhead-max", type=float, default=0.05,
                    help="max goodput lost to tracing, judged within "
                         "the current run on rows differing only in "
                         "`tracing` (api_bench --trace off/on pairs)")
    ap.add_argument("--quant-match-min", type=float, default=0.5,
                    help="quantized rows: minimum fraction of the "
                         "greedy probe window matching the fp stack "
                         "(absolute floor, judged within the current "
                         "run)")
    ap.add_argument("--quant-mse-max", type=float, default=1.0,
                    help="quantized rows: maximum probe logit MSE vs "
                         "the fp stack (absolute ceiling)")
    ap.add_argument("--quant-energy-min", type=float, default=2.0,
                    help="minimum int4/fp sim_tokens_per_j ratio on "
                         "rows differing only in `precision`")
    ap.add_argument("--tp-goodput-min", type=float, default=0.3,
                    help="minimum tp>1/tp=1 goodput ratio on rows "
                         "differing only in `tp` (judged within the "
                         "current run; byte-identity of the greedy "
                         "streams is always required — on a host-CPU "
                         "forced mesh no speedup is expected, only no "
                         "collapse)")
    ap.add_argument("--drift-max", type=float, default=3.0,
                    help="sim-vs-measured drift band on api_bench --slo "
                         "rows: worst-replica sim_drift_ratio must stay "
                         "within [1/drift-max, drift-max] (judged within "
                         "the current run; NaN = uncalibrated, skipped)")
    ap.add_argument("--update", action="store_true",
                    help="overwrite baselines from --current")
    args = ap.parse_args()

    names = args.names
    if names is None:
        # every committed baseline is gated: a bench that stopped
        # producing output must FAIL below, not silently drop out of
        # the comparison set
        # *.trace.json are Chrome trace artifacts riding alongside the
        # row JSON (api_bench --trace), not gateable bench output
        names = sorted(f[:-5] for f in os.listdir(args.baseline)
                       if f.endswith(".json")
                       and not f.endswith(".trace.json"))
        if args.update:
            # adopt benches that have no baseline yet (a new bench's
            # first --update run commits its initial rows)
            names = sorted(set(names)
                           | {f[:-5] for f in os.listdir(args.current)
                              if f.endswith(".json")
                              and not f.endswith(".trace.json")})
    if not names:
        print("check_bench: no baseline bench JSON found", file=sys.stderr)
        return 1

    if args.update:
        for n in names:
            src = os.path.join(args.current, n + ".json")
            if not os.path.exists(src):
                print(f"check_bench: {n}.json not in --current, baseline "
                      "kept")
                continue
            shutil.copy(src, os.path.join(args.baseline, n + ".json"))
            print(f"check_bench: baseline {n}.json updated")
        return 0

    tols = {"quality": args.quality_tol, "timing": args.timing_tol}
    all_failures: List[str] = []
    for n in names:
        with open(os.path.join(args.baseline, n + ".json")) as f:
            baseline = json.load(f)
        cur_path = os.path.join(args.current, n + ".json")
        if not os.path.exists(cur_path):
            all_failures.append(f"{n}: bench produced no JSON this run")
            print(f"check_bench: {n}: MISSING from current run [FAIL]")
            continue
        with open(cur_path) as f:
            current = json.load(f)
        fails = check_file(n, baseline, current, tols)
        fails += check_scaling(n, current, args.scaling_min)
        fails += check_tracing_overhead(n, current,
                                        args.trace_overhead_max)
        fails += check_quant_quality(n, current, args.quant_match_min,
                                     args.quant_mse_max)
        fails += check_quant_energy(n, current, args.quant_energy_min)
        fails += check_tp_identity(n, current, args.tp_goodput_min)
        fails += check_slo(n, current, args.drift_max)
        status = "FAIL" if fails else "ok"
        print(f"check_bench: {n}: {len(baseline)} baseline rows, "
              f"{len(fails)} regressions [{status}]")
        all_failures.extend(fails)

    for f in all_failures:
        print(f"  REGRESSION {f}", file=sys.stderr)
    return 1 if all_failures else 0


if __name__ == "__main__":
    sys.exit(main())
