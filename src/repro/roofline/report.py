"""Aggregate dry-run + probe JSONs into the EXPERIMENTS.md roofline table.

Per (arch x shape) cell it merges:
  * probe JSON (trip-count-correct flops / bytes / collective bytes),
  * full scanned-compile JSON (memory_analysis: peak HBM per device),
and derives the three roofline terms, dominant bottleneck, usefulness
ratios, and the roofline fraction:

    fraction = max(model_flops/PEAK, model_bytes/HBM) / bound_time

(model_bytes only for decode cells — decode moves bytes, not flops, so
its usefulness is bandwidth-side; train/prefill use the MFU-style
flops fraction.)

Usage:  PYTHONPATH=src python -m repro.roofline.report [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

from .analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def _is_baseline(fn: str) -> bool:
    # perf-iteration artifacts carry an extra __tag suffix; the baseline
    # table uses exactly arch__shape__mesh__quant.json
    return len(os.path.basename(fn)[:-5].split("__")) == 4


def load_results(dryrun_dir: str = "results/dryrun",
                 probe_dir: str = "results/probe") -> Dict:
    cells: Dict[tuple, Dict] = {}
    for fn in glob.glob(os.path.join(probe_dir, "*.json")):
        if not _is_baseline(fn):
            continue
        rec = json.load(open(fn))
        key = (rec["arch"], rec["shape"], rec["quant"])
        cells.setdefault(key, {})["probe"] = rec
    for fn in glob.glob(os.path.join(dryrun_dir, "*.json")):
        if not _is_baseline(fn):
            continue
        rec = json.load(open(fn))
        key = (rec["arch"], rec["shape"], rec["quant"])
        cells.setdefault(key, {})[f"full_{rec['mesh']}"] = rec
    return cells


def derive_row(arch: str, shape: str, quant: str, entry: Dict
               ) -> Optional[Dict]:
    probe = entry.get("probe")
    full = entry.get("full_single")
    if probe is None and full is None:
        return None
    src = probe or full
    from repro.configs.registry import SHAPES, get_config
    from repro.launch.params_count import decode_model_bytes
    kind = SHAPES[shape][2]
    n_dev = 256

    t_c = src["flops"] / PEAK_FLOPS
    t_m = src["hlo_bytes"] / HBM_BW
    t_x = src["collective_bytes"] / ICI_BW
    bound = max(t_c, t_m, t_x)
    dominant = {t_c: "compute", t_m: "memory", t_x: "collective"}[bound]

    model_flops = src["model_flops"]
    useful_t = model_flops / PEAK_FLOPS
    model_bytes = None
    if kind == "decode":
        cfg = get_config(arch)
        model_bytes = decode_model_bytes(cfg, shape, quant, n_dev)
        useful_t = max(useful_t, model_bytes / HBM_BW)
    fraction = useful_t / bound if bound else 0.0

    row = {
        "arch": arch, "shape": shape, "quant": quant, "kind": kind,
        "t_compute_ms": t_c * 1e3, "t_memory_ms": t_m * 1e3,
        "t_collective_ms": t_x * 1e3, "dominant": dominant,
        "model_flops": model_flops, "model_bytes": model_bytes,
        "useful_flops_ratio": (model_flops / src["flops"]
                               if src["flops"] else 0.0),
        "roofline_fraction": fraction,
        "source": "probe" if probe else "full(scan-undercounted)",
    }
    if full and full.get("memory_per_device"):
        row["peak_hbm_gib"] = full["memory_per_device"]["peak_bytes"] / 2**30
        row["fits_16g"] = row["peak_hbm_gib"] < 16.0
    if entry.get("full_multi"):
        row["multi_pod_ok"] = entry["full_multi"]["status"] == "ok"
    coll = src.get("collective_detail", {})
    if coll:
        top = max(coll, key=coll.get)
        row["top_collective"] = f"{top}:{coll[top]/2**20:.0f}MiB"
    return row


def bottleneck_sentence(row: Dict) -> str:
    """One sentence on what would move the dominant term down."""
    d = row["dominant"]
    if d == "collective":
        return ("reduce cross-device traffic: drop decode-path FSDP "
                "all-gathers / overlap collectives with compute"
                if row["kind"] == "decode" else
                "overlap FSDP all-gathers with layer compute; consider "
                "int8-compressed gradient reduction on the pod axis")
    if d == "memory":
        return ("quantize weights/KV (INT4 halves the stream) or split "
                "local-layer caches to window size"
                if row["kind"] == "decode" else
                "reduce remat traffic / fuse attention to avoid score "
                "materialization")
    return ("raise per-chip utilization: larger microbatch or less remat "
            "recompute")


def render_markdown(cells: Dict) -> str:
    lines = [
        "| arch | shape | quant | t_comp ms | t_mem ms | t_coll ms | "
        "dominant | useful | roofline | HBM GiB | multi-pod |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for (arch, shape, quant), entry in sorted(cells.items()):
        row = derive_row(arch, shape, quant, entry)
        if row:
            rows.append(row)
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['quant']} "
            f"| {r['t_compute_ms']:.2f} | {r['t_memory_ms']:.2f} "
            f"| {r['t_collective_ms']:.2f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r.get('peak_hbm_gib', float('nan')):.2f} "
            f"| {'yes' if r.get('multi_pod_ok') else '-'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json-out", default="results/roofline_table.json")
    args = ap.parse_args()
    cells = load_results()
    rows = []
    for (arch, shape, quant), entry in sorted(cells.items()):
        row = derive_row(arch, shape, quant, entry)
        if row:
            row["next_action"] = bottleneck_sentence(row)
            rows.append(row)
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    if args.md:
        print(render_markdown(cells))
    else:
        for r in rows:
            print(f"{r['arch']:24s} {r['shape']:12s} {r['quant']:5s} "
                  f"dom={r['dominant']:10s} rf={r['roofline_fraction']:.3f} "
                  f"hbm={r.get('peak_hbm_gib', -1):.1f}GiB")


if __name__ == "__main__":
    main()
