"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (peak_FLOP/s per chip)
    memory term     = HLO_bytes / (HBM bandwidth per chip)
    collective term = collective_bytes / (ICI link bandwidth per chip)

Sources: `compiled.cost_analysis()` supplies per-device FLOPs and bytes;
collective bytes are NOT in cost_analysis, so we parse the optimized HLO
(`compiled.as_text()`) and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (task spec).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  `%ag = bf16[4,128,2048]{...} all-gather(...)`  — capture result type
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9]+)\[([0-9,]*)\][^a-z]*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: float):
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of collective ops in (optimized) HLO text.

    Uses the result shape (post-collective size) per op; `-start`
    variants counted once (`-done` carries no shape work).  Line-streamed:
    multi-MB HLO dumps parse without materializing match lists.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        hit = None
        for c in _COLLECTIVES:
            if c in line:
                hit = c
                break
        if hit is None:
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            stats.add(kind, _shape_bytes(dtype, dims))
            continue
        m = _TUPLE_RE.search(line)
        if m:
            total = sum(_shape_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(m.group(1)))
            stats.add(m.group(2), total)
    return stats


@dataclass
class Roofline:
    arch: str
    shape_id: str
    kind: str
    mesh: str
    quant: str
    flops: float                  # per device
    hlo_bytes: float              # per device
    collective_bytes: float       # per device
    model_flops: float            # 6*N*D (useful) per device
    collective_detail: Dict[str, float] = field(default_factory=dict)
    memory_per_device: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term roofline that is 'useful' work:
        for compute-bound cells this is MFU against the bound time."""
        if self.bound_time == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.bound_time

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape_id, "kind": self.kind,
            "mesh": self.mesh, "quant": self.quant,
            "flops": self.flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_detail": self.collective_detail,
            "memory_per_device": self.memory_per_device,
        }


def model_flops_for(arch_id: str, shape_id: str, n_devices: int) -> float:
    """MODEL_FLOPS per device: 6*N*D for train (2 fwd + 4 bwd), 2*N*D for
    inference, with N = active params (MoE: top_k+shared only) and
    D = tokens processed this step."""
    from repro.configs.registry import SHAPES, get_config
    from repro.launch.params_count import active_params, total_tokens
    cfg = get_config(arch_id)
    seq, batch, kind = SHAPES[shape_id]
    n_active = active_params(cfg)
    tokens = total_tokens(shape_id)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens / n_devices
