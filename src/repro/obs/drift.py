"""Digital-twin drift audit: CIM-simulator predictions vs measurement.

EdgeCIM's serving stack carries its own cost model: `EnergyMeter`
predicts what each decode step *should* cost on the modeled CIM array
(`sim_*` in every summary).  The paper's co-design argument assumes the
model tracks reality — edge-SLM characterization work shows measured
throughput/energy routinely diverging from modeled numbers by
config-dependent factors, so this module watches the ratio
continuously instead of trusting the calibration once.

Per replica, each audit tick compares the deltas of two cumulative
decode clocks:

    measured   Telemetry.decode_s        (wall time in decode steps)
    predicted  EnergyMeter.decode_sim_s  (modeled CIM time, same steps)

Token counts cancel (both clocks cover the same steps), so the raw
tick ratio is predicted/measured seconds.  Its absolute level is
meaningless on a host-CPU simulator (the modeled CIM array is faster
than the interpreting CPU by an arbitrary config-dependent factor), so
drift is defined RELATIVE to a calibration baseline learned from the
replica's own first `calib_ticks` of traffic:

    x_t   = log(d_sim_s / d_meas_s)          per-tick log-ratio
    ewma  = (1-a)*ewma + a*x_t               smoothed level
    mu0   = mean(x_1..x_calib)               learned baseline
    sim_drift_ratio = exp(ewma - mu0)        ~1.0 while tracking

A replica that slows down (contention, thermal, mis-modeled config
change) drives the ratio UP (predicted time stays put, measured time
grows the denominator... i.e. d_meas grows so x falls — see sign note
below); a simulator overestimating cost drives it down.  Detection is
two-sided CUSUM on the centered log-ratio — the standard change-point
statistic: it accumulates small persistent shifts that a threshold on
the instantaneous value would miss, and ignores zero-mean noise:

    s+ = max(0, s+ + (x_t - mu0 - k))        k = slack (ignores |shift|<k)
    s- = max(0, s- - (x_t - mu0 + k))
    alarm when max(s+, s-) > h

Sign note: sim_drift_ratio > 1 means the simulator now predicts MORE
time relative to measurement than at calibration (measurement got
faster / model got pessimistic); < 1 means measurement degraded
relative to the model — the "replica slowed down" page-worthy case.
Both directions alarm: either way the digital twin stopped tracking.

Pure stdlib; fed by `FleetRouter.poll_slo` from published snapshots,
alarms recorded into the replica's flight recorder and exported as
`sim_drift_*` gauges in /metrics.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, Optional


class DriftAuditor:
    """EWMA + two-sided CUSUM on the log sim/measured decode-time ratio.

    `observe()` takes CUMULATIVE clocks (monotone counters from the
    snapshot); ticks without fresh decode activity are skipped so idle
    replicas neither alarm nor decay their statistics.
    """

    def __init__(self, *, ewma_alpha: float = 0.3, calib_ticks: int = 5,
                 cusum_k: float = 0.25, cusum_h: float = 2.0,
                 min_delta_s: float = 1e-6, max_events: int = 64):
        assert 0.0 < ewma_alpha <= 1.0 and calib_ticks >= 1
        self.ewma_alpha = ewma_alpha
        self.calib_ticks = calib_ticks
        self.cusum_k = cusum_k          # slack: |log-shift| below this
        self.cusum_h = cusum_h          # is treated as noise
        self.min_delta_s = min_delta_s
        # cumulative marks from the previous tick
        self._last_meas_s: Optional[float] = None
        self._last_sim_s: Optional[float] = None
        # statistics
        self.ticks = 0                  # ticks with decode activity
        self.ewma: Optional[float] = None
        self.mu0: Optional[float] = None
        self._calib_sum = 0.0
        self.s_pos = 0.0
        self.s_neg = 0.0
        self.alarm = False
        self.alarms = 0                 # rising edges
        self.events: Deque[Dict] = deque(maxlen=max_events)

    @property
    def calibrated(self) -> bool:
        return self.mu0 is not None

    def observe(self, now: float, measured_s: float,
                sim_s: float) -> Optional[Dict]:
        """One audit tick over cumulative decode clocks; returns an
        alarm event dict on a rising edge, else None."""
        lm, ls = self._last_meas_s, self._last_sim_s
        self._last_meas_s, self._last_sim_s = measured_s, sim_s
        if lm is None:
            return None
        d_meas = measured_s - lm
        d_sim = sim_s - ls
        if d_meas < self.min_delta_s or d_sim < self.min_delta_s:
            return None                 # idle (or rewound) tick
        x = math.log(d_sim / d_meas)
        self.ticks += 1
        self.ewma = (x if self.ewma is None else
                     (1.0 - self.ewma_alpha) * self.ewma
                     + self.ewma_alpha * x)
        if self.mu0 is None:
            self._calib_sum += x
            if self.ticks >= self.calib_ticks:
                self.mu0 = self._calib_sum / self.ticks
            return None                 # no detection until calibrated
        xc = x - self.mu0
        self.s_pos = max(0.0, self.s_pos + xc - self.cusum_k)
        self.s_neg = max(0.0, self.s_neg - xc - self.cusum_k)
        tripped = max(self.s_pos, self.s_neg) > self.cusum_h
        event = None
        if tripped and not self.alarm:
            self.alarms += 1
            event = {"t_s": now, "kind": "drift_alarm",
                     "ratio": self.drift_ratio,
                     "cusum": max(self.s_pos, self.s_neg),
                     "direction": ("sim_overpredicts" if
                                   self.s_pos >= self.s_neg else
                                   "measured_degraded")}
            self.events.append(event)
        self.alarm = tripped
        return event

    @property
    def drift_ratio(self) -> float:
        """Calibration-normalized ratio: ~1.0 while the twin tracks.
        NaN until calibrated (exported as absent, never a fake 1.0)."""
        if self.ewma is None or self.mu0 is None:
            return float("nan")
        return math.exp(self.ewma - self.mu0)

    @property
    def measured_ratio(self) -> float:
        """Raw (un-normalized) smoothed sim/measured ratio —
        informational: how much faster the modeled CIM array is than
        the host actually running the simulation."""
        if self.ewma is None:
            return float("nan")
        return math.exp(self.ewma)

    def summary(self) -> Dict:
        """Gauges for /metrics and bench rows (NaN = not calibrated;
        the exporter drops non-finite values)."""
        return {
            "sim_drift_ratio": self.drift_ratio,
            "sim_drift_alarm": 1.0 if self.alarm else 0.0,
            "sim_drift_alarms": float(self.alarms),
            "sim_drift_cusum": max(self.s_pos, self.s_neg),
            "sim_measured_ratio": self.measured_ratio,
            "sim_drift_ticks": float(self.ticks),
        }
