"""Mergeable quantile sketch (DDSketch-style log-bucketed histogram).

The fleet problem with percentiles: each replica's `Telemetry` used to
keep a rolling sample window and report p95/p99 from it, and the router
AVERAGED those per-replica percentiles into a "fleet p95" — which is
not a percentile of anything (router.py acknowledged the lie).  The
fix is a sketch whose merge operation is exact over its own state:
log-spaced buckets with counts, so merging two sketches is bucket-wise
addition and the merged quantile carries the SAME relative-error
guarantee as each input.

Guarantee: for any quantile q over the inserted values, the reported
value v' satisfies |v' - v| <= alpha * v for the true q-quantile v
(values below `min_value` collapse into an exact zero bucket, and
bucket collapsing under memory pressure can additionally bias the
LOWEST quantiles upward — never the tail, which is what SLOs watch).

Properties the SLO layer leans on:
  mergeable     merge(a, b) == merge(b, a); merge is associative; a
                merged sketch's quantiles match a sketch built from the
                pooled samples exactly (same buckets, same counts)
  bounded       at most `max_buckets` buckets regardless of insert
                count; for latencies 1e-6..1e2 s at alpha=0.01 the
                natural bucket span is ~920, under the default cap, so
                collapsing never engages in practice
  serializable  `to_dict()`/`from_dict()` round-trip through JSON (the
                driver thread publishes dicts; the router merges them
                lock-free on the event loop)

Pure stdlib + numpy (vectorized bulk insert); no jax.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

DEFAULT_ALPHA = 0.01            # 1% relative error (spec asks <= ~2%)
DEFAULT_MAX_BUCKETS = 2048
# values at or below this are counted in the exact zero bucket: latency
# measurements below a microsecond are clock noise, not signal
MIN_VALUE = 1e-6


class QuantileDigest:
    """DDSketch-style quantile sketch over non-negative values.

    Bucket i covers (gamma^(i-1), gamma^i] with gamma = (1+a)/(1-a);
    a value is reported as the bucket midpoint 2*gamma^i/(gamma+1),
    which is within alpha (relative) of anywhere in the bucket.
    """

    __slots__ = ("alpha", "max_buckets", "min_value", "_gamma",
                 "_log_gamma", "_buckets", "zero_count", "count",
                 "sum", "min", "max", "collapsed")

    def __init__(self, alpha: float = DEFAULT_ALPHA,
                 max_buckets: int = DEFAULT_MAX_BUCKETS,
                 min_value: float = MIN_VALUE):
        assert 0.0 < alpha < 1.0 and max_buckets >= 2
        self.alpha = alpha
        self.max_buckets = max_buckets
        self.min_value = min_value
        self._gamma = (1.0 + alpha) / (1.0 - alpha)
        self._log_gamma = math.log(self._gamma)
        self._buckets: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.collapsed = 0      # buckets folded under memory pressure

    # -- insertion ------------------------------------------------------
    def _key(self, v: float) -> int:
        return math.ceil(math.log(v) / self._log_gamma)

    def add(self, v: float, count: int = 1) -> None:
        """Insert `v` with multiplicity `count`.  Negative values clamp
        to the zero bucket (latencies are non-negative; a clock skew
        artifact must not crash the metrics path)."""
        if count <= 0 or not math.isfinite(v):
            return
        v = max(float(v), 0.0)
        self.count += count
        self.sum += v * count
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= self.min_value:
            self.zero_count += count
            return
        k = self._key(v)
        self._buckets[k] = self._buckets.get(k, 0) + count
        if len(self._buckets) > self.max_buckets:
            self._collapse()

    def extend(self, values: Iterable[float]) -> None:
        """Vectorized bulk insert (numpy): one log + one bincount for
        the whole batch — 1e6 inserts cost milliseconds, which is what
        makes the bounded-memory property test cheap to run."""
        arr = np.asarray(list(values) if not isinstance(values, np.ndarray)
                         else values, np.float64).ravel()
        arr = arr[np.isfinite(arr)]
        if arr.size == 0:
            return
        arr = np.maximum(arr, 0.0)
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        self.min = min(self.min, float(arr.min()))
        self.max = max(self.max, float(arr.max()))
        zero = arr <= self.min_value
        self.zero_count += int(zero.sum())
        pos = arr[~zero]
        if pos.size:
            keys = np.ceil(np.log(pos) / self._log_gamma).astype(np.int64)
            uniq, cnts = np.unique(keys, return_counts=True)
            for k, c in zip(uniq.tolist(), cnts.tolist()):
                self._buckets[k] = self._buckets.get(k, 0) + c
            if len(self._buckets) > self.max_buckets:
                self._collapse()

    def _collapse(self) -> None:
        """Fold the lowest buckets together until under the cap.  The
        DDSketch trade: tails (the SLO-relevant quantiles) keep their
        guarantee; the smallest values lose resolution."""
        keys = sorted(self._buckets)
        while len(self._buckets) > self.max_buckets and len(keys) > 1:
            lo = keys.pop(0)
            self._buckets[keys[0]] = (self._buckets.pop(lo)
                                      + self._buckets.get(keys[0], 0))
            self.collapsed += 1

    # -- merge ----------------------------------------------------------
    def merge(self, other: "QuantileDigest") -> "QuantileDigest":
        """In-place merge (bucket-wise addition).  Requires matching
        alpha: merging sketches of different resolution would silently
        void the error bound."""
        if not math.isclose(self.alpha, other.alpha):
            raise ValueError(
                f"cannot merge sketches of different alpha "
                f"({self.alpha} vs {other.alpha})")
        for k, c in other._buckets.items():
            self._buckets[k] = self._buckets.get(k, 0) + c
        self.zero_count += other.zero_count
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        self.collapsed += other.collapsed
        if len(self._buckets) > self.max_buckets:
            self._collapse()
        return self

    def copy(self) -> "QuantileDigest":
        out = QuantileDigest(self.alpha, self.max_buckets, self.min_value)
        out._buckets = dict(self._buckets)
        out.zero_count = self.zero_count
        out.count = self.count
        out.sum = self.sum
        out.min = self.min
        out.max = self.max
        out.collapsed = self.collapsed
        return out

    # -- queries --------------------------------------------------------
    def __len__(self) -> int:
        return self.count

    @property
    def n_buckets(self) -> int:
        return len(self._buckets) + (1 if self.zero_count else 0)

    def mean(self, default: float = float("nan")) -> float:
        return self.sum / self.count if self.count else default

    def quantile(self, q: float) -> Optional[float]:
        """Value at quantile `q` in [0, 100] (percentile convention, to
        match np.percentile call sites); None when empty."""
        if self.count == 0:
            return None
        q = min(max(q / 100.0, 0.0), 1.0)
        rank = q * (self.count - 1)
        if rank < self.zero_count:
            return 0.0
        cum = self.zero_count
        key = 0
        for key in sorted(self._buckets):
            cum += self._buckets[key]
            if cum > rank:
                break
        # bucket (gamma^(k-1), gamma^k]: midpoint is within alpha of
        # every value in it; clamp into the observed range so q=0/q=100
        # report the exact min/max
        v = 2.0 * self._gamma ** key / (self._gamma + 1.0)
        return float(min(max(v, self.min), self.max))

    def quantiles(self, qs: Iterable[float]) -> List[Optional[float]]:
        return [self.quantile(q) for q in qs]

    def count_above(self, threshold: float) -> int:
        """Number of inserted values > `threshold` (within the sketch's
        relative error at the bucket containing the threshold).  This
        is what turns a cumulative latency digest into an SLO
        good/bad-event counter: bad(t) = count_above(objective)."""
        if threshold < 0:
            return self.count
        if self.count and threshold >= self.max:
            return 0
        thr_key = (self._key(threshold) if threshold > self.min_value
                   else 0)
        n = 0
        for k, c in self._buckets.items():
            if threshold <= self.min_value or k > thr_key:
                n += c
        return n

    def count_below(self, threshold: float) -> int:
        return self.count - self.count_above(threshold)

    # -- serialization --------------------------------------------------
    def to_dict(self) -> Dict:
        """JSON-ready snapshot (string bucket keys).  The driver thread
        publishes these; the router merges them without ever touching
        the live object."""
        return {
            "alpha": self.alpha,
            "zero": self.zero_count,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "collapsed": self.collapsed,
            "buckets": {str(k): c for k, c in self._buckets.items()},
        }

    @classmethod
    def from_dict(cls, d: Dict, max_buckets: int = DEFAULT_MAX_BUCKETS
                  ) -> "QuantileDigest":
        out = cls(alpha=float(d["alpha"]), max_buckets=max_buckets)
        out._buckets = {int(k): int(c)
                        for k, c in (d.get("buckets") or {}).items()}
        out.zero_count = int(d.get("zero", 0))
        out.count = int(d.get("count", 0))
        out.sum = float(d.get("sum", 0.0))
        out.min = float(d["min"]) if d.get("min") is not None else math.inf
        out.max = (float(d["max"]) if d.get("max") is not None
                   else -math.inf)
        out.collapsed = int(d.get("collapsed", 0))
        if len(out._buckets) > out.max_buckets:
            out._collapse()
        return out


def merge_digest_dicts(dicts: Iterable[Optional[Dict]]
                       ) -> Optional[QuantileDigest]:
    """Merge serialized digests (skipping Nones) into one sketch; None
    when nothing mergeable was given.  The fleet rollup path: each
    replica publishes `Telemetry.digests()`, the router pools them
    here, and fleet p95/p99 come out mathematically correct."""
    out: Optional[QuantileDigest] = None
    for d in dicts:
        if not d:
            continue
        dig = QuantileDigest.from_dict(d)
        out = dig if out is None else out.merge(dig)
    return out


# the summary keys whose per-replica values are rank statistics and
# therefore must NEVER be averaged across replicas — the fleet value is
# recomputed from merged sketches keyed by the metric's digest name
PERCENTILE_KEYS: Dict[str, Tuple[str, float]] = {
    f"{metric}_p{p}_s": (f"{metric}_s", float(p))
    for metric in ("ttft", "tpot", "itl", "queue")
    for p in (50, 95, 99)
}
