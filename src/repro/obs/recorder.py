"""Flight recorder: bounded ring of recent engine events, dumped on death.

The fleet layer (PR 6) evicts a replica whose driver thread dies — but
evicting silently discards the one thing a postmortem needs: what the
engine was doing in the seconds before the fatal step.  The recorder is
the black box for that crash: every engine lifecycle event (admit,
prefill chunk, decode step, preempt, finish, cancel, eviction) lands in
a small ring regardless of whether tracing is enabled, and
`EngineDriver` dumps it to disk when its loop dies.

Unlike the tracer (opt-in, high-volume, per-thread rings), the recorder
is always on, tiny (default 512 events), and single-ring: engine events
are produced only by the one driver thread that owns the engine, so a
plain deque suffices.  Cost per event is one tuple append.

Dumps go to `REPRO_FLIGHT_DIR` (default `flight_records/` under the
cwd) as `flight-<label>-<pid>.json`:

    {"label": "replica-0", "reason": "boom", "pushes": 1234,
     "events": [{"t_s": ..., "kind": "decode_step", ...}, ...]}
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

DEFAULT_CAPACITY = 512
ENV_FLIGHT_DIR = "REPRO_FLIGHT_DIR"


class FlightRecorder:
    """Always-on bounded ring of engine events.

    `record(kind, **fields)` is the single producer API; `snapshot()`
    and `dump(reason)` are the consumer side.  The ring is written by
    the engine's owning thread and read (rarely) by whoever asks for a
    postmortem, so deque append/list() atomicity is all the safety we
    need — same argument as obs/trace.py, without even the per-thread
    indirection.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 label: str = "engine", clock=time.monotonic):
        self.capacity = capacity
        self.label = label
        self._clock = clock
        self._events: deque = deque(maxlen=capacity)
        self.pushes = 0

    def record(self, kind: str, **fields: Any) -> None:
        self._events.append((self._clock(), kind, fields or None))
        self.pushes += 1

    @property
    def dropped(self) -> int:
        return self.pushes - len(self._events)

    def snapshot(self) -> List[Dict[str, Any]]:
        out = []
        for t_s, kind, fields in list(self._events):
            ev = {"t_s": t_s, "kind": kind}
            if fields:
                ev.update(fields)
            out.append(ev)
        return out

    def to_payload(self, reason: str = "") -> Dict[str, Any]:
        return {
            "label": self.label,
            "reason": reason,
            "pid": os.getpid(),
            "thread": threading.current_thread().name,
            "capacity": self.capacity,
            "pushes": self.pushes,
            "dropped": self.dropped,
            "events": self.snapshot(),
        }

    def dump(self, reason: str = "",
             directory: Optional[str] = None) -> Optional[str]:
        """Write the ring to disk; returns the path, or None if the
        write itself failed (a postmortem must never take down the
        thread that is already dying)."""
        directory = directory or os.environ.get(
            ENV_FLIGHT_DIR, "flight_records")
        try:
            os.makedirs(directory, exist_ok=True)
            path = os.path.join(
                directory, f"flight-{self.label}-{os.getpid()}.json")
            with open(path, "w") as f:
                json.dump(self.to_payload(reason), f, indent=1,
                          default=str)
            return path
        except OSError:
            return None
