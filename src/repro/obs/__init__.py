"""Observability layer: tracing, exporters, flight recorder, energy, SLOs.

Stdlib + numpy only (jax enters only indirectly via the CIM cost model
in `obs.energy`).  See serve/README.md "Observability" for the span
taxonomy, and its "SLOs & drift" subsection for the quantile-sketch /
burn-rate / drift-audit layer (`obs.digest`, `obs.slo`, `obs.drift`).
"""
from .digest import QuantileDigest, merge_digest_dicts
from .drift import DriftAuditor
from .energy import EnergyMeter, slm_spec_from_model_config
from .export import chrome_trace, prometheus_text
from .recorder import FlightRecorder
from .slo import BurnRatePolicy, SLOMonitor, SLOSpec, parse_slos
from .trace import NULL_SPAN, Tracer, get_tracer

__all__ = [
    "BurnRatePolicy",
    "DriftAuditor",
    "EnergyMeter",
    "FlightRecorder",
    "NULL_SPAN",
    "QuantileDigest",
    "SLOMonitor",
    "SLOSpec",
    "Tracer",
    "chrome_trace",
    "get_tracer",
    "merge_digest_dicts",
    "parse_slos",
    "prometheus_text",
    "slm_spec_from_model_config",
]
