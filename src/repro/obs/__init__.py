"""Observability layer: span tracing, exporters, flight recorder, energy.

Stdlib-only (numpy/jax enter only indirectly via the CIM cost model in
`obs.energy`).  See serve/README.md "Observability" for the span
taxonomy and usage.
"""
from .energy import EnergyMeter, slm_spec_from_model_config
from .export import chrome_trace, prometheus_text
from .recorder import FlightRecorder
from .trace import NULL_SPAN, Tracer, get_tracer

__all__ = [
    "EnergyMeter",
    "FlightRecorder",
    "NULL_SPAN",
    "Tracer",
    "chrome_trace",
    "get_tracer",
    "prometheus_text",
    "slm_spec_from_model_config",
]
