"""SLO engine: declarative objectives + multi-window burn-rate alerting.

Telemetry exports numbers; nothing so far judged them.  This module
holds the judgment: an `SLOSpec` states an objective ("95% of requests
see TTFT under 500 ms", "error rate under 1%", "goodput at least
10 tok/s"), and an `SLOMonitor` evaluates each replica's (and the
fleet's) measured stream against it with Google-SRE multi-window
burn-rate rules, driving an `ok -> warn -> page` alert state machine
per (scope, objective).

Burn rate is error-budget consumption speed: with budget b (the
allowed bad-event fraction — 0.05 for a p95 objective), a window whose
bad fraction is f burns at f/b.  Burn 1.0 exactly exhausts the budget
over the SLO period; the SRE multi-window rule pages when BOTH a long
and a short window burn faster than a factor (long window = sustained,
short window = still happening), which suppresses both blips and
stale alerts:

    page  burn >= 14.4 over (1h  long, 5m  short)   [scaled]
    warn  burn >=  6.0 over (6h  long, 30m short)   [scaled]

`BurnRatePolicy.timescale` compresses the canonical SRE windows so a
30-second bench run exercises the same math a production day would
(timescale=1/600 turns 1h into 6s).

Event counting is uniform across SLO kinds — every tick contributes
(total_delta, bad_delta) to a time-bucketed series per (scope, slo):

    latency_p<q>   events = latency digest count delta, bad = delta of
                   `count_above(threshold)` on the SAME cumulative
                   sketch (obs/digest.py) — the sketch, not a sample
                   window, so fleet math stays exact under merge
    error_rate     events = requests delta, bad = cancelled delta
    goodput floor  events = 1 per tick with decode activity, bad = 1
                   when the tick's measured decode rate sat below the
                   floor (budget defaults to 5% of ticks)

The monitor is clock-driven and thread-free: `ingest()` +
`evaluate()` run wherever the caller likes (FleetRouter.poll_slo runs
them on the event loop from published snapshots).  Transitions land in
a bounded ring, fire subscribed callbacks (`FleetRouter.on_alert`),
and are mirrored into the owning replica's flight recorder by the
router so a postmortem dump explains a degraded death.
"""
from __future__ import annotations

import re
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .digest import QuantileDigest

# alert levels, ordered; exported as a Prometheus gauge by obs/export
LEVELS = ("ok", "warn", "page")
LEVEL_VALUE = {name: i for i, name in enumerate(LEVELS)}

_SPEC_RE = re.compile(
    r"^\s*(?P<metric>[a-zA-Z_][a-zA-Z0-9_]*?)"
    r"(?:_p(?P<pct>\d+(?:\.\d+)?))?(?P<unit>_s)?"
    r"\s*(?P<op><|>)\s*(?P<value>[-+0-9.eE]+)\s*$")

# latency metrics backed by a Telemetry digest (obs/digest.py names)
LATENCY_METRICS = ("ttft", "tpot", "itl", "queue")

# the stock objective set (--slo with no specs, api_bench --slo):
# interactive-serving targets loose enough for a CPU smoke cell
DEFAULT_SLOS = ("ttft_p95_s < 2.0", "itl_p99_s < 1.0",
                "error_rate < 0.05")


@dataclass(frozen=True)
class SLOSpec:
    """One declarative objective.

    kind "latency": `threshold` is the objective latency in seconds
    and `budget` the allowed fraction of requests above it (p95 ->
    0.05).  kind "error_rate": `budget` IS the ceiling.  kind
    "goodput": `threshold` is the floor in tokens/s; `budget` bounds
    the fraction of evaluation ticks allowed below it.
    """
    name: str
    kind: str                   # "latency" | "error_rate" | "goodput"
    metric: str                 # digest name for latency ("ttft_s")
    threshold: float
    budget: float
    spec: str                   # the source text, echoed in payloads

    @staticmethod
    def parse(text: str) -> "SLOSpec":
        """Parse one spec string:

            "ttft_p95_s < 0.5"            95% of TTFTs under 500 ms
            "itl_p99_s < 0.1"             99% of token gaps under 100 ms
            "error_rate < 0.01"           under 1% requests cancelled
            "goodput_tokens_per_s > 10"   decode rate floor 10 tok/s
        """
        m = _SPEC_RE.match(text)
        if m is None:
            raise ValueError(f"unparseable SLO spec {text!r}")
        metric, pct, op, value = (m.group("metric"), m.group("pct"),
                                  m.group("op"), float(m.group("value")))
        if pct is not None:
            if metric not in LATENCY_METRICS:
                raise ValueError(
                    f"SLO spec {text!r}: percentile objectives cover "
                    f"{LATENCY_METRICS}, not {metric!r}")
            if op != "<":
                raise ValueError(f"SLO spec {text!r}: latency "
                                 "objectives are upper bounds (<)")
            q = float(pct)
            if not 0.0 < q < 100.0:
                raise ValueError(f"SLO spec {text!r}: percentile must "
                                 "be in (0, 100)")
            return SLOSpec(name=f"{metric}_p{pct}", kind="latency",
                           metric=f"{metric}_s", threshold=value,
                           budget=1.0 - q / 100.0, spec=text)
        if metric == "error_rate":
            if op != "<" or not 0.0 < value < 1.0:
                raise ValueError(f"SLO spec {text!r}: error_rate takes "
                                 "'< fraction' in (0, 1)")
            return SLOSpec(name="error_rate", kind="error_rate",
                           metric="error_rate", threshold=value,
                           budget=value, spec=text)
        # the optional _s suffix group may have eaten the unit off
        # "goodput_tokens_per_s" — accept both shapes
        if metric in ("goodput", "goodput_tokens_per",
                      "goodput_tokens_per_s"):
            if op != ">":
                raise ValueError(f"SLO spec {text!r}: goodput is a "
                                 "floor (>)")
            return SLOSpec(name="goodput", kind="goodput",
                           metric="goodput_tokens_per_s",
                           threshold=value, budget=0.05, spec=text)
        raise ValueError(
            f"SLO spec {text!r}: unknown metric {metric!r} (know "
            f"{LATENCY_METRICS} percentiles, error_rate, "
            "goodput_tokens_per_s)")


def parse_slos(specs) -> Tuple[SLOSpec, ...]:
    """Parse a mixed list of spec strings / SLOSpec objects; duplicate
    names are an error (two objectives driving one state machine would
    silently shadow each other)."""
    out: List[SLOSpec] = []
    for s in specs or ():
        out.append(s if isinstance(s, SLOSpec) else SLOSpec.parse(s))
    names = [s.name for s in out]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate SLO names in {names}")
    return tuple(out)


@dataclass(frozen=True)
class BurnRatePolicy:
    """Multi-window burn-rate thresholds (canonical SRE numbers),
    uniformly compressed by `timescale` so bench-scale runs evaluate
    the same shape: timescale=1/600 maps the 1h page window to 6s."""
    page_long_s: float = 3600.0
    page_short_s: float = 300.0
    page_burn: float = 14.4
    warn_long_s: float = 21600.0
    warn_short_s: float = 1800.0
    warn_burn: float = 6.0
    timescale: float = 1.0

    def windows(self) -> Dict[str, Tuple[float, float, float]]:
        t = self.timescale
        return {
            "page": (self.page_long_s * t, self.page_short_s * t,
                     self.page_burn),
            "warn": (self.warn_long_s * t, self.warn_short_s * t,
                     self.warn_burn),
        }

    @property
    def max_window_s(self) -> float:
        return max(self.page_long_s, self.warn_long_s) * self.timescale


class _Series:
    """Time-bucketed (total, bad) event deltas with bounded retention:
    one bucket per ingest tick, pruned past the longest policy window.
    Rates over a window are bucket sums — O(window/tick) per query,
    tiny at any sane poll interval."""

    __slots__ = ("_buckets", "_horizon_s", "last_total", "last_bad")

    def __init__(self, horizon_s: float):
        self._buckets: Deque[Tuple[float, float, float]] = deque()
        self._horizon_s = horizon_s
        self.last_total: Optional[float] = None     # cumulative marks
        self.last_bad: Optional[float] = None

    def push_cumulative(self, now: float, total: float,
                        bad: float) -> Tuple[float, float]:
        """Ingest cumulative counters; appends the positive delta since
        the previous tick (a replica restart that rewinds a counter
        contributes zero, never a negative bucket)."""
        d_total = d_bad = 0.0
        if self.last_total is not None:
            d_total = max(total - self.last_total, 0.0)
            d_bad = max(bad - self.last_bad, 0.0)
        self.last_total, self.last_bad = total, bad
        self.push_delta(now, d_total, d_bad)
        return d_total, d_bad

    def push_delta(self, now: float, d_total: float, d_bad: float) -> None:
        self._buckets.append((now, d_total, d_bad))
        cutoff = now - self._horizon_s
        while self._buckets and self._buckets[0][0] < cutoff:
            self._buckets.popleft()

    def window(self, window_s: float, now: float) -> Tuple[float, float]:
        cutoff = now - window_s
        total = bad = 0.0
        for t, dt, db in reversed(self._buckets):
            if t < cutoff:
                break
            total += dt
            bad += db
        return total, bad


@dataclass
class AlertState:
    """Per-(scope, slo) alert machine.  Level follows the burn-rate
    evaluation directly — the multi-window rule itself provides the
    hysteresis (the long window must drain before de-escalation), so no
    extra dwell timers."""
    scope: str
    slo: str
    level: str = "ok"
    since: float = 0.0
    transitions: int = 0
    burn: Dict[str, float] = field(default_factory=dict)
    bad_total: float = 0.0
    events_total: float = 0.0

    def to_dict(self) -> Dict:
        return {"scope": self.scope, "slo": self.slo,
                "level": self.level, "since_s": self.since,
                "transitions": self.transitions,
                "burn": dict(self.burn),
                "events_total": self.events_total,
                "bad_total": self.bad_total}


class SLOMonitor:
    """Evaluates SLO specs over per-scope measured streams.

    One monitor serves every scope: per-replica scopes ("replica-0")
    and the synthetic "fleet" scope the router feeds with summed
    counters + merged digests.  `on_transition(cb)` subscribes to
    alert-level changes; `FleetRouter.on_alert` is a thin wrapper.
    """

    def __init__(self, slos, *, policy: Optional[BurnRatePolicy] = None,
                 clock=time.monotonic, max_transitions: int = 256):
        self.slos: Tuple[SLOSpec, ...] = parse_slos(slos)
        self.policy = policy or BurnRatePolicy()
        self._clock = clock
        self._series: Dict[Tuple[str, str], _Series] = {}
        self.states: Dict[Tuple[str, str], AlertState] = {}
        self.transitions: Deque[Dict] = deque(maxlen=max_transitions)
        self._subs: List[Callable[[Dict], None]] = []

    # -- subscriptions --------------------------------------------------
    def on_transition(self, cb: Callable[[Dict], None]) -> None:
        self._subs.append(cb)

    # -- ingest (one scope, one tick) -----------------------------------
    def _serie(self, scope: str, slo: str) -> _Series:
        key = (scope, slo)
        s = self._series.get(key)
        if s is None:
            # keep 2x the longest window so a query at the horizon edge
            # never reads a half-pruned bucket
            s = self._series[key] = _Series(2.0 * self.policy.max_window_s)
        return s

    def ingest(self, scope: str, *, digests: Optional[Dict] = None,
               counters: Optional[Dict] = None,
               now: Optional[float] = None) -> None:
        """One evaluation tick of cumulative state for `scope`:
        `digests` maps digest names to serialized sketches
        (`Telemetry.digests()`), `counters` is the telemetry snapshot
        (requests_total / cancelled / decode_tokens / decode_s)."""
        now = self._clock() if now is None else now
        digests = digests or {}
        counters = counters or {}
        for slo in self.slos:
            serie = self._serie(scope, slo.name)
            if slo.kind == "latency":
                d = digests.get(slo.metric)
                if d is None:
                    continue
                sketch = QuantileDigest.from_dict(d)
                serie.push_cumulative(
                    now, float(sketch.count),
                    float(sketch.count_above(slo.threshold)))
            elif slo.kind == "error_rate":
                serie.push_cumulative(
                    now, float(counters.get("requests_total", 0.0)),
                    float(counters.get("cancelled", 0.0)))
            elif slo.kind == "goodput":
                # per-tick gauge: measured decode rate over this tick's
                # (decode_tokens, decode_s) delta; idle ticks don't vote
                tokens = float(counters.get("decode_tokens", 0.0))
                busy_s = float(counters.get("decode_s", 0.0))
                lt, lb = serie.last_total, serie.last_bad
                d_tok = tokens - lt if lt is not None else 0.0
                d_s = busy_s - lb if lb is not None else 0.0
                serie.last_total, serie.last_bad = tokens, busy_s
                if d_tok > 0 and d_s > 0:
                    rate = d_tok / d_s
                    serie.push_delta(now, 1.0,
                                     1.0 if rate < slo.threshold else 0.0)
                else:
                    serie.push_delta(now, 0.0, 0.0)

    # -- evaluation -----------------------------------------------------
    def _burn(self, serie: _Series, slo: SLOSpec, window_s: float,
              now: float) -> float:
        total, bad = serie.window(window_s, now)
        if total <= 0:
            return 0.0
        return (bad / total) / slo.budget

    def evaluate(self, now: Optional[float] = None) -> List[Dict]:
        """Re-derive every alert level from the windowed series;
        returns the transitions this call produced (already pushed to
        the ring and delivered to subscribers)."""
        now = self._clock() if now is None else now
        fired: List[Dict] = []
        for (scope, name), serie in self._series.items():
            slo = next(s for s in self.slos if s.name == name)
            burns: Dict[str, float] = {}
            level = "ok"
            for lvl, (long_s, short_s, factor) in \
                    self.policy.windows().items():
                b_long = self._burn(serie, slo, long_s, now)
                b_short = self._burn(serie, slo, short_s, now)
                burns[f"{lvl}_long"] = b_long
                burns[f"{lvl}_short"] = b_short
                if b_long >= factor and b_short >= factor:
                    if LEVEL_VALUE[lvl] > LEVEL_VALUE[level]:
                        level = lvl
            st = self.states.get((scope, name))
            if st is None:
                st = self.states[(scope, name)] = AlertState(
                    scope=scope, slo=name, since=now)
            st.burn = burns
            total, bad = serie.window(self.policy.max_window_s, now)
            st.events_total, st.bad_total = total, bad
            if level != st.level:
                ev = {"t_s": now, "kind": "slo_alert", "scope": scope,
                      "slo": name, "from": st.level, "to": level,
                      "spec": slo.spec,
                      "burn_long": burns.get(f"{level}_long",
                                             burns.get("page_long", 0.0)),
                      "burn_short": burns.get(f"{level}_short",
                                              burns.get("page_short",
                                                        0.0))}
                st.level = level
                st.since = now
                st.transitions += 1
                self.transitions.append(ev)
                fired.append(ev)
                for cb in self._subs:
                    try:
                        cb(ev)
                    except Exception:
                        pass    # a broken subscriber must not stop
                        # evaluation or starve later subscribers
        return fired

    # -- views ----------------------------------------------------------
    def worst_level(self, scope: Optional[str] = None) -> str:
        """Highest active alert level, optionally restricted to one
        scope — /healthz's `degraded` flag reads this."""
        worst = "ok"
        for (sc, _), st in self.states.items():
            if scope is not None and sc != scope:
                continue
            if LEVEL_VALUE[st.level] > LEVEL_VALUE[worst]:
                worst = st.level
        return worst

    def payload(self) -> Dict:
        """JSON body for GET /debug/slo."""
        return {
            "slos": [{"name": s.name, "kind": s.kind, "spec": s.spec,
                      "threshold": s.threshold, "budget": s.budget}
                     for s in self.slos],
            "policy": {
                "timescale": self.policy.timescale,
                "windows": {lvl: {"long_s": lo, "short_s": sh,
                                  "burn": f}
                            for lvl, (lo, sh, f)
                            in self.policy.windows().items()}},
            "states": [st.to_dict() for st in self.states.values()],
            "worst": self.worst_level(),
            "transitions": list(self.transitions),
        }
