"""Trace and metrics exporters.

chrome_trace   Tracer events -> Chrome trace-event JSON (the format
               Perfetto / chrome://tracing load directly): one process,
               one track per recorded thread, "X" complete spans and
               "i" instants, args (request ids, lane lists, policy
               scores) preserved per event.

prometheus_text
               the gateway's /metrics JSON payload -> Prometheus text
               exposition (version 0.0.4): engine counters/gauges,
               gateway counters, per-replica gauges with a `replica`
               label, and the latency histograms as cumulative
               `_bucket{le=...}` series.  Same numbers as the JSON —
               one source payload, two renderings — so a scrape can
               never disagree with the debug view.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional

from .slo import LEVEL_VALUE
from .trace import Tracer

TRACE_CATEGORIES = ("gateway", "router", "driver", "engine", "sched")


def chrome_trace(tracer: Tracer,
                 process_name: str = "repro-serve") -> Dict[str, Any]:
    """Chrome trace-event JSON object for every ring in `tracer`.

    Timestamps are microseconds on the tracer's monotonic clock; each
    thread that ever recorded becomes its own track via metadata
    events, so a 2-replica run shows gateway/event-loop, router, and
    both driver threads as parallel lanes.
    """
    events: List[Dict[str, Any]] = []
    pid = tracer.pid
    named: Dict[int, str] = {}
    for ring in tracer.rings():
        if ring.tid not in named:
            named[ring.tid] = ring.thread_name
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": ring.tid,
                           "args": {"name": ring.thread_name}})
    for ev in tracer.events():
        out = {"name": ev["name"], "cat": ev["cat"], "ph": ev["ph"],
               "ts": ev["t_s"] * 1e6, "pid": pid, "tid": ev["tid"]}
        if ev["ph"] == "X":
            out["dur"] = ev["dur_s"] * 1e6
        if ev["ph"] == "i":
            out["s"] = "t"                  # instant scope: thread
        if ev["args"]:
            out["args"] = dict(ev["args"])
        events.append(out)
    events.insert(0, {"ph": "M", "name": "process_name", "pid": pid,
                      "tid": 0, "args": {"name": process_name}})
    return {"traceEvents": events,
            "displayTimeUnit": "ms",
            "metadata": {"dropped_events": tracer.dropped()}}


# ----------------------------------------------------------------------------
# Prometheus text exposition
# ----------------------------------------------------------------------------
_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")

# engine-summary keys that are monotonic counts (everything else in the
# summary is a gauge: rates, percentiles, occupancies)
_COUNTER_KEYS = frozenset({
    "requests", "tokens", "prefill_tokens", "steps", "decode_steps",
    "spec_drafted", "spec_accepted", "prefix_lookups", "prefix_hits",
    "prefill_tokens_skipped", "fork_admissions", "cancelled",
    "cow_copies", "kv_pages_shared", "prefix_pages_evicted",
})


def _mname(*parts: str) -> str:
    return _NAME_OK.sub("_", "_".join(p.strip("_") for p in parts))


def _fmt_value(v: Any) -> Optional[str]:
    if isinstance(v, bool):
        return "1" if v else "0"
    if not isinstance(v, (int, float)):
        return None
    f = float(v)
    if not math.isfinite(f):
        # "no data yet" is an ABSENT series in Prometheus, not a NaN
        # sample: a NaN line poisons every recording rule / aggregation
        # that touches it, and +/-Inf never describes a real scrape.
        # Skipping the line is the exposition-format idiom for absence.
        return None
    return repr(f) if isinstance(v, float) else str(v)


def _line(out: List[str], name: str, value: Any,
          labels: Optional[Dict[str, str]] = None,
          mtype: Optional[str] = None,
          typed: Optional[set] = None) -> None:
    sval = _fmt_value(value)
    if sval is None:
        return
    if mtype and typed is not None and name not in typed:
        typed.add(name)
        out.append(f"# TYPE {name} {mtype}")
    lab = ""
    if labels:
        body = ",".join(
            '%s="%s"' % (k, str(v).replace("\\", "\\\\")
                         .replace('"', '\\"').replace("\n", "\\n"))
            for k, v in sorted(labels.items()))
        lab = "{" + body + "}"
    out.append(f"{name}{lab} {sval}")


def _hist_lines(out: List[str], name: str, hist: Dict[str, List],
                labels: Optional[Dict[str, str]], typed: set) -> None:
    """Fixed-bucket latency histogram -> cumulative `le` series.  Our
    edges bracket every count (first bucket reaches to 0, last is
    unbounded), so the +Inf bucket equals the total count exactly."""
    edges = hist["edges_s"]
    counts = hist["counts"]
    if name not in typed:
        typed.add(name)
        out.append(f"# TYPE {name} histogram")
    cum = 0
    # counts[i] covers (edges[i], edges[i+1]]; upper bounds skip the
    # leading 0.0 edge and end on the "inf" sentinel
    for upper, c in zip(list(edges[1:]), counts):
        cum += int(c)
        le = "+Inf" if upper == "inf" else repr(float(upper))
        _line(out, name + "_bucket", cum, {**(labels or {}), "le": le})
    _line(out, name + "_count", cum, labels)


def prometheus_text(payload: Dict[str, Any],
                    prefix: str = "repro") -> str:
    """Render the gateway /metrics JSON payload as Prometheus text
    exposition.  Strictly derived: every sample is read from `payload`,
    so the JSON and Prometheus views are always the same scrape."""
    out: List[str] = []
    typed: set = set()

    if payload.get("schema_version") is not None:
        _line(out, _mname(prefix, "metrics_schema_version"),
              payload["schema_version"], mtype="gauge", typed=typed)

    engine = payload.get("engine") or {}
    for key in sorted(engine):
        val = engine[key]
        mtype = "counter" if key in _COUNTER_KEYS else "gauge"
        name = _mname(prefix, "engine", key)
        if mtype == "counter":
            name = _mname(name, "total")
        _line(out, name, val, mtype=mtype, typed=typed)

    for key in ("n_running", "n_queued", "kv_pages_free"):
        if key in payload:
            _line(out, _mname(prefix, key), payload[key],
                  mtype="gauge", typed=typed)

    gw = payload.get("gateway") or {}
    for key in sorted(gw):
        mtype = "gauge" if key in ("inflight", "max_pending") \
            else "counter"
        name = _mname(prefix, "gateway", key)
        if mtype == "counter":
            name = _mname(name, "total")
        _line(out, name, gw[key], mtype=mtype, typed=typed)

    fleet = payload.get("fleet") or {}
    for key, val in sorted((fleet.get("counters") or {}).items()):
        _line(out, _mname(prefix, "fleet", key, "total"), val,
              mtype="counter", typed=typed)
    for key in ("n_replicas", "n_live"):
        if key in fleet:
            _line(out, _mname(prefix, "fleet", key), fleet[key],
                  mtype="gauge", typed=typed)
    for key in ("affinity_hits", "affinity_misses"):
        if fleet.get(key) is not None:
            _line(out, _mname(prefix, "fleet", key, "total"),
                  fleet[key], mtype="counter", typed=typed)
    for rid, rep in sorted((fleet.get("replicas") or {}).items()):
        labels = {"replica": rid}
        _line(out, _mname(prefix, "replica_up"),
              bool(rep.get("alive")), labels, mtype="gauge", typed=typed)
        _line(out, _mname(prefix, "replica_pending"),
              rep.get("pending"), labels, mtype="gauge", typed=typed)
        _line(out, _mname(prefix, "replica_dispatches_total"),
              rep.get("dispatches"), labels, mtype="counter",
              typed=typed)
        snap = rep.get("snapshot") or {}
        for key in ("kv_occupancy", "n_running", "n_queued"):
            if key in snap:
                _line(out, _mname(prefix, "replica", key), snap[key],
                      labels, mtype="gauge", typed=typed)
        # digital-twin drift audit (obs/drift.py): NaN ratio before
        # calibration renders as an absent series, so dashboards show
        # drift only once it is a meaningful number
        drift = rep.get("drift") or {}
        for key in ("sim_drift_ratio", "sim_drift_alarm",
                    "sim_drift_cusum", "sim_measured_ratio"):
            if key in drift:
                _line(out, _mname(prefix, "replica", key), drift[key],
                      labels, mtype="gauge", typed=typed)
        if "sim_drift_alarms" in drift:
            _line(out, _mname(prefix, "replica_sim_drift_alarms_total"),
                  drift["sim_drift_alarms"], labels, mtype="counter",
                  typed=typed)

    # SLO alert state machines (obs/slo.py): level as an enum gauge
    # (0=ok 1=warn 2=page) plus the page-window burn rates behind it
    slo = payload.get("slo") or {}
    for st in slo.get("states") or []:
        labels = {"scope": str(st.get("scope")),
                  "slo": str(st.get("slo"))}
        lvl = LEVEL_VALUE.get(st.get("level"), 0)
        _line(out, _mname(prefix, "slo_alert_level"), lvl, labels,
              mtype="gauge", typed=typed)
        burn = st.get("burn") or {}
        for bkey in ("page_long", "page_short"):
            if bkey in burn:
                _line(out, _mname(prefix, "slo_burn", bkey),
                      burn[bkey], labels, mtype="gauge", typed=typed)
        _line(out, _mname(prefix, "slo_transitions_total"),
              st.get("transitions"), labels, mtype="counter",
              typed=typed)

    for hname, hist in sorted((payload.get("histograms") or {}).items()):
        _hist_lines(out, _mname(prefix, hname.removesuffix("_s"),
                                "seconds"), hist, None, typed)

    return "\n".join(out) + "\n"
