"""Span tracer: lock-free per-thread event rings for the serving stack.

EdgeCIM's whole argument is an attribution argument — decode's
memory-bound GEMV is where time and energy go (paper Fig. 2) — and the
runtime now spans gateway -> fleet router -> driver thread -> engine
phases.  Windowed aggregates (serve/telemetry.py) cannot answer "where
did THIS request's p99 spike come from", so this module records the
raw timeline instead: timestamped spans and instants, tagged with a
propagated request id, exportable as a Chrome trace (obs/export.py)
that Perfetto opens directly.

Design constraints, in order:

  disabled == free   every instrumentation site guards on the single
                     attribute read `tracer.enabled` before building
                     any args dict; `span()` on a disabled tracer
                     returns one shared no-op context manager.
  no locks on the    each thread writes its OWN `collections.deque`
  hot path           (appends are atomic in CPython, maxlen gives ring
                     semantics for free); the only lock guards ring
                     REGISTRATION — once per thread, ever.
  bounded memory     rings hold `capacity` events per thread; older
                     events fall off the back.  `dropped` counts what
                     the window lost, so an export can say "partial".

Clocks are `time.monotonic` seconds (caller-overridable for tests),
exported as microseconds — the unit Chrome trace events use.

One process-wide tracer (`get_tracer()`) serves every component:
request ids must correlate across gateway, router, and N driver
threads, which means one id namespace and one export surface.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

DEFAULT_CAPACITY = 65536        # events per thread ring

# event tuples: (ph, t_s, dur_s, name, cat, args_or_None)
#   ph "X" = complete span (dur_s meaningful), "i" = instant


class _Ring:
    """One thread's event buffer.  Only its owner thread appends;
    exporters snapshot via list(), which is safe against concurrent
    appends in CPython (worst case: an event lands after the copy)."""

    __slots__ = ("events", "tid", "thread_name", "pushes")

    def __init__(self, capacity: int, tid: int, thread_name: str):
        self.events: deque = deque(maxlen=capacity)
        self.tid = tid
        self.thread_name = thread_name
        self.pushes = 0         # total ever; minus len() = dropped

    @property
    def dropped(self) -> int:
        return self.pushes - len(self.events)


class _Span:
    """Context manager recording one complete ("X") event on exit.
    Exceptions propagate; the span still closes (the trace should show
    the step that blew up, not end just before it)."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc) -> None:
        t = self._tracer
        t._push(("X", self._t0, t._clock() - self._t0, self._name,
                 self._cat, self._args))


class _NullSpan:
    """Shared no-op context manager: `span()` on a disabled tracer
    costs one attribute check and returns this singleton."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 clock=time.monotonic):
        self.enabled = False
        self.capacity = capacity
        self._clock = clock
        self._tls = threading.local()
        self._rings: List[_Ring] = []
        self._reg_lock = threading.Lock()
        self._rid_counter = itertools.count()
        self.pid = os.getpid()

    # -- lifecycle ------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        """Drop all recorded events (rings stay registered — their
        owner threads still hold them thread-locally)."""
        for ring in list(self._rings):
            ring.events.clear()
            ring.pushes = 0

    def next_request_id(self) -> int:
        """Process-unique request id: the one value that ties a
        gateway lifecycle span to router dispatch instants and engine
        step spans across threads."""
        return next(self._rid_counter)

    # -- recording (hot path) -------------------------------------------
    def _ring(self) -> _Ring:
        ring = getattr(self._tls, "ring", None)
        if ring is None:
            th = threading.current_thread()
            ring = _Ring(self.capacity, th.ident or 0, th.name)
            self._tls.ring = ring
            with self._reg_lock:
                self._rings.append(ring)
        return ring

    def _push(self, event: Tuple) -> None:
        ring = self._ring()
        ring.events.append(event)
        ring.pushes += 1

    def instant(self, name: str, cat: str = "engine",
                **args: Any) -> None:
        """Zero-duration event.  Callers on a hot path should guard
        with `if tracer.enabled:` so the kwargs dict is never built."""
        if not self.enabled:
            return
        self._push(("i", self._clock(), 0.0, name, cat, args or None))

    def span(self, name: str, cat: str = "engine", **args: Any):
        """`with tracer.span("prefill_chunk", lanes=3): ...`"""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args or None)

    def complete(self, name: str, t0: float, dur_s: float,
                 cat: str = "engine", **args: Any) -> None:
        """Record a span whose interval was measured by the caller
        (the engine already times its jitted dispatches; re-measuring
        around them would double the clock reads)."""
        if not self.enabled:
            return
        self._push(("X", t0, dur_s, name, cat, args or None))

    # -- export side ----------------------------------------------------
    def rings(self) -> List[_Ring]:
        with self._reg_lock:
            return list(self._rings)

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of every ring as plain dicts (seconds-domain ts);
        obs/export.py turns these into Chrome trace events."""
        out: List[Dict[str, Any]] = []
        for ring in self.rings():
            for ph, t_s, dur_s, name, cat, args in list(ring.events):
                out.append({"ph": ph, "t_s": t_s, "dur_s": dur_s,
                            "name": name, "cat": cat,
                            "tid": ring.tid,
                            "thread_name": ring.thread_name,
                            "args": args})
        out.sort(key=lambda e: e["t_s"])
        return out

    def dropped(self) -> int:
        return sum(r.dropped for r in self.rings())


# process-wide tracer: request ids and the /debug/trace export need one
# namespace across the event loop and every driver thread
_TRACER = Tracer()
if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    _TRACER.enable()


def get_tracer() -> Tracer:
    return _TRACER
