"""Energy-aware serving metrics via the EdgeCIM analytical cost model.

The paper's headline claims are tokens/s AND tokens/J (336 tok/s,
173 tok/J at INT4); the serving stack measures the first but, running
on commodity hardware, cannot measure the second.  This module closes
the gap the way the paper does — analytically: it maps the runtime's
`ModelConfig` onto the simulator's `SLMSpec` and charges every decoded
/ prefilled token its CIM cost (`core/simulator.py` on `core/hw.py`
defaults), so `/metrics` and bench summaries report *simulated* energy
and tokens/J for the exact token/shape stream the engine ran.

These numbers are a model, not a measurement — they answer "what would
this serving trace cost on the EdgeCIM accelerator", which is the
observability hook ROADMAP's quantization item asks for.

Cost shape: a decode step at KV length `seq` is linear in seq (the KV
stream is the only seq-dependent term; weights are streamed in full
regardless), so the meter samples `decode_token` at two seq points and
charges per-token as e0 + de*seq thereafter — two simulator calls at
construction, pure arithmetic on the hot path.  Prefill is charged per
token at the GEMM-regime average cost (weights amortized across the
chunk).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from ..core.hw import HWConfig
from ..core.simulator import EdgeCIMSimulator
from ..core.workload import SLMSpec

_REF_PREFILL = 128      # chunk size for the per-token prefill estimate
_SEQ_LO, _SEQ_HI = 64.0, 1024.0     # linear-fit sample points


def slm_spec_from_model_config(cfg: Any) -> SLMSpec:
    """Map the runtime `models.config.ModelConfig` onto the simulator's
    `SLMSpec`.  Dense/GQA/MLA/local-attention map exactly; MoE maps to
    the active-expert stream; SSM-bearing families (xlstm, zamba) are
    approximated as pure recurrent-state models sized from the config —
    good enough for energy attribution, not for DSE."""
    mla = getattr(cfg, "mla", None)
    moe = getattr(cfg, "moe", None) if cfg.family == "moe" else None
    hd = cfg.hd()

    kw: Dict[str, Any] = dict(
        name=cfg.name,
        n_layers=cfg.n_layers,
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_ff=cfg.d_ff,
        vocab=cfg.vocab,
        head_dim=cfg.head_dim,
        ffn_gated=cfg.ffn_gated,
        qkv_bias=cfg.qkv_bias,
        tie_embeddings=cfg.tie_embeddings,
    )

    if cfg.attn_kind == "mla" and mla is not None:
        kw.update(attn_kind="mla",
                  mla_kv_lora=mla.kv_lora_rank,
                  mla_rope_dim=mla.qk_rope_head_dim,
                  mla_q_nope=mla.qk_nope_head_dim)

    if moe is not None:
        kw.update(n_experts=moe.n_experts, top_k=moe.top_k,
                  n_shared_experts=moe.n_shared_experts,
                  d_ff_expert=moe.d_ff_expert)

    if cfg.local_window and cfg.local_pattern:
        kw.update(local_window=cfg.local_window,
                  local_ratio=(cfg.local_pattern - 1) / cfg.local_pattern)

    ssm = getattr(cfg, "ssm", None)
    if cfg.family in ("xlstm", "zamba") and ssm is not None:
        # recurrent state replaces the KV stream; size it from the
        # config's expansion factors (mamba2-style: d_inner x d_state
        # matrix state per layer; xlstm mLSTM is of the same shape)
        d_inner = int(cfg.d_model * getattr(ssm, "expand", 2))
        state = float(d_inner * getattr(ssm, "d_state", 64))
        # in/out projections + conv + gates, ~3x d_model*d_inner
        w_ssm = 3.0 * cfg.d_model * d_inner
        n_ssm = cfg.n_layers
        if cfg.family == "zamba" and getattr(cfg, "zamba", None):
            # keep the shared attention block as one attn layer's worth
            n_ssm = max(cfg.n_layers - 1, 1)
        kw.update(n_ssm_layers=n_ssm,
                  ssm_state_elems_per_layer=state,
                  ssm_weight_elems_per_layer=w_ssm,
                  ssm_macs_per_layer=w_ssm + state)
        if n_ssm == cfg.n_layers:
            kw.update(attn_kind="none")

    return SLMSpec(**kw)


class EnergyMeter:
    """Charges engine token traffic against the CIM cost model.

    Construction runs three simulator evaluations (two decode seq
    points + one prefill chunk); after that `charge_decode` /
    `charge_prefill` are a multiply-add each, cheap enough to sit
    unconditionally in the engine step loop.
    """

    def __init__(self, model_cfg: Any, *, hw: Optional[HWConfig] = None,
                 w_bits: int = 4, a_bits: int = 8, tp: int = 1):
        """`tp` models tensor parallelism: the engine's weight/KV
        stream is split across `tp` accelerators.  Total bytes moved
        (hence total joules) stay what one accelerator would pay, but
        each device streams 1/tp of them concurrently, so simulated
        wall time divides by tp — the aggregate-bandwidth claim TP
        exists to cash in.  tp == 1 reporting is unchanged."""
        self.hw = hw or HWConfig()
        self.w_bits = w_bits
        self.a_bits = a_bits
        self.tp = max(int(tp), 1)
        self.spec = slm_spec_from_model_config(model_cfg)
        sim = EdgeCIMSimulator()
        lo = sim.decode_token(self.spec, self.hw, _SEQ_LO,
                              w_bits=w_bits, a_bits=a_bits)
        hi = sim.decode_token(self.spec, self.hw, _SEQ_HI,
                              w_bits=w_bits, a_bits=a_bits)
        span = _SEQ_HI - _SEQ_LO
        self._de_j = (hi.joules - lo.joules) / span
        self._e0_j = lo.joules - self._de_j * _SEQ_LO
        self._ds_s = (hi.seconds - lo.seconds) / span
        self._s0_s = lo.seconds - self._ds_s * _SEQ_LO
        pf = sim.prefill(self.spec, self.hw, _REF_PREFILL,
                         w_bits=w_bits, a_bits=a_bits)
        self._prefill_j_per_tok = pf.joules / _REF_PREFILL
        self._prefill_s_per_tok = pf.seconds / _REF_PREFILL

        self.decode_j = 0.0
        self.prefill_j = 0.0
        self.sim_s = 0.0
        self.decode_sim_s = 0.0     # decode-only modeled time: the
        # predicted clock the drift auditor (obs/drift.py) holds
        # against Telemetry.decode_s — prefill must not blur it
        self.decode_tokens = 0
        self.prefill_tokens = 0

    def reset(self) -> None:
        """Zero the accumulators (keeps the fitted cost model) — bench
        warmup resets this alongside Telemetry so reported tokens/J
        covers only the measured window."""
        self.decode_j = self.prefill_j = self.sim_s = 0.0
        self.decode_sim_s = 0.0
        self.decode_tokens = self.prefill_tokens = 0

    # -- accounting -----------------------------------------------------
    def decode_cost_j(self, seq: float) -> float:
        """Simulated joules for ONE decode token at KV length `seq`."""
        return self._e0_j + self._de_j * seq

    def charge_decode(self, n_tokens: int, mean_seq: float) -> None:
        """Charge `n_tokens` decode-lane tokens at mean KV length
        `mean_seq` (cost is linear in seq, so the mean is exact)."""
        if n_tokens <= 0:
            return
        self.decode_j += n_tokens * (self._e0_j + self._de_j * mean_seq)
        sim_s = n_tokens * (self._s0_s + self._ds_s * mean_seq)
        self.sim_s += sim_s
        self.decode_sim_s += sim_s
        self.decode_tokens += n_tokens

    def charge_prefill(self, n_tokens: int) -> None:
        if n_tokens <= 0:
            return
        self.prefill_j += n_tokens * self._prefill_j_per_tok
        self.sim_s += n_tokens * self._prefill_s_per_tok
        self.prefill_tokens += n_tokens

    # -- reporting ------------------------------------------------------
    @property
    def total_j(self) -> float:
        return self.decode_j + self.prefill_j

    def tokens_per_j(self) -> float:
        return self.decode_tokens / self.total_j if self.total_j > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        """Keys merged into the engine summary / `/metrics` payload.
        `sim_*` prefix flags every value as cost-model output, not a
        wall-clock measurement.

        At tp > 1 the aggregate keys stay engine-level (energy sums
        across shards; wall time divides by the tp-way bandwidth) and
        per-device keys carry each shard's slice, so a fleet rollup of
        TP engines still sums joules correctly (`sim_energy_j` is in
        `fleet.router._SUM_KEYS`; the per-device keys average)."""
        wall_s = self.sim_s / self.tp
        out = {
            "sim_energy_j": self.total_j,
            "sim_decode_energy_j": self.decode_j,
            "sim_prefill_energy_j": self.prefill_j,
            "sim_time_s": wall_s,
            # decode-only modeled wall time (tp-scaled like sim_time_s):
            # the drift audit's predicted clock
            "sim_decode_time_s": self.decode_sim_s / self.tp,
            "sim_decode_tokens": float(self.decode_tokens),
            "sim_tokens_per_j": self.tokens_per_j(),
            "sim_tokens_per_s": (self.decode_tokens / wall_s
                                 if wall_s > 0 else 0.0),
            # the precision the cost model was fitted at (engine sets
            # these from ServeConfig: int4 = the paper's operating
            # point, 16/16 = the fp baseline)
            "sim_w_bits": float(self.w_bits),
            "sim_a_bits": float(self.a_bits),
        }
        if self.tp > 1:
            out.update({
                "sim_tp": float(self.tp),
                "sim_energy_j_per_device": self.total_j / self.tp,
                "sim_decode_energy_j_per_device": self.decode_j / self.tp,
                "sim_time_s_per_device": wall_s,
            })
        return out
