"""`paged_flash_decode` — block-table paged decode attention Pallas kernel.

The paged-KV serving runtime keeps K/V in a shared pool of fixed-size
pages; each sequence owns a list of page ids (its block table).  This
kernel is `flash_decode` with the KV stream INDIRECTED through the block
table: the table and the per-sequence lengths ride in as scalar-prefetch
operands, so the grid's page dimension DMAs exactly the pages the
sequence owns (EdgeCIM's KV-block streaming, Sec. III-C2, with paging on
top).  Online-softmax state (m, l, acc) lives in VMEM scratch across the
page dimension.

Grid: (batch, kv_head, seq_page).  Padded table entries must hold a
valid page id (the engine pads with 0); their scores are masked by the
length operand, so the gathered garbage never contributes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, *rest, page_size: int,
            n_i: int, scale: float, window: int, attn_cap: float,
            quant: bool = False):
    if quant:
        # per-token INT8 pools: scale blocks (1, page_size, 1) ride the
        # same block-table index map as their K/V pages
        ks_ref, vs_ref, o_ref = rest[0], rest[1], rest[2]
        m_ref, l_ref, acc_ref = rest[3], rest[4], rest[5]
    else:
        o_ref, m_ref, l_ref, acc_ref = rest[0], rest[1], rest[2], rest[3]
    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)

    @pl.when(i_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b_idx]
    q = q_ref[0, 0].astype(jnp.float32)                 # (qpk, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (page_size, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if attn_cap:
        s = attn_cap * jnp.tanh(s / attn_cap)
    k_pos = i_idx * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = k_pos < length
    if window:
        valid = valid & ((length - 1) - k_pos < window)
    s = jnp.where(valid, s, NEG_INF)                    # (qpk, page_size)

    m_prev = m_ref[...]                                 # (qpk, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i_idx == n_i - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _verify_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                   page_size: int, n_i: int, qpk: int, scale: float,
                   window: int, attn_cap: float, quant: bool = False):
    """Multi-query variant: the q block carries s query positions (rows
    j*qpk..j*qpk+qpk-1 are position lengths[b]+j), each with its own
    causal horizon — verification of a k-token draft window in ONE pass
    over the sequence's pages (decode GEMV -> small-batch GEMM)."""
    if quant:
        ks_ref, vs_ref, o_ref = rest[0], rest[1], rest[2]
        m_ref, l_ref, acc_ref = rest[3], rest[4], rest[5]
    else:
        o_ref, m_ref, l_ref, acc_ref = rest[0], rest[1], rest[2], rest[3]
    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)

    @pl.when(i_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b_idx]                             # tokens BEFORE window
    q = q_ref[0, 0].astype(jnp.float32)                 # (s*qpk, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (page_size, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    sq = q.shape[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if attn_cap:
        s = attn_cap * jnp.tanh(s / attn_cap)
    k_pos = i_idx * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (sq, page_size), 1)
    q_pos = length + jax.lax.broadcasted_iota(
        jnp.int32, (sq, page_size), 0) // qpk           # intra-window causal
    valid = k_pos <= q_pos
    if window:
        valid = valid & (q_pos - k_pos < window)
    s = jnp.where(valid, s, NEG_INF)                    # (s*qpk, page_size)

    m_prev = m_ref[...]                                 # (s*qpk, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i_idx == n_i - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _page_specs(page_size: int, hd: int, quant: bool):
    """K/V (and, when quant, per-token scale) BlockSpecs sharing the
    block-table index map: page i of lane bi streams pool page
    tab[bi, i] for kv-head gi."""
    kv = pl.BlockSpec((1, page_size, 1, hd), lambda bi, gi, i, tab, ln:
                      (tab[bi, i], 0, gi, 0))
    specs = [kv, kv]
    if quant:
        sc = pl.BlockSpec((1, page_size, 1), lambda bi, gi, i, tab, ln:
                          (tab[bi, i], 0, gi))
        specs += [sc, sc]
    return specs


@functools.partial(jax.jit, static_argnames=("window", "attn_cap",
                                             "interpret"))
def paged_flash_verify(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       tables: jax.Array, lengths: jax.Array,
                       window: int = 0, attn_cap: float = 0.0,
                       interpret: bool = False,
                       k_scales: jax.Array = None,
                       v_scales: jax.Array = None) -> jax.Array:
    """Speculative-verify attention over the paged pool.

    q: (b, s, g, qpk, hd) — s draft-window query positions per lane;
    query j of lane i sits at absolute position lengths[i] + j and
    attends k_pos <= lengths[i] + j (its own K row is already scattered
    into the pool).  lengths counts tokens cached BEFORE this window
    (exclusive — unlike `paged_flash_decode`, whose lengths include the
    current token).  With k_scales/v_scales ((n_pages, page_size, g)
    f16) the pools are per-token INT8 and dequantized in-register after
    each page DMA.  Returns (b, s, g, qpk, hd).
    """
    b, s, g, qpk, hd = q.shape
    page_size = k_pages.shape[1]
    max_pages = tables.shape[1]
    scale = 1.0 / (hd ** 0.5)
    qf = q.transpose(0, 2, 1, 3, 4).reshape(b, g, s * qpk, hd)
    quant = k_scales is not None

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, g, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, s * qpk, hd), lambda bi, gi, i, tab, ln:
                         (bi, gi, 0, 0)),
            *_page_specs(page_size, hd, quant),
        ],
        out_specs=pl.BlockSpec((1, 1, s * qpk, hd), lambda bi, gi, i, tab, ln:
                               (bi, gi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((s * qpk, 1), jnp.float32),
            pltpu.VMEM((s * qpk, 1), jnp.float32),
            pltpu.VMEM((s * qpk, hd), jnp.float32),
        ],
    )
    operands = (qf, k_pages, v_pages)
    if quant:
        operands += (k_scales, v_scales)
    out = pl.pallas_call(
        functools.partial(_verify_kernel, page_size=page_size,
                          n_i=max_pages, qpk=qpk, scale=scale,
                          window=window, attn_cap=attn_cap, quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, s * qpk, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    return out.reshape(b, g, s, qpk, hd).transpose(0, 2, 1, 3, 4)


@functools.partial(jax.jit, static_argnames=("window", "attn_cap",
                                             "interpret"))
def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       tables: jax.Array, lengths: jax.Array,
                       window: int = 0, attn_cap: float = 0.0,
                       interpret: bool = False,
                       k_scales: jax.Array = None,
                       v_scales: jax.Array = None) -> jax.Array:
    """q: (b, g, qpk, hd); k_pages/v_pages: (n_pages, page_size, g, hd);
    tables: (b, max_pages) int32; lengths: (b,) int32 valid tokens per
    sequence (inclusive of the current token).  With k_scales/v_scales
    ((n_pages, page_size, g) f16) the pools are per-token INT8, streamed
    packed and dequantized in-register — KV DMA bytes drop ~2x vs bf16.
    Returns (b, g, qpk, hd).
    """
    b, g, qpk, hd = q.shape
    page_size = k_pages.shape[1]
    max_pages = tables.shape[1]
    scale = 1.0 / (hd ** 0.5)
    quant = k_scales is not None

    # pools stay in their storage layout (n_pages, ps, g, hd): the block
    # table drives the page index and the kv-head rides as a unit axis,
    # so no whole-pool transpose/copy happens per decode step
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, g, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, qpk, hd), lambda bi, gi, i, tab, ln:
                         (bi, gi, 0, 0)),
            *_page_specs(page_size, hd, quant),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, hd), lambda bi, gi, i, tab, ln:
                               (bi, gi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, hd), jnp.float32),
        ],
    )
    operands = (q, k_pages, v_pages)
    if quant:
        operands += (k_scales, v_scales)
    return pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, n_i=max_pages,
                          scale=scale, window=window, attn_cap=attn_cap,
                          quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, qpk, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
