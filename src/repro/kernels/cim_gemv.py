"""`cim_gemv` — quantized weight-stationary GEMV/GEMM Pallas TPU kernel.

The EdgeCIM DCIM macro, rethought for the TPU memory hierarchy
(DESIGN.md SS2): instead of bit-serial SRAM arrays, packed INT4/INT8
weight blocks stream HBM -> VMEM through the Pallas grid pipeline (the
hardware double-buffering plays the paper's "active tiles prefetch while
compute proceeds" role), are dequantized in-register against per-group
scales, and hit the MXU as fp32 tiles.  The K-grid dimension is the
paper's partition stream; accumulation lives in a VMEM fp32 scratch.

Block shapes are MXU-aligned (multiples of 128 on the N dim; the K block
a multiple of the quantization group so scales tile cleanly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 512


def _dequant_block_int4(w_ref, s_ref, group: int) -> jax.Array:
    """(K/2, N) uint8 packed + (K/group, N) scales -> (K, N) f32."""
    packed = w_ref[...]
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    k2, n = packed.shape
    q = jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)       # (K, N) int8
    scales = s_ref[...].astype(jnp.float32)                   # (K/g, N)
    qg = q.reshape(scales.shape[0], group, n).astype(jnp.float32)
    return (qg * scales[:, None, :]).reshape(2 * k2, n)


def _dequant_block_int8(w_ref, s_ref, group: int) -> jax.Array:
    q = w_ref[...]
    k, n = q.shape
    scales = s_ref[...].astype(jnp.float32)
    qg = q.reshape(scales.shape[0], group, n).astype(jnp.float32)
    return (qg * scales[:, None, :]).reshape(k, n)


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bits: int, group: int,
            n_k: int):
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if bits == 4:
        w = _dequant_block_int4(w_ref, s_ref, group)
    else:
        w = _dequant_block_int8(w_ref, s_ref, group)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_n",
                                             "block_k", "interpret"))
def cim_gemv(x: jax.Array, packed: jax.Array, scales: jax.Array,
             bits: int = 4, group: int = 128,
             block_n: int = DEFAULT_BLOCK_N, block_k: int = DEFAULT_BLOCK_K,
             interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16/f32; packed: (K/2, N) uint8 [int4] or (K, N) int8;
    scales: (K/group, N) bf16.  Returns (M, N) in x.dtype.

    Grid = (N blocks "parallel", K blocks "arbitrary"): K innermost so the
    fp32 accumulator carries across the weight-partition stream, exactly
    the EdgeCIM accumulate-across-partitions schedule (Sec. III-C1).
    """
    m, K = x.shape
    N = packed.shape[-1]
    block_k = min(block_k, K)
    block_n = min(block_n, N)
    assert K % block_k == 0, (K, block_k)
    assert N % block_n == 0, (N, block_n)
    assert block_k % group == 0, (block_k, group)
    n_k = K // block_k
    grid = (N // block_n, n_k)
    w_rows = block_k // 2 if bits == 4 else block_k

    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda n, k: (0, k)),
            pl.BlockSpec((w_rows, block_n), lambda n, k: (k, n)),
            pl.BlockSpec((block_k // group, block_n), lambda n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((m, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales)
