"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from .ops import (qmatmul, qmatmul_xla, decode_attention,
                  paged_decode_attention, swiglu)
from .cim_gemv import cim_gemv
from .flash_decode import flash_decode
from .paged_flash_decode import paged_flash_decode
from .swiglu_gemv import swiglu_qgemv
from . import ref

__all__ = ["qmatmul", "qmatmul_xla", "decode_attention",
           "paged_decode_attention", "swiglu", "cim_gemv", "flash_decode",
           "paged_flash_decode", "swiglu_qgemv", "ref"]
