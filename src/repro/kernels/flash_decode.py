"""`flash_decode` — KV-block streamed decode attention Pallas kernel.

EdgeCIM's attention stage (Sec. III-C2): K/V stream from DRAM in blocks of
(b x d_h); block-level scores feed a block-wise softmax unit following
FlashAttention.  On TPU: the KV-sequence grid dimension streams cache
blocks HBM -> VMEM, an online-softmax state (m, l, acc) carried in VMEM
scratch plays the paper's accumulators, and sliding-window layers
(gemma-style locals) mask at block granularity.

Layout: one grid step per (batch*kv_head, kv block); GQA query groups ride
along in the q block (qpk x hd tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1.0e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_s: int, n_s: int, scale: float, window: int,
            attn_cap: float):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32)                    # (qpk, hd)
    k = k_ref[0].astype(jnp.float32)                    # (block_s, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if attn_cap:
        s = attn_cap * jnp.tanh(s / attn_cap)
    k_pos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    valid = k_pos <= pos
    if window:
        valid = valid & (pos - k_pos < window)
    s = jnp.where(valid, s, NEG_INF)                    # (qpk, block_s)

    m_prev = m_ref[...]                                 # (qpk, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "window",
                                             "attn_cap", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
                 block_s: int = DEFAULT_BLOCK_S, window: int = 0,
                 attn_cap: float = 0.0, interpret: bool = False
                 ) -> jax.Array:
    """q: (bg, qpk, hd); k, v: (bg, S, hd); pos: scalar int32.

    bg = batch * kv_heads (flattened outer grid).  Returns (bg, qpk, hd).
    """
    bg, qpk, hd = q.shape
    S = k.shape[1]
    block_s = min(block_s, S)
    assert S % block_s == 0
    n_s = S // block_s
    scale = 1.0 / (hd ** 0.5)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, n_s=n_s, scale=scale,
                          window=window, attn_cap=attn_cap),
        grid=(bg, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, qpk, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, qpk, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bg, qpk, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, 1), jnp.float32),
            pltpu.VMEM((qpk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k, v)
