"""`swiglu_gemv` — fused gate/up quantized GEMV + SiLU*mul epilogue.

EdgeCIM's FFN stage maps the up and gate matrices onto the PEs *in
parallel* and fuses activation + elementwise-multiply on dedicated units
(Sec. III-C4).  TPU image: both quantized weight blocks ride the same
K-stream; the SiLU*mul epilogue runs on the VPU at the last K step, so the
intermediate gate/up activations never round-trip to HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .cim_gemv import (_dequant_block_int4, _dequant_block_int8,
                       DEFAULT_BLOCK_K, DEFAULT_BLOCK_N)


def _kernel(x_ref, wg_ref, sg_ref, wu_ref, su_ref, o_ref, accg_ref,
            accu_ref, *, bits: int, group: int, n_k: int):
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    deq = _dequant_block_int4 if bits == 4 else _dequant_block_int8
    x = x_ref[...].astype(jnp.float32)
    accg_ref[...] += jnp.dot(x, deq(wg_ref, sg_ref, group),
                             preferred_element_type=jnp.float32)
    accu_ref[...] += jnp.dot(x, deq(wu_ref, su_ref, group),
                             preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        g = accg_ref[...]
        o_ref[...] = (g * jax.nn.sigmoid(g) * accu_ref[...]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "group", "block_n",
                                             "block_k", "interpret"))
def swiglu_qgemv(x: jax.Array, wg_packed: jax.Array, wg_scales: jax.Array,
                 wu_packed: jax.Array, wu_scales: jax.Array, bits: int = 4,
                 group: int = 128, block_n: int = DEFAULT_BLOCK_N,
                 block_k: int = DEFAULT_BLOCK_K, interpret: bool = False
                 ) -> jax.Array:
    """x: (M, K); gate/up packed like cim_gemv. Returns (M, F)."""
    m, K = x.shape
    F = wg_packed.shape[-1]
    block_k = min(block_k, K)
    block_n = min(block_n, F)
    assert K % block_k == 0 and F % block_n == 0
    assert block_k % group == 0
    n_k = K // block_k
    w_rows = block_k // 2 if bits == 4 else block_k

    wspec = pl.BlockSpec((w_rows, block_n), lambda n, k: (k, n))
    sspec = pl.BlockSpec((block_k // group, block_n), lambda n, k: (k, n))
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group=group, n_k=n_k),
        grid=(F // block_n, n_k),
        in_specs=[pl.BlockSpec((m, block_k), lambda n, k: (0, k)),
                  wspec, sspec, wspec, sspec],
        out_specs=pl.BlockSpec((m, block_n), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((m, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((m, block_n), jnp.float32),
                        pltpu.VMEM((m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, wg_packed, wg_scales, wu_packed, wu_scales)
