"""jit'd public wrappers for the Pallas kernels.

On the CPU container (no TPU backend) the kernels execute in
interpret=True mode; the same call sites compile to real Mosaic kernels on
TPU.  `qmatmul` additionally falls back to the pure-jnp reference when
shapes are not tile-aligned (ragged edges) so model code can call it
unconditionally.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.qarray import QTensor, count_dequant, maybe_dequantize

from .cim_gemv import cim_gemv
from .flash_decode import flash_decode
from .paged_flash_decode import paged_flash_decode, paged_flash_verify
from .ref import (ref_flash_decode, ref_paged_decode, ref_paged_verify,
                  ref_qmatmul, ref_qmatmul_fused, ref_swiglu_qgemv)
from .swiglu_gemv import swiglu_qgemv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tile_ok(qt: QTensor) -> bool:
    K, N = qt.orig_shape[0], qt.orig_shape[-1]
    return (qt.ndim == 2 and qt.axis == -2 and K % qt.group == 0
            and N % 128 == 0 and K % 256 == 0)


def qmatmul(x: jax.Array, w: Any) -> jax.Array:
    """x @ W for dense or QTensor weights, kernel-accelerated when aligned."""
    if not isinstance(w, QTensor):
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if _tile_ok(w) and x2.shape[0] <= 1024:
        count_dequant("fused_dequant")
        out = cim_gemv(x2, w.data, w.scales, bits=w.bits, group=w.group,
                       interpret=_interpret())
    else:
        out = ref_qmatmul(x2, w)
    return out.reshape(*lead, w.orig_shape[-1])


def qmatmul_xla(x: jax.Array, w: Any) -> jax.Array:
    """Fused grouped contraction on the XLA path (used for pjit lowering:
    keeps HLO free of pallas custom-calls while preserving the quantized
    bytes).  The weight stays integer end-to-end — scales multiply group
    partial sums, so no float copy of W is ever materialized (the
    serve-path residency invariant tracked by `qarray.dequant_counters`)."""
    if not isinstance(w, QTensor):
        return x @ w
    return ref_qmatmul_fused(x, w)


def qmatmul_fused(x: jax.Array, w: Any) -> jax.Array:
    """Serve-path x @ W: `cim_gemv` Pallas kernel on TPU when the packed
    weight is tile-aligned and the row count is decode-sized, the fused
    grouped-einsum reference otherwise.  Either way the float weight is
    never materialized."""
    if not isinstance(w, QTensor):
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if not _interpret() and _tile_ok(w) and x2.shape[0] <= 1024:
        count_dequant("fused_dequant")
        out = cim_gemv(x2, w.data, w.scales, bits=w.bits, group=w.group)
        return out.reshape(*lead, w.orig_shape[-1])
    return ref_qmatmul_fused(x, w)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, window: int = 0, attn_cap: float = 0.0,
                     use_kernel: bool = True) -> jax.Array:
    """q: (b,g,qpk,hd); k/v: (b,S,g,hd) -> (b,g,qpk,hd)."""
    b, g, qpk, hd = q.shape
    S = k.shape[1]
    if not use_kernel or S % 512 != 0:
        return ref_flash_decode(q, k, v, pos, window, attn_cap)
    qf = q.reshape(b * g, qpk, hd)
    kf = k.swapaxes(1, 2).reshape(b * g, S, hd)
    vf = v.swapaxes(1, 2).reshape(b * g, S, hd)
    out = flash_decode(qf, kf, vf, pos, window=window, attn_cap=attn_cap,
                       interpret=_interpret())
    return out.reshape(b, g, qpk, hd)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, tables: jax.Array,
                           lengths: jax.Array, window: int = 0,
                           attn_cap: float = 0.0,
                           use_kernel: bool = None,
                           k_scales: jax.Array = None,
                           v_scales: jax.Array = None) -> jax.Array:
    """Paged decode attention: q (b,g,qpk,hd), pools (n_pages,ps,g,hd),
    tables (b,max_pages), lengths (b,) -> (b,g,qpk,hd).

    Routes to the Pallas block-table kernel on TPU (the gather never
    materializes); the pure-jnp gather reference is the lowering path
    everywhere else (and the oracle the kernel is tested against).
    With k_scales/v_scales the pools are per-token INT8 and dequantized
    in-kernel (or post-gather on the reference path).
    """
    if use_kernel is None:
        use_kernel = not _interpret()
    if not use_kernel:
        return ref_paged_decode(q, k_pages, v_pages, tables, lengths,
                                window, attn_cap, k_scales, v_scales)
    return paged_flash_decode(q, k_pages, v_pages, tables, lengths,
                              window=window, attn_cap=attn_cap,
                              interpret=_interpret(),
                              k_scales=k_scales, v_scales=v_scales)


def paged_verify_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, tables: jax.Array,
                           lengths: jax.Array, window: int = 0,
                           attn_cap: float = 0.0,
                           use_kernel: bool = None,
                           k_scales: jax.Array = None,
                           v_scales: jax.Array = None) -> jax.Array:
    """Multi-query paged attention for speculative verify windows.

    q: (b, s, g, qpk, hd) — s draft positions per lane, query j at
    absolute position lengths[i] + j; lengths EXCLUDE the window.
    Pallas multi-query kernel on TPU (one pass over the sequence's
    pages verifies the whole window), jnp gather oracle elsewhere.
    k_scales/v_scales mark the pools as per-token INT8.
    Returns (b, s, g, qpk, hd).
    """
    if use_kernel is None:
        use_kernel = not _interpret()
    if not use_kernel:
        return ref_paged_verify(q, k_pages, v_pages, tables, lengths,
                                window, attn_cap, k_scales, v_scales)
    return paged_flash_verify(q, k_pages, v_pages, tables, lengths,
                              window=window, attn_cap=attn_cap,
                              interpret=_interpret(),
                              k_scales=k_scales, v_scales=v_scales)


def swiglu(x: jax.Array, w_gate: Any, w_up: Any) -> jax.Array:
    """Fused quantized SwiGLU: Pallas kernel when tile-aligned on TPU,
    fused grouped-einsum reference otherwise — packed weights stay
    integer on every route."""
    if (isinstance(w_gate, QTensor) and isinstance(w_up, QTensor)
            and not _interpret() and _tile_ok(w_gate) and _tile_ok(w_up)):
        count_dequant("fused_dequant")
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        out = swiglu_qgemv(x2, w_gate.data, w_gate.scales, w_up.data,
                           w_up.scales, bits=w_gate.bits, group=w_gate.group)
        return out.reshape(*lead, w_gate.orig_shape[-1])
    g = qmatmul_xla(x, w_gate).astype(jnp.float32)
    u = qmatmul_xla(x, w_up).astype(jnp.float32)
    return (g * jax.nn.sigmoid(g) * u).astype(x.dtype)
