"""Pure-jnp oracles for every Pallas kernel (and the lowering path used by
the dry-run on the CPU backend — identical math, identical shardability)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.qarray import (QTensor, count_dequant, dequantize,
                                maybe_dequantize, unpack_int4)


def ref_qmatmul(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """x @ W with W dense or QTensor (dequant-then-matmul oracle)."""
    wd = maybe_dequantize(w, jnp.bfloat16 if out_dtype is None else out_dtype)
    return jnp.dot(x, wd.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(
        out_dtype or x.dtype)


def _int_weight(qt: QTensor) -> jax.Array:
    """Packed data -> int8 values at full size (scales NOT applied)."""
    return unpack_int4(qt.data, qt.axis) if qt.bits == 4 else qt.data


def ref_qmatmul_fused(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """x @ W with W held as integers end-to-end: per-group partial sums
    contracted against the f16 scales — the CPU-backend image of the
    `cim_gemv` in-kernel dequant.  Never materializes the float weight
    (a whole-tensor `dequantize` would bump the `full_dequant` trace
    counter; this path bumps `fused_dequant` instead).

    Handles the three serve-path layouts: 2D (K, N) axis=-2 projections,
    batched (E, K, N) axis=-2 expert stacks (x: (E, ..., K)), and the
    axis=-1 (V, K) tied-embedding table contracted over K for logits.

    Shapes are inferred from the DATA arrays, never `orig_shape`: under
    `lax.scan` a stacked QTensor's leaves are sliced per layer while the
    static orig_shape aux keeps the layer dim (the same reason `axis` is
    stored negative).
    """
    if not isinstance(w, QTensor):
        return ref_qmatmul(x, w, out_dtype)
    count_dequant("fused_dequant")
    g = w.group
    q = _int_weight(w)
    xf = x.astype(jnp.float32)
    sf = w.scales.astype(jnp.float32)
    if w.axis == -1:
        # (V, K) table, contraction over K: logits = h @ embed.T
        V, K = q.shape[-2], q.shape[-1]
        xg = xf.reshape(*x.shape[:-1], K // g, g)
        qg = q.reshape(V, K // g, g).astype(jnp.float32)
        partial = jnp.einsum("...ag,vag->...av", xg, qg)
        out = jnp.einsum("...av,va->...v", partial, sf)
        return out.astype(out_dtype or x.dtype)
    assert w.axis == -2, w.axis
    K, N = q.shape[-2], q.shape[-1]
    lead = q.shape[:-2]
    xg = xf.reshape(*x.shape[:-1], K // g, g)
    qg = q.reshape(*lead, K // g, g, N).astype(jnp.float32)
    if not lead:
        partial = jnp.einsum("...ag,agn->...an", xg, qg)
        out = jnp.einsum("...an,an->...n", partial, sf)
    else:
        # batched expert stack: W's leading dim pairs with x's leading dim
        assert len(lead) == 1 and x.shape[0] == lead[0], (x.shape, q.shape)
        partial = jnp.einsum("e...ag,eagn->e...an", xg, qg)
        out = jnp.einsum("e...an,ean->e...n", partial, sf)
    return out.astype(out_dtype or x.dtype)


def ref_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, window: int = 0,
                     attn_cap: float = 0.0) -> jax.Array:
    """Single-token decode attention oracle.

    q: (b, g, qpk, hd); k, v: (b, S, g, hd); pos scalar; returns
    (b, g, qpk, hd).
    """
    hd = q.shape[-1]
    S = k.shape[1]
    scores = jnp.einsum("bgph,bkgh->bgpk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_cap:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    k_pos = jnp.arange(S)
    mask = k_pos <= pos
    if window:
        mask = mask & (pos - k_pos < window)
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgpk,bkgh->bgph", w.astype(v.dtype), v)


def _gather_pages(pages: jax.Array, tables: jax.Array, b: int, S: int,
                  scales: Optional[jax.Array] = None) -> jax.Array:
    """Gather pool pages by block table; with `scales` (per-page INT8
    quantized pool, scales (n_pages, ps, g)) dequantize ONLY the gathered
    rows — the full pool never exists in float."""
    x = pages[tables].reshape(b, S, *pages.shape[2:])
    if scales is None:
        return x
    s = scales[tables].reshape(b, S, *scales.shape[2:])
    return x.astype(jnp.float32) * s[..., None].astype(jnp.float32)


def ref_paged_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array,
                     window: int = 0, attn_cap: float = 0.0,
                     k_scales: Optional[jax.Array] = None,
                     v_scales: Optional[jax.Array] = None) -> jax.Array:
    """Paged single-token decode attention oracle (block-table gather).

    q: (b, g, qpk, hd); k_pages, v_pages: (n_pages, page_size, g, hd);
    tables: (b, max_pages) int32 page ids (padded entries must be valid
    indices — they are masked out); lengths: (b,) int32 tokens valid per
    sequence INCLUSIVE of the current one.  With k_scales/v_scales the
    pools are per-token INT8 (scales (n_pages, page_size, g) f16) and are
    dequantized after the gather.  Returns (b, g, qpk, hd).
    """
    b = q.shape[0]
    hd = q.shape[-1]
    n_pg, ps = k_pages.shape[0], k_pages.shape[1]
    S = tables.shape[1] * ps
    k = _gather_pages(k_pages, tables, b, S, k_scales)
    v = _gather_pages(v_pages, tables, b, S, v_scales)
    scores = jnp.einsum("bgph,bkgh->bgpk", q, k.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_cap:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    k_pos = jnp.arange(S)
    mask = k_pos[None, :] < lengths[:, None]
    if window:
        mask = mask & ((lengths[:, None] - 1) - k_pos[None, :] < window)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgpk,bkgh->bgph", w.astype(q.dtype),
                      v.astype(q.dtype))


def ref_paged_verify(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array,
                     window: int = 0, attn_cap: float = 0.0,
                     k_scales: Optional[jax.Array] = None,
                     v_scales: Optional[jax.Array] = None) -> jax.Array:
    """Multi-query paged verify oracle (speculative-decode windows).

    q: (b, s, g, qpk, hd) — query j of lane i sits at absolute position
    lengths[i] + j (its K/V rows are already scattered into the pool);
    lengths: (b,) int32 tokens cached BEFORE the window (EXCLUSIVE of
    the window, unlike `ref_paged_decode`).  Intra-window causal mask:
    query j sees k_pos <= lengths[i] + j.  Returns (b, s, g, qpk, hd).
    """
    b, s = q.shape[0], q.shape[1]
    hd = q.shape[-1]
    ps = k_pages.shape[1]
    S = tables.shape[1] * ps
    k = _gather_pages(k_pages, tables, b, S, k_scales)
    v = _gather_pages(v_pages, tables, b, S, v_scales)
    scores = jnp.einsum("bqgph,bkgh->bgpqk", q, k.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_cap:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    k_pos = jnp.arange(S)
    q_pos = lengths[:, None] + jnp.arange(s)[None, :]           # (b, s)
    mask = k_pos[None, None, :] <= q_pos[:, :, None]            # (b, s, S)
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgpqk,bkgh->bqgph", w.astype(q.dtype),
                      v.astype(q.dtype))


def ref_swiglu_qgemv(x: jax.Array, w_gate, w_up) -> jax.Array:
    """Fused gate/up GEMV + SiLU*mul oracle. x: (m, d) -> (m, f).

    Uses the fused grouped contraction so the CPU serving path keeps
    packed weights integer end-to-end, matching `swiglu_qgemv`."""
    g = ref_qmatmul_fused(x, w_gate, out_dtype=jnp.float32)
    u = ref_qmatmul_fused(x, w_up, out_dtype=jnp.float32)
    return (g * jax.nn.sigmoid(g) * u).astype(x.dtype)
