"""Pure-jnp oracles for every Pallas kernel (and the lowering path used by
the dry-run on the CPU backend — identical math, identical shardability)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.qarray import QTensor, dequantize, maybe_dequantize


def ref_qmatmul(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """x @ W with W dense or QTensor (dequant-then-matmul oracle)."""
    wd = maybe_dequantize(w, jnp.bfloat16 if out_dtype is None else out_dtype)
    return jnp.dot(x, wd.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(
        out_dtype or x.dtype)


def ref_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, window: int = 0,
                     attn_cap: float = 0.0) -> jax.Array:
    """Single-token decode attention oracle.

    q: (b, g, qpk, hd); k, v: (b, S, g, hd); pos scalar; returns
    (b, g, qpk, hd).
    """
    hd = q.shape[-1]
    S = k.shape[1]
    scores = jnp.einsum("bgph,bkgh->bgpk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_cap:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    k_pos = jnp.arange(S)
    mask = k_pos <= pos
    if window:
        mask = mask & (pos - k_pos < window)
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgpk,bkgh->bgph", w.astype(v.dtype), v)


def ref_paged_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array,
                     window: int = 0, attn_cap: float = 0.0) -> jax.Array:
    """Paged single-token decode attention oracle (block-table gather).

    q: (b, g, qpk, hd); k_pages, v_pages: (n_pages, page_size, g, hd);
    tables: (b, max_pages) int32 page ids (padded entries must be valid
    indices — they are masked out); lengths: (b,) int32 tokens valid per
    sequence INCLUSIVE of the current one.  Returns (b, g, qpk, hd).
    """
    b = q.shape[0]
    hd = q.shape[-1]
    n_pg, ps = k_pages.shape[0], k_pages.shape[1]
    S = tables.shape[1] * ps
    k = k_pages[tables].reshape(b, S, *k_pages.shape[2:])
    v = v_pages[tables].reshape(b, S, *v_pages.shape[2:])
    scores = jnp.einsum("bgph,bkgh->bgpk", q, k.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_cap:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    k_pos = jnp.arange(S)
    mask = k_pos[None, :] < lengths[:, None]
    if window:
        mask = mask & ((lengths[:, None] - 1) - k_pos[None, :] < window)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgpk,bkgh->bgph", w.astype(q.dtype),
                      v.astype(q.dtype))


def ref_paged_verify(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array,
                     window: int = 0, attn_cap: float = 0.0) -> jax.Array:
    """Multi-query paged verify oracle (speculative-decode windows).

    q: (b, s, g, qpk, hd) — query j of lane i sits at absolute position
    lengths[i] + j (its K/V rows are already scattered into the pool);
    lengths: (b,) int32 tokens cached BEFORE the window (EXCLUSIVE of
    the window, unlike `ref_paged_decode`).  Intra-window causal mask:
    query j sees k_pos <= lengths[i] + j.  Returns (b, s, g, qpk, hd).
    """
    b, s = q.shape[0], q.shape[1]
    hd = q.shape[-1]
    ps = k_pages.shape[1]
    S = tables.shape[1] * ps
    k = k_pages[tables].reshape(b, S, *k_pages.shape[2:])
    v = v_pages[tables].reshape(b, S, *v_pages.shape[2:])
    scores = jnp.einsum("bqgph,bkgh->bgpqk", q, k.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if attn_cap:
        scores = attn_cap * jnp.tanh(scores / attn_cap)
    k_pos = jnp.arange(S)
    q_pos = lengths[:, None] + jnp.arange(s)[None, :]           # (b, s)
    mask = k_pos[None, None, :] <= q_pos[:, :, None]            # (b, s, S)
    if window:
        mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgpqk,bkgh->bqgph", w.astype(q.dtype),
                      v.astype(q.dtype))


def ref_swiglu_qgemv(x: jax.Array, w_gate, w_up) -> jax.Array:
    """Fused gate/up GEMV + SiLU*mul oracle. x: (m, d) -> (m, f)."""
    g = ref_qmatmul(x, w_gate, out_dtype=jnp.float32)
    u = ref_qmatmul(x, w_up, out_dtype=jnp.float32)
    return (g * jax.nn.sigmoid(g) * u).astype(x.dtype)
