"""Checkpoint/restore with atomic writes and elastic resharding.

Layout:  <dir>/step_<N>/
           manifest.json       tree structure + shapes/dtypes + metadata
           arrays.npz          flattened leaves (host numpy)
         <dir>/LATEST          atomic pointer (written last)

Fault-tolerance properties (exercised in tests/test_checkpoint.py):
  * step-atomic: LATEST flips only after the full step directory is
    fsync'd into place — a crash mid-save leaves the previous checkpoint
    intact;
  * elastic restore: arrays are restored host-side and re-placed with
    jax.device_put against the *current* mesh shardings, so a job can
    restart on a different topology (the multi-pod dry-run meshes restore
    from single-pod checkpoints);
  * data-cursor: the manifest carries (data_seed, next_batch_index) so the
    deterministic pipeline resumes bit-identically;
  * async: `save(..., blocking=False)` snapshots to host then writes on a
    worker thread, keeping the step loop running.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SEPARATOR = "/"


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEPARATOR.join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V":      # bfloat16 etc: npz-unsupported
            arr = np.asarray(jax.device_get(
                jax.numpy.asarray(leaf, jax.numpy.float32)))
        out[key] = arr
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Pytree,
         metadata: Optional[Dict] = None, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Snapshot `tree` to host and write <ckpt_dir>/step_<step> atomically."""
    arrays, _ = _flatten_with_paths(tree)
    meta = dict(metadata or {})
    meta["step"] = step
    meta["keys"] = sorted(arrays)

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, like: Pytree, step: Optional[int] = None,
            shardings: Optional[Pytree] = None
            ) -> Tuple[Pytree, Dict]:
    """Restore into the structure of `like`.  With `shardings` (a pytree of
    NamedSharding matching `like`) the arrays are placed directly onto the
    current mesh — this is the elastic-rescale path."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint in {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    out = []
    for (path, leaf), shd in zip(flat, shard_leaves):
        key = _SEPARATOR.join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        arr = jax.numpy.asarray(arr).astype(leaf.dtype)   # bf16 cast-back
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), meta
