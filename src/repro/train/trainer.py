"""Training loop: grad accumulation, checkpoint/restart, preemption,
straggler policy.

Large-scale runnability features (DESIGN.md SS5):
  * microbatch gradient accumulation via lax.scan (HBM-bounded global
    batches);
  * step-atomic async checkpoints + deterministic data cursor -> exact
    resume after a node failure (tests/test_trainer.py proves the loss
    trajectory is bit-identical across a kill/restart);
  * elastic rescale: restore() re-places host arrays against the current
    mesh, so the same checkpoint resumes on 1 or 512 devices;
  * preemption hook: a SIGTERM/flag-file check per step triggers a final
    checkpoint before exit (standard TPU-pod maintenance protocol);
  * straggler mitigation: steps are synchronous (pjit collectives), so the
    policy is detect-and-replace — per-step wall-time is logged and a
    step exceeding `straggler_factor` x the trailing median raises a
    STRAGGLER event the launcher acts on (documented; simulated in tests
    by the event hook).  At 1000+ nodes this pairs with the checkpoint
    cadence to bound lost work.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import DecoderLM, init_params
from repro.models.common import spec_structs

from . import checkpoint as ckpt_lib
from .adamw import AdamW, AdamWState


@dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # grad-accumulation factor
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    preempt_flag: Optional[str] = None   # path; existence => preemption
    straggler_factor: float = 3.0
    async_checkpoint: bool = True


def make_train_step(model: DecoderLM, opt: AdamW,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, state, loss).

    With microbatches > 1, `batch` has a leading accumulation dim and
    gradients are averaged via lax.scan before a single optimizer update
    (the collective-friendly schedule: one all-reduce per step)."""

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def acc_body(carry, mb):
                loss_i, g_i = jax.value_and_grad(loss_fn)(params, mb)
                gsum, lsum = carry
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g_i)
                return (gsum, lsum + loss_i), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, 0.0), batch)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


@dataclass
class TrainEvent:
    kind: str                      # STEP | CKPT | PREEMPT | STRAGGLER
    step: int
    payload: Dict[str, Any] = field(default_factory=dict)


class Trainer:
    def __init__(self, model: DecoderLM, opt: AdamW, data: SyntheticLM,
                 tc: TrainConfig, shard: int = 0, n_shards: int = 1,
                 event_hook: Optional[Callable[[TrainEvent], None]] = None):
        self.model = model
        self.opt = opt
        self.data = data
        self.tc = tc
        self.shard = shard
        self.n_shards = n_shards
        self.events: List[TrainEvent] = []
        self.event_hook = event_hook
        self._step_times: List[float] = []
        self.train_step = jax.jit(make_train_step(model, opt,
                                                  tc.microbatches))

    # ------------------------------------------------------------------
    def _emit(self, ev: TrainEvent):
        self.events.append(ev)
        if self.event_hook:
            self.event_hook(ev)

    def _preempted(self) -> bool:
        return bool(self.tc.preempt_flag
                    and os.path.exists(self.tc.preempt_flag))

    def _check_straggler(self, dt: float, step: int):
        self._step_times.append(dt)
        hist = self._step_times[-20:]
        if len(hist) >= 5:
            med = float(np.median(hist[:-1]))
            if dt > self.tc.straggler_factor * med:
                self._emit(TrainEvent("STRAGGLER", step,
                                      {"dt": dt, "median": med}))

    def _batch_at(self, index: int):
        if self.tc.microbatches == 1:
            b = self.data.batch(index, self.shard, self.n_shards)
            return {k: jnp.asarray(v) for k, v in b.items()}
        mbs = [self.data.batch(index * self.tc.microbatches + j,
                               self.shard, self.n_shards)
               for j in range(self.tc.microbatches)]
        return {k: jnp.stack([jnp.asarray(m[k]) for m in mbs])
                for k in mbs[0]}

    # ------------------------------------------------------------------
    def run(self, params=None, opt_state=None, start_step: int = 0,
            resume: bool = False) -> Dict[str, Any]:
        tc = self.tc
        if resume and tc.ckpt_dir and ckpt_lib.latest_step(tc.ckpt_dir) is not None:
            p0 = init_params(self.model.param_specs(),
                             jax.random.PRNGKey(0))
            like = {"params": p0, "opt": tuple(self.opt.init(p0))}
            tree, meta = ckpt_lib.restore(tc.ckpt_dir, like)
            params, opt_state = tree["params"], AdamWState(*tree["opt"])
            start_step = int(meta["step"])
        if params is None:
            params = init_params(self.model.param_specs(),
                                 jax.random.PRNGKey(0))
        if opt_state is None:
            opt_state = self.opt.init(params)

        losses: List[float] = []
        pending = None
        step = start_step
        while step < tc.steps:
            t0 = time.monotonic()
            batch = self._batch_at(step)
            params, opt_state, loss = self.train_step(params, opt_state,
                                                      batch)
            loss = float(loss)
            losses.append(loss)
            dt = time.monotonic() - t0
            self._check_straggler(dt, step)
            step += 1

            if step % tc.log_every == 0 or step == tc.steps:
                self._emit(TrainEvent("STEP", step,
                                      {"loss": loss, "dt": dt}))
            preempt = self._preempted()
            if tc.ckpt_dir and (step % tc.ckpt_every == 0
                                or step == tc.steps or preempt):
                tree = {"params": params, "opt": tuple(opt_state)}
                pending = ckpt_lib.save(
                    tc.ckpt_dir, step, tree,
                    metadata={"data_seed": self.data.cfg.seed,
                              "next_batch_index": step},
                    blocking=not tc.async_checkpoint)
                self._emit(TrainEvent("CKPT", step, {}))
            if preempt:
                self._emit(TrainEvent("PREEMPT", step, {}))
                break
        if pending is not None:
            pending.join()
        return {"params": params, "opt_state": opt_state, "step": step,
                "losses": losses}
