"""Training substrate: AdamW, trainer (fault-tolerant), checkpointing."""
from .adamw import AdamW, AdamWState, cosine_schedule, global_norm
from .trainer import Trainer, TrainConfig, TrainEvent, make_train_step
from . import checkpoint

__all__ = ["AdamW", "AdamWState", "cosine_schedule", "global_norm",
           "Trainer", "TrainConfig", "TrainEvent", "make_train_step",
           "checkpoint"]
