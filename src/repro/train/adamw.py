"""AdamW + learning-rate schedules, dependency-free (no optax in the
container).  Optimizer state is a pytree shaped like the params, so it
inherits the FSDPxTP shardings — Adam moments shard 256-way and the 27B /
235B configs fit the 16 GB/chip budget (see EXPERIMENTS.md SSDry-run)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jax.Array       # ()
    mu: Pytree            # first moment  (fp32)
    nu: Pytree            # second moment (fp32)


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Pytree) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree_util.tree_map(jnp.copy, zeros))

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: Pytree, state: AdamWState, params: Pytree
               ) -> Tuple[Pytree, AdamWState]:
        step = state.step + 1

        if self.clip_norm:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) * scale, grads)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state.nu, grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1.0 + jnp.cos(math.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
