"""Post-training quantization of a parameter pytree.

Converts the DRAM-traffic-dominant 2D matmul weights (attention q/k/v/o,
FFN gate/up/down, MoE experts, embedding/LM head) to packed QTensors at
INT4 or INT8 — the serve-path image of EdgeCIM's precision axis.  Norm
scales, biases, gates and other small/1D tensors stay in bf16 (they are
latency-irrelevant: <0.5% of decode bytes, matching the paper's treatment
of auxiliary operators on dedicated units).
"""
from __future__ import annotations

import warnings
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .qarray import QTensor, quantize

# parameter names eligible for quantization (leaf key in the pytree path)
QUANT_KEYS = {
    "wq", "wk", "wv", "wo", "w_dkv", "w_uk", "w_uv",          # attention
    "w_gate", "w_up", "w_down",                               # dense ffn
    "we_gate", "we_up", "we_down", "ws_gate", "ws_up", "ws_down",  # moe
    "embed", "head",                                          # vocab
    "in_proj", "out_proj", "up_proj", "down_proj", "w_o",     # ssm blocks
    "ffn_up", "ffn_down",                                     # slstm ffn
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _pick_group(K: int, group: int, shard_hint: int) -> int:
    """Largest group <= `group` dividing K, preferring group counts
    (K/group) divisible by the tensor-parallel mesh width: misaligned
    group counts force GSPMD to re-gather packed weights around the
    dequant reshape (SSPerf iteration c3, ~400MB/step on qwen2.5-3b).

    Returns 0 when no group >= 8 divides K (e.g. K prime or < 8); the
    caller must skip quantization for that leaf — 0 is a sentinel, not a
    usable group size."""
    best = 0
    for g in range(min(group, K), 7, -1):
        if K % g:
            continue
        if (K // g) % shard_hint == 0:
            return g
        best = best or g
    return best


def _skip_leaf(name: str, K: int) -> None:
    warnings.warn(
        f"ptq: no valid group size for leaf '{name}' (K={K}); "
        "leaving it unquantized", stacklevel=3)


def _quantize_leaf(name: str, x: Any, bits: int, group: int,
                   shard_hint: int = 16) -> Any:
    if not isinstance(x, jax.Array) or name not in QUANT_KEYS:
        return x
    if x.ndim < 2 or not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    # contraction axis: axis 0 for 2D (K, N); axis 1 for batched (E/L, K, N).
    # The embedding table groups along d (axis=1) so row lookups can gather
    # packed rows directly (qarray.dequant_rows).
    axis = 1 if name == "embed" else x.ndim - 2
    K = x.shape[axis]
    g = _pick_group(K, group, shard_hint)
    if not g or K % g != 0 or (bits == 4 and K % 2 != 0):
        # _pick_group returns the 0 sentinel when nothing >= 8 divides K;
        # quantize() would assert/divide by zero on it
        _skip_leaf(name, K)
        return x
    return quantize(x, bits=bits, group=g, axis=axis)


def quantize_params(params: Any, bits: int = 4, group: int = 128,
                    shard_hint: int = 16) -> Any:
    """Walk the pytree; replace eligible weights with QTensors."""
    def fn(path, x):
        return _quantize_leaf(_leaf_name(path), x, bits, group, shard_hint)
    return jax.tree_util.tree_map_with_path(fn, params)


def quantize_structs(spec_tree: Any, bits: int = 4, group: int = 128,
                     shard_hint: int = 16) -> Any:
    """ParamSpec pytree -> pytree of ShapeDtypeStructs where eligible
    weights become QTensor(structs) — the allocation-free image of
    quantize_params used by the multi-pod dry-run (a 235B model lowers
    quantized without materializing a byte)."""
    import jax as _jax
    from repro.models.common import ParamSpec, is_spec

    def fn(path, s: ParamSpec):
        name = _leaf_name(path)
        shape, dtype = tuple(s.shape), s.dtype
        if (name not in QUANT_KEYS or len(shape) < 2
                or not jnp.issubdtype(dtype, jnp.floating)):
            return s.struct()
        axis = 1 if name == "embed" else len(shape) - 2
        K = shape[axis]
        g = _pick_group(K, group, shard_hint)
        if not g or K % g != 0 or (bits == 4 and K % 2 != 0):
            _skip_leaf(name, K)
            return s.struct()
        dshape = list(shape)
        if bits == 4:
            dshape[axis] //= 2
        sshape = list(shape)
        sshape[axis] = K // g
        return QTensor(
            data=_jax.ShapeDtypeStruct(tuple(dshape),
                                       jnp.uint8 if bits == 4 else jnp.int8),
            scales=_jax.ShapeDtypeStruct(tuple(sshape), jnp.float16),
            bits=bits, group=g, axis=axis - len(shape),
            orig_shape=shape)

    return jax.tree_util.tree_map_with_path(
        fn, spec_tree, is_leaf=lambda x: hasattr(x, "axes")
        and hasattr(x, "materialize"))


def quantized_fraction(qparams: Any) -> float:
    """Fraction of parameter *bytes* now stored quantized."""
    qbytes = 0
    tbytes = 0
    for leaf in jax.tree_util.tree_leaves(
            qparams, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            n = int(np.prod(leaf.orig_shape))
            qbytes += n
            tbytes += n
        elif isinstance(leaf, jax.Array):
            tbytes += int(np.prod(leaf.shape))
    return qbytes / max(tbytes, 1)
