"""Packed INT4/INT8 weight tensors with per-group scales.

The TPU image of EdgeCIM's precision-reconfigurable DCIM storage: weights
live in DRAM/HBM packed at 4 or 8 bits with one scale per
(group_size x column) block; decode streams 1/4 (INT4) or 1/2 (INT8) of
the bf16 bytes — the same lever that gives the paper its ~2x INT4-over-
INT8 throughput (validated in EXPERIMENTS.md).

QTensor is a pytree node: it flows through jit/pjit/scan (packing is IN
PLACE along the contraction axis, so stacked-layer leading dims survive
for lax.scan), shards by the same logical axes as the dense weight it
replaces, and is consumed either by the pure-jnp dequant path
(kernels/ref.py — the lowering path on the CPU backend) or by the Pallas
`cim_gemv` kernel on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

INT4_GROUP = 128

# Trace-time counters: python-side increments inside jitted functions run
# when the function is TRACED, not per step — so after tracing a decode
# step, `full_dequant == 0` proves the compiled graph contains no
# whole-weight float materialization (the serve-path residency guarantee
# asserted by `api_bench --precision int4`).  `fused_dequant` counts
# group-scale applications that never build the full float weight
# (fused refs, cim_gemv/swiglu_qgemv kernels, row gathers).
_COUNTERS = {"full_dequant": 0, "fused_dequant": 0}


def count_dequant(kind: str = "full_dequant") -> None:
    _COUNTERS[kind] += 1


def dequant_counters() -> dict:
    return dict(_COUNTERS)


def reset_dequant_counters() -> None:
    for k in _COUNTERS:
        _COUNTERS[k] = 0


@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """Quantized weight; `axis` is the contraction/grouping axis.  INT4
    packs two consecutive `axis` entries per uint8 byte, in place:
    data.shape == orig_shape except axis dim halved (bits=4)."""
    data: jax.Array          # int8 (bits=8) or uint8 packed pairs (bits=4)
    scales: jax.Array        # orig_shape with axis dim = K/group, f16
    bits: int
    group: int
    axis: int                # NEGATIVE (from the end): slice-invariant under
                             # lax.scan slicing of leading stacked-layer dims
    orig_shape: Tuple[int, ...]

    def tree_flatten(self):
        return (self.data, self.scales), (self.bits, self.group, self.axis,
                                          self.orig_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scales = children
        bits, group, axis, orig_shape = aux
        return cls(data, scales, bits, group, axis, orig_shape)

    @property
    def shape(self):
        return self.orig_shape

    @property
    def ndim(self):
        return len(self.orig_shape)

    def nbytes_packed(self) -> int:
        import numpy as np
        return int(np.prod(self.data.shape)) + 2 * int(
            np.prod(self.scales.shape))

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return dequantize(self, dtype)


def quantize(w: jax.Array, bits: int = 4, group: int = INT4_GROUP,
             axis: int = 0) -> QTensor:
    """Symmetric per-(group, col) quantization along `axis` (in place)."""
    assert bits in (4, 8)
    if axis >= 0:
        axis = axis - w.ndim                 # store relative to the end
    orig_shape = tuple(w.shape)
    wf = jnp.moveaxis(w.astype(jnp.float32), axis, 0)
    K = wf.shape[0]
    rest = wf.shape[1:]
    g = min(group, K)
    assert K % g == 0, (K, g)
    wg = wf.reshape(K // g, g, *rest)
    qmax = 7.0 if bits == 4 else 127.0
    absmax = jnp.max(jnp.abs(wg), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    q = jnp.clip(jnp.round(wg / scale), -qmax - 1, qmax)
    q = q.reshape(K, *rest).astype(jnp.int8)
    # f16 scales: bf16's 8-bit mantissa costs up to 0.5*scale of
    # extra INT8 error; f16 (10-bit) keeps it <6% (same 16-bit storage)
    scales = jnp.moveaxis(scale[:, 0].astype(jnp.float16), 0, axis)
    if bits == 4:
        assert K % 2 == 0
        lo = (q[0::2].astype(jnp.int32) + 8)
        hi = (q[1::2].astype(jnp.int32) + 8)
        data = jnp.moveaxis((lo | (hi << 4)).astype(jnp.uint8), 0, axis)
    else:
        data = jnp.moveaxis(q, 0, axis)
    return QTensor(data=data, scales=scales, bits=bits, group=g, axis=axis,
                   orig_shape=orig_shape)


def unpack_int4(packed: jax.Array, axis: int = 0) -> jax.Array:
    """(..., K/2, ...) uint8 -> (..., K, ...) int8 in [-8, 7] along axis."""
    p = jnp.moveaxis(packed, axis, 0)
    lo = (p & 0xF).astype(jnp.int8) - 8
    hi = (p >> 4).astype(jnp.int8) - 8
    out = jnp.stack([lo, hi], axis=1).reshape(2 * p.shape[0], *p.shape[1:])
    return jnp.moveaxis(out, 0, axis)


def dequantize(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    count_dequant("full_dequant")
    q = unpack_int4(qt.data, qt.axis) if qt.bits == 4 else qt.data
    qm = jnp.moveaxis(q, qt.axis, 0)
    K = qm.shape[0]
    g = qt.group
    rest = qm.shape[1:]
    sm = jnp.moveaxis(qt.scales, qt.axis, 0)
    qg = qm.reshape(K // g, g, *rest).astype(jnp.float32)
    w = (qg * sm[:, None].astype(jnp.float32)).reshape(K, *rest)
    return jnp.moveaxis(w, 0, qt.axis).astype(dtype)


def maybe_dequantize(w: Any, dtype=jnp.bfloat16) -> jax.Array:
    return dequantize(w, dtype) if isinstance(w, QTensor) else w


def dequant_rows(qt: QTensor, ids: jax.Array, dtype=jnp.bfloat16
                 ) -> jax.Array:
    """Gather + dequantize rows of an axis=1-quantized (vocab, d) table.

    The embedding-lookup path: only the gathered rows are unpacked, so a
    quantized tied embedding costs `len(ids) * d/2` bytes, not the full
    table.  ids: (...,) int32 -> (..., d)."""
    assert qt.axis == -1 and len(qt.orig_shape) == 2
    count_dequant("fused_dequant")
    d = qt.orig_shape[1]
    data = qt.data[ids]                              # (..., d/2 or d)
    scales = qt.scales[ids]                          # (..., d/group)
    if qt.bits == 4:
        lo = (data & 0xF).astype(jnp.int8) - 8
        hi = (data >> 4).astype(jnp.int8) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(*data.shape[:-1], d)
    else:
        q = data
    qg = q.reshape(*q.shape[:-1], d // qt.group, qt.group).astype(jnp.float32)
    w = qg * scales[..., None].astype(jnp.float32)
    return w.reshape(*q.shape[:-1], d).astype(dtype)
