"""INT4/INT8 weight quantization substrate (EdgeCIM precision axis)."""
from .qarray import (QTensor, quantize, dequantize, maybe_dequantize,
                     unpack_int4, INT4_GROUP)
from .ptq import quantize_params, quantize_structs, quantized_fraction

__all__ = ["QTensor", "quantize", "dequantize", "maybe_dequantize",
           "unpack_int4", "INT4_GROUP", "quantize_params",
           "quantize_structs", "quantized_fraction"]
