"""Serving substrate: paged-KV continuous-batching runtime (v2).

allocator -> scheduler -> engine -> telemetry; see README.md in this
package.  `ServeEngine`/`Request` remain as the seed-API shim.
"""
from .config import ServeConfig
from .engine import PagedServeEngine, Request, ServeEngine
from .paged_cache import BlockAllocator, OutOfPagesError, PagedKVCache
from .prefix import PrefixIndex
from .sampling import SamplingParams, sample_tokens
from .scheduler import Scheduler, ServeRequest
from .state import StateArena
from .telemetry import Telemetry

__all__ = ["PagedServeEngine", "PrefixIndex", "Request", "ServeConfig",
           "ServeEngine",
           "BlockAllocator", "OutOfPagesError", "PagedKVCache",
           "SamplingParams", "sample_tokens", "Scheduler", "ServeRequest",
           "StateArena", "Telemetry"]
