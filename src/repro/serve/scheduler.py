"""Request scheduler: admission control, priorities, deadlines, chunked
prefill accounting.

Queue discipline: a heap ordered by (priority, absolute deadline,
arrival).  Admission is gated on BOTH a batch-lane budget and the paged
cache's free-page count — a request enters the running batch only when
its whole prompt fits in free pages (plus one growth page), so decode
never deadlocks on a half-prefilled request.  Requests whose deadline
passed while queued are rejected, not run: at the edge a late answer is
a wasted answer (EdgeCIM's latency-bound regime).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

import numpy as np

from .paged_cache import OutOfPagesError
from .sampling import SamplingParams


@dataclass
class ServeRequest:
    prompt: np.ndarray                       # (prompt_len,) int32
    max_new_tokens: int = 32
    rid: int = 0                             # caller's label (not unique)
    priority: int = 0                        # lower value = more urgent
    deadline_s: Optional[float] = None       # relative to enqueue
    sampling: SamplingParams = field(default_factory=SamplingParams)
    spec: bool = True                        # opt out of speculative decode
    #   (only meaningful on an engine built with a SpecConfig; such an
    #   engine still serves spec=False lanes, one token per step, in the
    #   same shape-stable verify call with an empty draft window)
    on_token: Optional[Callable[[int, int], None]] = None  # (rid, token)
    logprobs: bool = False                   # record per-token (logprob,
    #   entropy) under the processed sampling distribution into
    #   `out_logprobs` (host-side O(vocab) per token; free when off)
    # parallel sampling: a request carrying `fork_from` (a sibling
    # ServeRequest over the SAME prompt, submitted first) adopts the
    # parent's prompt KV pages via `PagedKVCache.fork` at admission and
    # prefills only the final prompt token — n samples off one prompt
    # share its pages copy-on-write.  If the parent is gone before the
    # child admits (finished, cancelled, rejected) the child falls back
    # to a plain admission (possibly a prefix-cache hit).
    fork_from: Optional["ServeRequest"] = None

    # lifecycle (engine-owned)
    out_tokens: List[int] = field(default_factory=list)
    out_logprobs: List = field(default_factory=list)  # [(logprob, entropy)]
    #   parallel to out_tokens, filled only when `logprobs` is set
    done: bool = False
    rejected: bool = False                   # never ran: deadline/too big
    reject_reason: str = ""                  # expired | empty | too-big
    truncated: bool = False                  # evicted mid-generation
    cancelled: bool = False                  # aborted by the caller
    trace_id: int = -1                       # process-unique tracing id
    #   (gateway-assigned via Tracer.next_request_id; -1 = untraced
    #   caller).  Unlike rid it never collides, so one value correlates
    #   gateway lifecycle, router dispatch, and engine span events.
    prefill_done: int = 0                    # prompt tokens consumed
    prefix_cached: int = 0                   # prompt tokens adopted from
    t_enqueue: float = 0.0                   #   the prefix cache at admit
    forked_tokens: int = 0                   # prompt tokens adopted by fork
    prompt_folded: int = 0                   # out_tokens already folded
    #   into prompt by preemption rebuilds (out_tokens[:prompt_folded]
    #   appear in prompt; concatenating past this cursor, never the
    #   whole list, is what keeps a twice-preempted prompt and the
    #   suffix-cache commit free of duplicated token runs)
    eid: int = -1                            # engine-assigned unique id
    # preempted recurrent state (StateArena host snapshot): restored on
    # re-admission instead of re-prefilling prompt + generated tokens
    saved_state: Any = None
    saved_length: int = 0
    saved_prefill_done: int = 0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def prefill_remaining(self) -> int:
        return self.prompt_len - self.prefill_done

    @property
    def tokens_resident(self) -> int:
        """Tokens the lane must hold at admission: the prompt, or — for
        a preempted request resuming from a saved StateArena snapshot —
        everything it had already consumed (admission's page budget must
        cover the restored position, not just the prompt)."""
        return max(self.prompt_len, self.saved_length)


class Scheduler:
    def __init__(self, max_batch: int, prefill_chunk: int = 16):
        assert max_batch > 0 and prefill_chunk > 0
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        self._heap: List = []
        self._order = itertools.count()
        self.tracer = None      # set by the engine (obs.trace.Tracer)

    # -- queue ----------------------------------------------------------
    def submit(self, req: ServeRequest, now: float,
               resubmit: bool = False) -> None:
        """resubmit=True (preemption) keeps the ORIGINAL enqueue time, so
        a deadline is measured from first arrival, not from eviction."""
        if not resubmit:
            req.t_enqueue = now
        abs_deadline = (req.t_enqueue + req.deadline_s
                        if req.deadline_s is not None else float("inf"))
        heapq.heappush(self._heap, (req.priority, abs_deadline,
                                    next(self._order), req))

    @property
    def n_queued(self) -> int:
        return len(self._heap)

    def drain_queue(self) -> List[ServeRequest]:
        """Remove and return every queued request that has NOT started,
        in heap-priority order — the fleet router's drain path re-homes
        them onto healthy replicas.  Requests already in lanes are
        untouched (drain lets in-flight work finish where it runs), and
        a preempted request stays queued here too: its progress —
        folded prompt, StateArena snapshot, telemetry trace — belongs
        to this engine and will resume on it."""
        out: List[ServeRequest] = []
        keep: List = []
        while self._heap:
            item = heapq.heappop(self._heap)
            req = item[3]
            if req.cancelled:
                continue
            if (req.out_tokens or req.prefill_done
                    or req.saved_state is not None):
                keep.append(item)
            else:
                out.append(req)
        for item in keep:
            heapq.heappush(self._heap, item)
        return out

    def cancel(self, eid: int) -> Optional[ServeRequest]:
        """Remove a queued request by engine id; returns it (marked
        cancelled) or None when it is not queued.  The heap is small
        (bounded by admission backpressure), so an eager O(n) sweep
        beats carrying tombstones through every admit pass."""
        for i, (_, _, _, req) in enumerate(self._heap):
            if req.eid == eid:
                req.cancelled = True
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                heapq.heapify(self._heap)
                return req
        return None

    # -- admission ------------------------------------------------------
    def admit(self, now: float, n_running: int, cache,
              on_reject=None) -> List[ServeRequest]:
        """Pop admissible requests: respects the lane budget and the
        allocator (fresh prompt pages + 1 growth page must be free or
        reclaimable from the prefix cache).  Prompt prefixes resident in
        the prefix index are adopted by refcount, so chunked prefill
        starts at the first unmatched token.  Expired requests are
        marked rejected and dropped.  Returns newly admitted requests
        with their pages already allocated."""
        admitted: List[ServeRequest] = []
        deferred: List = []
        max_tokens = cache.max_pages * cache.page_size
        while self._heap and n_running + len(admitted) < self.max_batch:
            prio, abs_dl, order, req = heapq.heappop(self._heap)
            if req.cancelled:       # cancelled while queued (belt and
                continue            # braces next to the eager sweep)
            need = cache.pages_needed(req.tokens_resident) + 1
            if (now > abs_dl or req.prompt_len == 0
                    or req.tokens_resident >= max_tokens
                    or need > cache.allocator.n_pages):
                # expired in queue; empty prompt; prompt can never fit
                # max_seq; or needs more pages than the pool HAS (not
                # merely has free) — deferring any of these would spin
                # forever.  A preempted request that already generated
                # output is TRUNCATED (partial result stands); one that
                # never ran is REJECTED.
                req.reject_reason = ("expired" if now > abs_dl
                                     else "empty" if req.prompt_len == 0
                                     else "too-big")
                if req.out_tokens:
                    req.truncated = True
                else:
                    req.rejected = True
                req.done = True
                if self.tracer is not None and self.tracer.enabled:
                    self.tracer.instant(
                        "queue_reject", cat="sched", eid=req.eid,
                        rid=req.trace_id, reason=req.reject_reason,
                        truncated=req.truncated)
                if on_reject is not None:   # let the engine close the
                    on_reject(req)          # telemetry trace
                continue
            parent = req.fork_from
            if parent is not None and (parent.done or parent.cancelled):
                parent = req.fork_from = None   # parent gone: the child
                #   admits on its own (prefix-cache hit if the parent's
                #   prompt pages were committed before release)
            if parent is not None:
                pseq = cache.seqs.get(parent.eid)
                if pseq is None or parent.prefill_remaining > 0:
                    # parent queued / mid-prefill / preempted: wait
                    # WITHOUT head-of-line blocking — a preempted parent
                    # may sit BEHIND this child in the very same heap,
                    # and blocking here would deadlock its re-admission
                    deferred.append((prio, abs_dl, order, req))
                    continue
                # share every full prompt page plus the partial tail;
                # the final prompt token is always re-prefilled so this
                # lane samples its OWN first token from its own logits
                # (COW copies the tail page on that write)
                prefix_len = min(max(req.prompt_len - 1, 0), pseq.length)
                try:
                    cache.fork(req.eid, parent.eid, prefix_len)
                except OutOfPagesError:
                    deferred.append((prio, abs_dl, order, req))
                    break
                req.prefill_done = prefix_len
                req.forked_tokens = prefix_len
                admitted.append(req)
                continue
            match = cache.probe_admit(req.tokens_resident, req.prompt)
            if match is None:
                # keep it queued; lower-priority requests behind it may
                # still fit, but skipping ahead would starve this one —
                # stop admitting (head-of-line, by design)
                deferred.append((prio, abs_dl, order, req))
                break
            try:
                seq = cache.admit(req.eid, req.tokens_resident, match=match)
            except OutOfPagesError:
                # the probe's evictable count was optimistic (e.g. a
                # refcount-1 interior trie node shielded by shared
                # children): wait, head-of-line, like any full pool
                deferred.append((prio, abs_dl, order, req))
                break
            req.prefill_done = req.prefix_cached = seq.length
            admitted.append(req)
        for item in deferred:
            heapq.heappush(self._heap, item)
        return admitted

    # -- chunked prefill ------------------------------------------------
    def prefill_quota(self, req: ServeRequest) -> int:
        """Prompt tokens this request may consume in the current step."""
        return min(self.prefill_chunk, req.prefill_remaining)
