"""Token sampling: greedy / temperature / top-k / top-p, PRNG-key threaded.

The seed engine's non-greedy branch computed softmax-then-argmax — i.e.
greedy with extra steps.  This module is the real thing, vectorized over
a batch whose lanes may carry different sampling params (the engine
serves mixed traffic in one decode step).

This runs once per generated token, so the dispatch avoids paying for
machinery a batch doesn't use: all-greedy batches take a pure argmax,
no-top-k batches skip truncation, top-k uses `lax.top_k` over the
batch max k instead of a full-vocab sort, and only batches with an
active nucleus (top_p < 1) lane pay for the full descending sort the
cumulative cutoff needs.

`processed_probs` exposes the same truncation rules as a host-side
numpy distribution — the speculative-decode acceptance test
(`repro.spec.verify`) must judge draft tokens against EXACTLY the
distribution this module samples from, or speculation would skew the
output distribution.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> full vocab
    top_p: float = 1.0           # 1 -> no nucleus truncation


@jax.jit
def _greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _mix_greedy(logits, temperature, sampled):
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled)


@jax.jit
def _sample_full(key, logits, temperature):
    lf = logits.astype(jnp.float32)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return _mix_greedy(lf, temperature, sampled)


def _topk_cutoff(scaled: jax.Array, top_k: jax.Array, kmax: int
                 ) -> jax.Array:
    """Per-lane kth-largest value; -inf (keep all) where top_k <= 0."""
    top_vals, _ = jax.lax.top_k(scaled, kmax)                # (b, kmax)
    k_eff = jnp.clip(top_k, 1, kmax).astype(jnp.int32)
    kth = jnp.take_along_axis(top_vals, (k_eff - 1)[:, None], axis=-1)
    return jnp.where((top_k > 0)[:, None], kth, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("kmax",))
def _sample_topk(key, logits, temperature, top_k, kmax: int):
    lf = logits.astype(jnp.float32)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # kth-largest per lane from the batch-max top-k (no full-vocab sort);
    # lanes with top_k <= 0 keep the whole vocab
    kth = _topk_cutoff(scaled, top_k, kmax)
    truncated = jnp.where(scaled >= kth, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, truncated, axis=-1).astype(
        jnp.int32)
    return _mix_greedy(lf, temperature, sampled)


@functools.partial(jax.jit, static_argnames=("kmax",))
def _sample_topk_topp(key, logits, temperature, top_k, top_p, kmax: int):
    """Nucleus path: full descending sort (the cumulative cutoff needs
    it), composed with the top-k cutoff.  Nucleus rule: keep the
    smallest prefix of the sorted distribution whose mass reaches
    top_p — a token survives iff the mass STRICTLY BEFORE it is still
    under top_p (so the argmax always survives)."""
    lf = logits.astype(jnp.float32)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    srt = jnp.sort(scaled, axis=-1)[:, ::-1]                 # descending
    probs = jax.nn.softmax(srt, axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs              # exclusive
    # top_p <= 0 floors to "argmax only" (before[0] == 0 always keeps
    # the head) rather than truncating the entire vocabulary
    keep = before < jnp.maximum(top_p, 1e-9)[:, None]
    p_cut = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
    p_cut = jnp.where((top_p < 1.0)[:, None], p_cut, -jnp.inf)
    cut = jnp.maximum(p_cut, _topk_cutoff(scaled, top_k, kmax)
                      if kmax > 0 else -jnp.inf)
    truncated = jnp.where(scaled >= cut, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, truncated, axis=-1).astype(
        jnp.int32)
    return _mix_greedy(lf, temperature, sampled)


def sample_tokens(key: jax.Array, logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array = None) -> jax.Array:
    """logits: (b, v); temperature, top_k, top_p: (b,) per-lane params.

    temperature <= 0 lanes decode greedily; top_k <= 0 means full vocab;
    top_p >= 1 disables nucleus truncation.  Returns (b,) int32 — one
    categorical draw per lane from the temperature-scaled, top-k- and
    top-p-truncated distribution.
    """
    temp_np = np.asarray(temperature)
    topk_np = np.asarray(top_k)
    if not np.any(temp_np > 0.0):
        return _greedy(logits)
    # clamp the batch-max k to the vocab (a k >= vocab lane keeps the
    # whole vocab through the kth-value cutoff) instead of zeroing it,
    # which would silently drop OTHER lanes' truncation
    kmax = min(int(topk_np.max(initial=0)), logits.shape[-1])
    if top_p is not None and np.any(
            (np.asarray(top_p) < 1.0) & (temp_np > 0.0)):
        return _sample_topk_topp(key, logits, temperature, top_k,
                                 jnp.asarray(top_p), kmax)
    if kmax <= 0:
        return _sample_full(key, logits, temperature)
    return _sample_topk(key, logits, temperature, top_k, kmax)


# ----------------------------------------------------------------------------
# host-side processed distribution (speculative-decode acceptance)
# ----------------------------------------------------------------------------
def processed_probs(logits: np.ndarray, temperature: float, top_k: int,
                    top_p: float) -> np.ndarray:
    """The (v,) probability vector `sample_tokens` draws one lane from.

    Mirrors the device path's truncation rules exactly (same kth-value
    top-k cutoff, same exclusive-cumsum nucleus rule, ties kept on both)
    so the speculative accept/reject test preserves the served
    distribution.  temperature <= 0 returns the greedy one-hot.
    """
    lf = np.asarray(logits, np.float64)
    if temperature <= 0.0:
        out = np.zeros_like(lf)
        out[int(np.argmax(lf))] = 1.0
        return out
    scaled = lf / max(temperature, 1e-6)
    cut = -np.inf
    if 0 < top_k < lf.shape[-1]:
        cut = np.sort(scaled)[::-1][top_k - 1]
    if top_p < 1.0:
        srt = np.sort(scaled)[::-1]
        e = np.exp(srt - srt[0])
        probs = e / e.sum()
        before = np.cumsum(probs) - probs
        cut = max(cut, srt[before < max(top_p, 1e-9)].min())
    scaled = np.where(scaled >= cut, scaled, -np.inf)
    e = np.exp(scaled - scaled.max())
    return e / e.sum()
