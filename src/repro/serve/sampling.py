"""Token sampling: greedy / temperature / top-k, PRNG-key threaded.

The seed engine's non-greedy branch computed softmax-then-argmax — i.e.
greedy with extra steps.  This module is the real thing, vectorized over
a batch whose lanes may carry different sampling params (the engine
serves mixed traffic in one decode step).

This runs once per generated token, so the dispatch avoids paying for
machinery a batch doesn't use: all-greedy batches take a pure argmax,
no-top-k batches skip truncation, and top-k uses `lax.top_k` over the
batch max k instead of a full-vocab sort.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 -> greedy
    top_k: int = 0               # 0 -> full vocab


@jax.jit
def _greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _mix_greedy(logits, temperature, sampled):
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy_tok, sampled)


@jax.jit
def _sample_full(key, logits, temperature):
    lf = logits.astype(jnp.float32)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return _mix_greedy(lf, temperature, sampled)


@functools.partial(jax.jit, static_argnames=("kmax",))
def _sample_topk(key, logits, temperature, top_k, kmax: int):
    lf = logits.astype(jnp.float32)
    scaled = lf / jnp.maximum(temperature, 1e-6)[:, None]
    # kth-largest per lane from the batch-max top-k (no full-vocab sort);
    # lanes with top_k <= 0 keep the whole vocab
    top_vals, _ = jax.lax.top_k(scaled, kmax)                # (b, kmax)
    k_eff = jnp.clip(top_k, 1, kmax).astype(jnp.int32)
    kth = jnp.take_along_axis(top_vals, (k_eff - 1)[:, None], axis=-1)
    kth = jnp.where((top_k > 0)[:, None], kth, -jnp.inf)
    truncated = jnp.where(scaled >= kth, scaled, -jnp.inf)
    sampled = jax.random.categorical(key, truncated, axis=-1).astype(
        jnp.int32)
    return _mix_greedy(lf, temperature, sampled)


def sample_tokens(key: jax.Array, logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array) -> jax.Array:
    """logits: (b, v); temperature, top_k: (b,) per-lane params.

    temperature <= 0 lanes decode greedily; top_k <= 0 means full vocab.
    Returns (b,) int32 — one categorical draw per sampling lane from the
    temperature-scaled, top-k-truncated distribution.
    """
    temp_np = np.asarray(temperature)
    topk_np = np.asarray(top_k)
    if not np.any(temp_np > 0.0):
        return _greedy(logits)
    kmax = int(topk_np.max(initial=0))
    if kmax <= 0 or kmax >= logits.shape[-1]:
        return _sample_full(key, logits, temperature)
    return _sample_topk(key, logits, temperature, top_k, kmax)
