"""ServeConfig — the single typed configuration object for the serving
stack.

One frozen dataclass flows launcher -> Gateway -> FleetRouter -> Replica
-> PagedServeEngine, replacing the kwarg-and-flag sprawl that had every
layer re-declaring (and silently defaulting) max_batch/page_size/... .
The old per-layer kwargs keep working through a deprecation shim in
`PagedServeEngine.__init__` that warns once per process.

`precision` is the serving precision of the EdgeCIM hot path:

  "fp"    float weights, float KV (the pre-PR-8 behavior)
  "int8"  packed INT8 weights (QTensor, per-group scales)
  "int4"  packed INT4 weights — the paper's headline operating point

`kv_dtype` picks the paged-KV pool storage independently:

  "auto"  int8 pools when precision is quantized, bf16 otherwise
  "bf16" | "f32"  float pools
  "int8"  per-token INT8 K/V with f16 scale pages beside the block table

The resolved config is reported verbatim under `/metrics` (key
"config") so an operator can read the precision a fleet is actually
serving at.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

PRECISIONS = ("fp", "int8", "int4")
KV_DTYPES = ("auto", "bf16", "f32", "int8")


@dataclass(frozen=True)
class ServeConfig:
    # precision of the hot path
    precision: str = "fp"            # "fp" | "int8" | "int4"
    kv_dtype: str = "auto"           # "auto" | "bf16" | "f32" | "int8"
    quant_group: int = 128           # group size for weight quantization

    # engine geometry
    max_batch: int = 8
    max_seq: int = 256
    page_size: int = 16
    n_pages: Optional[int] = None    # None -> engine sizes the pool
    prefill_chunk: int = 16
    eos_id: Optional[int] = None
    seed: int = 0
    prefix_cache: Optional[bool] = None   # None -> engine default (on)

    # fleet shape
    replicas: int = 1
    policy: str = "least-loaded"
    max_pending: int = 32

    # tensor parallelism: devices per engine (the ("model",) mesh width;
    # composes with `replicas` as replicas x tp).  tp must divide the
    # model's head/KV-group/FFN dims — the engine validates against the
    # actual architecture at build time.
    tp: int = 1

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got "
                f"{self.precision!r}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got "
                f"{self.kv_dtype!r}")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")

    # -- resolution ------------------------------------------------------
    def quantized(self) -> bool:
        return self.precision in ("int8", "int4")

    def weight_bits(self) -> int:
        """Bits per weight for quantize_params AND the energy model's
        w_bits (fp maps to 16: bf16 storage)."""
        return {"fp": 16, "int8": 8, "int4": 4}[self.precision]

    def resolved_kv_dtype(self):
        """The jnp dtype the paged KV pools are allocated at."""
        kv = self.kv_dtype
        if kv == "auto":
            kv = "int8" if self.quantized() else "bf16"
        return {"bf16": jnp.bfloat16, "f32": jnp.float32,
                "int8": jnp.int8}[kv]

    # -- reporting -------------------------------------------------------
    def as_dict(self) -> dict:
        """JSON-safe resolved view (what `/metrics` reports)."""
        d = dataclasses.asdict(self)
        d["kv_dtype_resolved"] = jnp.dtype(self.resolved_kv_dtype()).name
        d["weight_bits"] = self.weight_bits()
        return d
