"""Prefix cache: a token-id radix trie over committed, full KV pages.

Edge serving traffic is dominated by shared prompt prefixes (chat
templates, few-shot preambles, spec-decode drafters re-seeing the same
context).  Every prefill token skipped is DRAM bandwidth and TTFT saved
— the exact axes EdgeCIM optimizes.  This index remembers the pages of
completed prompt prefills so later requests with the same prefix adopt
them by refcount instead of recomputing.

Structure: one trie level per FULL page (page_size tokens); a node's
key is its page's token tuple, so a path from the root spells out an
exact token prefix.  KV rows depend on the whole causal prefix, which
is why matching must walk from the root — two pages with identical
tokens under different parents hold different KV and live in different
nodes.

Ownership: the trie is one more allocator owner (`PREFIX_OWNER`).
Inserting a page increfs it; a sequence matching it increfs it again
(so eviction can never pull a page out from under a running request —
only refcount-1 pages, held by nobody but the trie, are evictable).
Eviction is leaf-first LRU, driven by allocation pressure from
`PagedKVCache._reclaim`.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

PREFIX_OWNER = -1          # allocator owner id reserved for the trie

# fingerprint root: the path hash of the empty prefix.  Path hashes fold
# the parent's hash into every level, so two identical page keys under
# different parents (different causal prefixes, different KV) hash
# differently.  A hash collision can only misroute a fleet dispatch
# (the replica still re-prefills on the real trie miss) — never corrupt
# KV, because adoption itself always walks the exact token trie.
ROOT_HASH = 0


def combine_hash(parent_hash: int, key: Tuple[int, ...]) -> int:
    """Path hash of a child page under `parent_hash`."""
    return hash((parent_hash,) + key)


def prompt_page_hashes(prompt: np.ndarray, page_size: int) -> List[int]:
    """Path hashes of every full-page prefix of `prompt` the trie could
    hold (same `len(prompt) - 1` cap as `match_nodes`) — the router
    side of the fingerprint: count how many consecutive entries a
    replica's fingerprint contains and you have its resident-prefix
    depth for this prompt, without touching the replica's thread."""
    limit = (len(prompt) - 1) // page_size
    h, out = ROOT_HASH, []
    for i in range(limit):
        h = combine_hash(h, tuple(int(t) for t in
                                  prompt[i * page_size:(i + 1) * page_size]))
        out.append(h)
    return out


class _Node:
    __slots__ = ("key", "page", "children", "parent", "last_use", "hash")

    def __init__(self, key: Optional[Tuple[int, ...]], page: Optional[int],
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.last_use = 0
        self.hash = (ROOT_HASH if parent is None
                     else combine_hash(parent.hash, key))


class PrefixIndex:
    def __init__(self, allocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self.root = _Node(None, None, None)
        self._tick = itertools.count(1)
        # hit/skip rates live in serve Telemetry (counted once per
        # admission); the trie only tracks its own churn
        self.pages_inserted = 0
        self.pages_evicted = 0
        # fleet fingerprint: path hashes of every resident node,
        # maintained incrementally on insert/evict so exporting it is a
        # set copy, not a trie walk.  `version` bumps with every
        # membership change — a poller republishes only when it moved.
        self.version = 0
        self._hashes: Set[int] = set()

    def fingerprint(self) -> Tuple[int, frozenset]:
        """(version, resident path-hash set) — cheap to export per
        engine step; match against `prompt_page_hashes` output."""
        return self.version, frozenset(self._hashes)

    # -- size accounting ------------------------------------------------
    @property
    def n_pages(self) -> int:
        return sum(1 for _ in self._walk())

    def _walk(self, node: Optional[_Node] = None):
        node = node or self.root
        for child in node.children.values():
            yield child
            yield from self._walk(child)

    def n_evictable(self, exclude: Optional[Set[int]] = None,
                    limit: Optional[int] = None) -> int:
        """Pages only the trie holds (refcount 1) and not in `exclude` —
        what allocation pressure could reclaim right now.  `limit` stops
        the walk early once that many are found (admission probes only
        need to know 'at least n', not the exact count)."""
        exclude = exclude or set()
        count = 0
        for n in self._walk():
            if (self.allocator.refcount(n.page) == 1
                    and n.page not in exclude):
                count += 1
                if limit is not None and count >= limit:
                    break
        return count

    # -- lookup ---------------------------------------------------------
    def match_nodes(self, prompt: np.ndarray) -> List[_Node]:
        """Longest resident full-page prefix of `prompt` as trie nodes,
        capped at `len(prompt) - 1` tokens: at least the final prompt
        token is always recomputed so prefill emits the logits that
        sample the first output token.  Pure lookup — never touches LRU
        stamps (admission PROBES must not refresh recency: a request
        deferred every step would otherwise pin its prefix against
        eviction without ever running).  The caller stamps via `touch`
        when the match is actually adopted."""
        limit = (len(prompt) - 1) // self.page_size   # full pages usable
        node, nodes = self.root, []
        for i in range(limit):
            key = tuple(int(t) for t in
                        prompt[i * self.page_size:(i + 1) * self.page_size])
            child = node.children.get(key)
            if child is None:
                break
            nodes.append(child)
            node = child
        return nodes

    def touch(self, nodes: List[_Node]) -> None:
        """Refresh LRU recency of an adopted match path."""
        tick = next(self._tick)
        for node in nodes:
            node.last_use = tick

    def match(self, prompt: np.ndarray, touch: bool = True
              ) -> Tuple[int, List[int]]:
        """(tokens_matched, pages) convenience over `match_nodes`."""
        nodes = self.match_nodes(prompt)
        if touch and nodes:
            self.touch(nodes)
        return len(nodes) * self.page_size, [n.page for n in nodes]

    # -- commit ---------------------------------------------------------
    def insert(self, prompt: np.ndarray, pages: List[int]) -> int:
        """Commit the full-page prefix of a materialized prompt:
        `pages[i]` holds tokens `prompt[i*ps:(i+1)*ps]`.  New nodes
        incref their page under PREFIX_OWNER; a node that already exists
        keeps its original page (the duplicate stays solely with the
        sequence and dies on its release).  Returns pages adopted."""
        n_full = min(len(prompt) // self.page_size, len(pages))
        node, adopted = self.root, 0
        tick = next(self._tick)
        for i in range(n_full):
            key = tuple(int(t) for t in
                        prompt[i * self.page_size:(i + 1) * self.page_size])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, pages[i], node)
                self.allocator.share(PREFIX_OWNER, [pages[i]])
                node.children[key] = child
                self._hashes.add(child.hash)
                self.version += 1
                adopted += 1
            child.last_use = tick
            node = child
        self.pages_inserted += adopted
        return adopted

    # -- eviction -------------------------------------------------------
    def evict(self, n: int) -> int:
        """Free up to `n` pages, leaf-first in LRU order, skipping pages
        a live sequence still shares (refcount > 1).  Each outer pass
        collects ALL current evictable leaves and frees them
        oldest-first (one trie walk per generation of exposed parents,
        not per page).  Returns the number of pages actually freed."""
        freed = 0
        while freed < n:
            leaves = [node for node in self._walk()
                      if not node.children
                      and self.allocator.refcount(node.page) == 1]
            if not leaves:
                break
            for node in sorted(leaves, key=lambda x: x.last_use):
                if freed >= n:
                    break
                self.allocator.free_pages(PREFIX_OWNER, [node.page])
                del node.parent.children[node.key]
                self._hashes.discard(node.hash)
                self.version += 1
                self.pages_evicted += 1
                freed += 1
        return freed
