"""Paged-KV continuous-batching serve engine (repro.serve v2).

EdgeCIM's workload is autoregressive decode — batched GEMV over a
growing KV cache — and the memory that cache wastes is the edge
bottleneck.  v2 replaces the seed's fixed-slot engine + dense
(n_slots, max_seq) cache with:

  allocator  (paged_cache.BlockAllocator) — refcounted free-list over
                                            KV pages (shared via prefix
                                            cache / fork, copy-on-write)
  prefix     (prefix.PrefixIndex)         — radix trie over committed
                                            prompt pages; admission
                                            adopts matched prefixes so
                                            prefill skips them
  scheduler  (scheduler.Scheduler)        — admission control, priority,
                                            deadlines, chunked prefill
  engine     (this file)                  — dynamic decode batch against
                                            the paged pool, streaming
                                            callbacks, preemption
  telemetry  (telemetry.Telemetry)        — TTFT/TPOT/queue percentiles,
                                            KV occupancy

Every step runs at most two jitted graphs with shape-stable arguments:
one chunked BATCH PREFILL call (b = max_batch, s = prefill_chunk) and
one decode call — `DecoderLM.paged_step` (b = max_batch, s = 1), or,
when the engine is built with a `repro.spec.SpecConfig`, one
`paged_verify_step` (b = max_batch, s = k + 1) that verifies a drafted
window and emits a variable number of tokens per lane (speculative
decoding; see repro/spec/).  Per-lane positions make one sequence's
prefill unable to clobber another's cache rows (the seed
`_prefill_slot` bug).

The legacy slot engine survives only as `ServeEngine`, a compatibility
shim: dense/moe families route to the paged runtime; recurrent families
(xlstm/zamba — constant-size state, nothing to page) keep a slot loop
that only admits into an idle batch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DecoderLM
from repro.models.common import spec_structs

from .paged_cache import PagedKVCache
from .prefix import PrefixIndex
from .sampling import SamplingParams, sample_tokens
from .scheduler import Scheduler, ServeRequest
from .telemetry import Telemetry


class PagedServeEngine:
    def __init__(self, model: DecoderLM, params: Any, *,
                 max_batch: int = 8, max_seq: int = 256,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefill_chunk: int = 16, kv_dtype=jnp.bfloat16,
                 eos_id: Optional[int] = None, seed: int = 0,
                 spec: Optional[Any] = None, prefix_cache: bool = True,
                 clock=time.monotonic):
        assert model.cfg.embed_inputs, "engine serves token-input models"
        assert model.supports_paged(), (
            f"family {model.cfg.family!r} has no paged-KV path; use the "
            "ServeEngine shim")
        assert max_seq % page_size == 0, (max_seq, page_size)
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._clock = clock
        if n_pages is None:      # dense-equivalent worst case: never OOM
            n_pages = max_batch * (max_seq // page_size)
        self.cache = PagedKVCache(model, n_pages, page_size, max_seq,
                                  kv_dtype)
        # prefix sharing: committed prompt pages live in a radix trie and
        # are adopted by later requests with the same prefix (see
        # prefix.py); allocation pressure evicts trie-only pages LRU
        self.prefix: Optional[PrefixIndex] = None
        if prefix_cache:
            self.prefix = PrefixIndex(self.cache.allocator, page_size)
            self.cache.prefix_index = self.prefix
        self.scheduler = Scheduler(max_batch,
                                   prefill_chunk=min(prefill_chunk, max_seq))
        self.telemetry = Telemetry()
        self.lanes: List[Optional[ServeRequest]] = [None] * max_batch
        self._step_fn = jax.jit(model.paged_step, donate_argnums=(1,))
        self._key = jax.random.PRNGKey(seed)
        self._next_eid = 0
        if spec is not None:            # SpecConfig -> speculative decode
            from repro.spec import SpecDecoder
            self.spec: Optional[SpecDecoder] = SpecDecoder(
                model, spec, max_batch=max_batch, max_seq=max_seq,
                kv_dtype=kv_dtype)
        else:
            self.spec = None

    # ------------------------------------------------------------------
    @property
    def n_running(self) -> int:
        return sum(r is not None for r in self.lanes)

    @property
    def busy(self) -> bool:
        return self.n_running > 0 or self.scheduler.n_queued > 0

    def submit(self, req: ServeRequest) -> None:
        now = self._clock()
        req.eid = self._next_eid      # rid is the caller's label and may
        self._next_eid += 1           # collide; eid keys cache/telemetry
        self.telemetry.enqueue(req.eid, now)
        self.scheduler.submit(req, now)

    def run(self, requests: List[ServeRequest]) -> List[ServeRequest]:
        for r in requests:
            self.submit(r)
        while self.busy:
            self.step()
        return requests

    # ------------------------------------------------------------------
    def _tables(self) -> np.ndarray:
        tab = np.zeros((self.max_batch, self.cache.max_pages), np.int32)
        for i, req in enumerate(self.lanes):
            if req is not None:
                tab[i] = self.cache.table_for(req.eid)
        return tab

    def _lengths(self) -> np.ndarray:
        ln = np.zeros(self.max_batch, np.int32)
        for i, req in enumerate(self.lanes):
            if req is not None:
                ln[i] = self.cache.seqs[req.eid].length
        return ln

    def _sample_rows(self, rows: jax.Array) -> np.ndarray:
        """rows: (max_batch, vocab) -> (max_batch,) tokens, per-lane
        sampling params, PRNG key threaded through the engine."""
        temp = np.zeros(self.max_batch, np.float32)
        topk = np.zeros(self.max_batch, np.int32)
        topp = np.ones(self.max_batch, np.float32)
        for i, req in enumerate(self.lanes):
            if req is not None:
                temp[i] = req.sampling.temperature
                topk[i] = req.sampling.top_k
                topp[i] = req.sampling.top_p
        self._key, sub = jax.random.split(self._key)
        return np.asarray(sample_tokens(sub, rows, temp, topk, topp))

    def _emit(self, req: ServeRequest, token: int, now: float,
              decode: bool = True) -> None:
        req.out_tokens.append(token)
        self.telemetry.token(req.eid, now, decode=decode)
        if req.on_token is not None:
            req.on_token(req.rid, token)

    def _maybe_finish(self, lane: int, now: float) -> None:
        req = self.lanes[lane]
        seq = self.cache.seqs[req.eid]
        hit_eos = (self.eos_id is not None and req.out_tokens
                   and req.out_tokens[-1] == self.eos_id)
        if (len(req.out_tokens) >= req.max_new_tokens or hit_eos
                or seq.length >= self.max_seq):
            req.done = True
            self.telemetry.done(req.eid, now)
            self.cache.release(req.eid)
            self.lanes[lane] = None
            if self.spec is not None:
                self.spec.drafter.release(lane)

    def _preempt(self, lane: int) -> None:
        """Pool exhausted mid-decode: evict this lane, requeue it with
        (prompt + generated) as the new prompt — its KV is rebuilt by
        prefill when pages free up."""
        req = self.lanes[lane]
        self.cache.release(req.eid)
        self.lanes[lane] = None
        if self.spec is not None:
            self.spec.drafter.release(lane)
        req.prompt = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens, np.int32)])
        req.prefill_done = 0
        self.scheduler.submit(req, self._clock(), resubmit=True)

    # ------------------------------------------------------------------
    def step(self) -> None:
        now = self._clock()
        for req in self.scheduler.admit(now, self.n_running, self.cache):
            lane = self.lanes.index(None)
            self.lanes[lane] = req
            self.telemetry.admit(req.eid, now)
            if self.prefix is not None:
                self.telemetry.prefix(req.prefix_cached)

        prefill_s = self._prefill_phase()
        if self.spec is not None:
            decode_s, decode_lanes = self._decode_phase_spec()
        else:
            decode_s, decode_lanes = self._decode_phase()
        self.telemetry.step(self.cache.occupancy(), self.n_running,
                            decode_s=decode_s, prefill_s=prefill_s,
                            decode_lanes=decode_lanes)

    def _prefill_phase(self) -> float:
        """One chunked BATCH prefill call for every lane with prompt
        tokens left; lanes finishing their prompt sample their first
        output token from this call's logits."""
        pre = [i for i, r in enumerate(self.lanes)
               if r is not None and r.prefill_remaining > 0]
        if not pre:
            return 0.0
        s = self.scheduler.prefill_chunk
        tokens = np.zeros((self.max_batch, s), np.int32)
        n_new = np.zeros(self.max_batch, np.int32)
        finishing = False
        for i in list(pre):
            req = self.lanes[i]
            q = self.scheduler.prefill_quota(req)
            # prompt pages were allocated at admission, but a forked /
            # resubmitted lane may start mid-page on a shared page:
            # copy-on-write it before the chunk lands
            if not self.cache.prepare_write(req.eid, q):
                self._preempt(i)
                pre.remove(i)
                continue
            tokens[i, :q] = req.prompt[req.prefill_done:req.prefill_done + q]
            n_new[i] = q
            finishing |= q == req.prefill_remaining
        if not pre:
            return 0.0
        lengths = self._lengths()
        tables = self._tables()

        t0 = time.monotonic()
        logits, self.cache.pools = self._step_fn(
            self.params, self.cache.pools, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(tables), jnp.asarray(lengths), jnp.asarray(n_new))
        dt = time.monotonic() - t0

        if finishing:       # only sample when some lane ends its prompt
            last = jnp.take_along_axis(
                logits, jnp.asarray(np.maximum(n_new - 1, 0)
                                    )[:, None, None], axis=1)[:, 0, :]
            nxt = self._sample_rows(last)
        now = self._clock()
        for i in pre:
            req = self.lanes[i]
            q = int(n_new[i])
            req.prefill_done += q
            self.cache.seqs[req.eid].length += q
            self.telemetry.prefill_tokens += q
            if req.prefill_remaining == 0:
                if self.prefix is not None:
                    # prompt fully materialized: commit its full pages
                    # so later requests with the same prefix skip them
                    self.prefix.insert(np.asarray(req.prompt, np.int32),
                                       self.cache.seqs[req.eid].pages)
                self._emit(req, int(nxt[i]), now, decode=False)
                self._maybe_finish(i, now)
        return dt

    def _decode_ready(self) -> List[int]:
        """Lanes with their prompt fully cached and at least one emitted
        token (a lane that finished prefill this same step joins
        immediately: its first token is this call's input, written at
        position seqs[eid].length)."""
        return [i for i, r in enumerate(self.lanes)
                if r is not None and r.prefill_remaining == 0
                and r.out_tokens]

    def _decode_phase(self) -> tuple:
        """One token for every decode-ready lane.  Returns (graph
        seconds, lanes advanced)."""
        ready = []
        for i in self._decode_ready():
            req = self.lanes[i]
            # the token we feed is the last emitted one; this decode call
            # itself writes its KV row at position seqs[rid].length
            # (prepare_write also copy-on-writes a shared tail page)
            if not self.cache.prepare_write(req.eid, 1):
                self._preempt(i)
                continue
            ready.append(i)
        if not ready:
            return 0.0, 0

        tokens = np.zeros((self.max_batch, 1), np.int32)
        n_new = np.zeros(self.max_batch, np.int32)
        for i in ready:
            req = self.lanes[i]
            tokens[i, 0] = req.out_tokens[-1]
            n_new[i] = 1
        lengths = self._lengths()
        tables = self._tables()

        t0 = time.monotonic()
        logits, self.cache.pools = self._step_fn(
            self.params, self.cache.pools, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(tables), jnp.asarray(lengths), jnp.asarray(n_new))
        dt = time.monotonic() - t0

        nxt = self._sample_rows(logits[:, 0, :])
        now = self._clock()
        for i in ready:
            req = self.lanes[i]
            self.cache.seqs[req.eid].length += 1
            self._emit(req, int(nxt[i]), now)
            self._maybe_finish(i, now)
        return dt, len(ready)

    def _decode_phase_spec(self) -> tuple:
        """Speculative decode: draft up to k tokens per lane, verify the
        whole window in ONE `paged_verify_step` call (always
        (max_batch, k + 1) — shape-stable under jit), emit the accepted
        prefix plus the bonus token, roll rejected KV rows back.

        Lanes with `req.spec == False`, or whose drafter found nothing,
        ride the same call with an empty window — for them this IS a
        plain decode step, so greedy output is byte-identical to the
        non-speculative engine either way.
        """
        spec = self.spec
        k = spec.cfg.k
        dec = self._decode_ready()
        if not dec:
            return 0.0, 0

        histories: List[Optional[np.ndarray]] = [None] * self.max_batch
        smp: List[Optional[SamplingParams]] = [None] * self.max_batch
        for i in dec:
            req = self.lanes[i]
            if req.spec:
                histories[i] = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.out_tokens, np.int32)])
                smp[i] = req.sampling
        # drafting is part of the decode budget speculation spends —
        # timing it keeps tokens_per_s_decode (and spec_bench's speedup
        # column) honest about what a model drafter costs
        t0 = time.monotonic()
        prop = spec.drafter.propose(histories, k, smp)
        draft_s = time.monotonic() - t0

        tokens = np.zeros((self.max_batch, k + 1), np.int32)
        n_new = np.zeros(self.max_batch, np.int32)
        ready: List[tuple] = []                 # (lane, n_draft)
        for i in dec:
            req = self.lanes[i]
            nd = int(prop.n[i]) if histories[i] is not None else 0
            # the window writes 1 + nd KV rows and may emit 1 + nd
            # tokens; cap at the sequence budget AND the request's
            # remaining token budget (no point verifying tokens
            # emitted[:budget] would discard), then shrink until the
            # pool can hold it (a shrunk window beats a preemption)
            nd = max(0, min(nd,
                            self.max_seq
                            - self.cache.seqs[req.eid].length - 1,
                            req.max_new_tokens - len(req.out_tokens) - 1))
            while nd > 0 and not self.cache.prepare_write(req.eid, 1 + nd):
                nd -= 1
            if nd == 0 and not self.cache.prepare_write(req.eid, 1):
                self._preempt(i)
                continue
            tokens[i, 0] = req.out_tokens[-1]
            tokens[i, 1:1 + nd] = prop.tokens[i, :nd]
            n_new[i] = 1 + nd
            ready.append((i, nd))
        if not ready:
            return 0.0, 0
        lengths = self._lengths()
        tables = self._tables()

        # nothing drafted anywhere this step: the (b, k+1) verify graph
        # would burn (k+1)x decode compute on an effectively plain step,
        # so dispatch the ordinary (b, 1) decode graph instead
        plain = all(nd == 0 for _, nd in ready)
        step_fn = self._step_fn if plain else spec.verify_fn
        step_tokens = tokens[:, :1] if plain else tokens

        t0 = time.monotonic()
        logits, self.cache.pools = step_fn(
            self.params, self.cache.pools,
            {"tokens": jnp.asarray(step_tokens)},
            jnp.asarray(tables), jnp.asarray(lengths), jnp.asarray(n_new))
        dt = time.monotonic() - t0 + draft_s

        logits_np = np.asarray(logits)
        now = self._clock()
        drafted = accepted = 0
        for i, nd in ready:
            req = self.lanes[i]
            q_rows = prop.probs[i, :nd] if prop.probs is not None else None
            n_acc, emitted = spec.accept(
                logits_np[i, :nd + 1], tokens[i, 1:1 + nd], q_rows,
                req.sampling)
            drafted += nd
            accepted += n_acc
            seq = self.cache.seqs[req.eid]
            seq.length += n_acc + 1             # keep input + accepted rows
            self.cache.trim(req.eid, seq.length)  # free rejected pages
            if self.eos_id is not None and self.eos_id in emitted:
                emitted = emitted[:emitted.index(self.eos_id) + 1]
            budget = req.max_new_tokens - len(req.out_tokens)
            for tok in emitted[:budget]:
                self._emit(req, tok, now)
            self._maybe_finish(i, now)
        self.telemetry.spec(drafted, accepted)
        return dt, len(ready)

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        s = self.telemetry.summary()
        s["cow_copies"] = float(self.cache.cow_copies)
        s["kv_pages_shared"] = float(self.cache.pages_shared)
        if self.prefix is not None:
            s["prefix_pages_resident"] = float(self.prefix.n_pages)
            s["prefix_pages_evicted"] = float(self.prefix.pages_evicted)
        return s

    def throughput(self) -> float:
        """Decode-graph token rate (matches summary's
        decode_tokens_per_s; prefill time/tokens are reported
        separately)."""
        s = self.telemetry
        return s.decode_tokens / s.decode_s if s.decode_s else 0.0


# ============================================================================
# legacy compatibility shim
# ============================================================================
@dataclass
class Request:
    """Legacy request (seed API); prefer scheduler.ServeRequest."""
    prompt: np.ndarray
    max_new_tokens: int = 32
    rid: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Seed-API shim over the paged runtime.

    Dense/moe models run on `PagedServeEngine` (n_slots -> max_batch,
    worst-case page count so old workloads can never OOM).  Recurrent
    families keep a minimal slot loop over `decode_step` that only
    admits into an idle batch (their per-sequence state is constant-size;
    interleaved admission needs per-lane state swap, out of scope here).
    """

    def __init__(self, model: DecoderLM, params: Any, n_slots: int = 4,
                 max_seq: int = 256, greedy: bool = True,
                 sampling: Optional[SamplingParams] = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.sampling = sampling
        self._paged = model.supports_paged()
        if self._paged:
            # largest page size dividing max_seq (any max_seq works, as
            # the seed API allowed; page_size 1 = one token per page)
            page_size = next(p for p in (16, 8, 4, 2, 1)
                             if max_seq % p == 0)
            self.engine = PagedServeEngine(
                model, params, max_batch=n_slots, max_seq=max_seq,
                page_size=page_size,
                prefill_chunk=min(16, max_seq))
        else:
            self.engine = None
        self.stats: Dict[str, float] = {"tokens": 0, "steps": 0,
                                        "decode_s": 0.0}

    def run(self, requests: List[Request]) -> List[Request]:
        sampling = self.sampling if self.sampling is not None else \
            SamplingParams(temperature=0.0 if self.greedy else 1.0)
        if self._paged:
            sreqs = [ServeRequest(prompt=np.asarray(r.prompt, np.int32),
                                  max_new_tokens=r.max_new_tokens,
                                  rid=i, sampling=sampling)
                     for i, r in enumerate(requests)]
            self.engine.run(sreqs)
            for r, sr in zip(requests, sreqs):
                r.out_tokens = sr.out_tokens
                r.done = sr.done
            t = self.engine.telemetry
            self.stats = {"tokens": t.tokens, "steps": t.steps,
                          "decode_tokens": t.decode_tokens,
                          "decode_s": t.decode_s}
            return requests
        return self._run_recurrent(requests, sampling)

    def throughput(self) -> float:
        n = self.stats.get("decode_tokens", self.stats["tokens"])
        return n / self.stats["decode_s"] if self.stats["decode_s"] else 0.0

    # -- recurrent-family fallback --------------------------------------
    def _run_recurrent(self, requests: List[Request],
                       sampling: SamplingParams) -> List[Request]:
        model, params = self.model, self.params
        decode = jax.jit(model.decode_step)
        key = jax.random.PRNGKey(0)
        temp = jnp.full((self.n_slots,), sampling.temperature, jnp.float32)
        topk = jnp.full((self.n_slots,), sampling.top_k, jnp.int32)
        topp = jnp.full((self.n_slots,), sampling.top_p, jnp.float32)
        # recurrent state has no padding mask, so only EQUAL-length
        # prompts may share a lockstep batch (a pad token would corrupt
        # the shorter lane's state); group by length, then chunk
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        queue: List[List[Request]] = []
        for _, group in sorted(by_len.items()):
            for j in range(0, len(group), self.n_slots):
                queue.append(group[j:j + self.n_slots])
        while queue:
            batch = queue.pop(0)
            cache = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                spec_structs(model.cache_specs(self.n_slots, self.max_seq)))
            maxp = len(batch[0].prompt)
            toks = np.zeros((self.n_slots, maxp), np.int32)
            for i, r in enumerate(batch):
                toks[i] = r.prompt
            logits = None
            for t in range(maxp):
                logits, cache = decode(params, cache,
                                       {"tokens": jnp.asarray(toks[:, t:t + 1])},
                                       jnp.int32(t))
            steps = max(r.max_new_tokens for r in batch)
            t0 = time.monotonic()
            last = None
            for step in range(steps):
                key, sub = jax.random.split(key)
                nxt = np.asarray(sample_tokens(sub, logits[:, 0, :], temp,
                                               topk, topp))
                for i, r in enumerate(batch):
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(nxt[i]))
                        self.stats["tokens"] += 1
                        self.stats["decode_tokens"] = \
                            self.stats.get("decode_tokens", 0) + 1
                last = nxt.reshape(-1, 1)
                if step == steps - 1 or maxp + step + 1 >= self.max_seq:
                    break
                logits, cache = decode(params, cache,
                                       {"tokens": jnp.asarray(last)},
                                       jnp.int32(maxp + step))
                self.stats["steps"] += 1
            self.stats["decode_s"] += time.monotonic() - t0
            for r in batch:
                r.done = True
        return requests
