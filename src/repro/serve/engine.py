"""Batched decode serving engine (EdgeCIM's workload at pod scale).

Slot-based continuous batching-lite: a fixed decode batch of `n_slots`
sequences; finished/empty slots are refilled from the request queue at
step granularity.  The decode step is a single jitted call (one graph for
the whole batch — the GEMV regime the paper optimizes), with quantized
weights (INT4/INT8) as first-class params.

The engine is deliberately single-process here (the multi-pod image of
decode is the dry-run's serve_step with KV sharded over the mesh); its
role in this repo is (a) the end-to-end serving example, (b) the harness
that measures tokens/s for the benchmark suite.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DecoderLM
from repro.models.common import spec_structs


@dataclass
class Request:
    prompt: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 32
    rid: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: DecoderLM, params: Any, n_slots: int = 4,
                 max_seq: int = 256, greedy: bool = True):
        assert model.cfg.embed_inputs, "engine serves token-input models"
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy

        cache_specs = model.cache_specs(n_slots, max_seq)
        self.cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec_structs(cache_specs))
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)

        self._decode = jax.jit(model.decode_step)
        self.stats: Dict[str, float] = {"tokens": 0, "steps": 0,
                                        "decode_s": 0.0}

    # ------------------------------------------------------------------
    def _prefill_slot(self, slot: int, req: Request):
        """Token-by-token prefill into the slot's cache rows (keeps one
        compiled graph; a production engine would batch-prefill)."""
        for t, tok in enumerate(req.prompt):
            token = jnp.zeros((self.n_slots, 1), jnp.int32
                              ).at[slot, 0].set(int(tok))
            logits, self.cache = self._decode(self.params, self.cache,
                                              {"tokens": token},
                                              jnp.int32(t))
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        p = jax.nn.softmax(logits[:, 0, :], axis=-1)
        return np.asarray(jnp.argmax(p, axis=-1))

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Request]:
        queue = list(requests)
        active = 0
        # NOTE: slots share a position counter per step (aligned decoding);
        # per-slot positions are tracked for output trimming.
        while queue or any(r is not None for r in self.slot_req):
            # refill empty slots
            for s in range(self.n_slots):
                if self.slot_req[s] is None and queue:
                    self._prefill_slot(s, queue.pop(0))
            # one decode step for the whole batch
            pos = int(self.slot_pos.max())
            if pos >= self.max_seq:
                break
            last = np.zeros((self.n_slots, 1), np.int32)
            for s, req in enumerate(self.slot_req):
                if req is not None:
                    last[s, 0] = (req.out_tokens[-1] if req.out_tokens
                                  else req.prompt[-1])
            t0 = time.monotonic()
            logits, self.cache = self._decode(
                self.params, self.cache, {"tokens": jnp.asarray(last)},
                jnp.int32(pos))
            self.stats["decode_s"] += time.monotonic() - t0
            self.stats["steps"] += 1
            nxt = self._sample(logits)
            for s, req in enumerate(self.slot_req):
                if req is None:
                    continue
                req.out_tokens.append(int(nxt[s]))
                self.stats["tokens"] += 1
                self.slot_pos[s] = pos + 1
                if len(req.out_tokens) >= req.max_new_tokens:
                    req.done = True
                    self.slot_req[s] = None
        return requests

    def throughput(self) -> float:
        return self.stats["tokens"] / max(self.stats["decode_s"], 1e-9)
