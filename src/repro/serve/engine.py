"""Paged-KV continuous-batching serve engine (repro.serve v2).

EdgeCIM's workload is autoregressive decode — batched GEMV over a
growing KV cache — and the memory that cache wastes is the edge
bottleneck.  v2 replaces the seed's fixed-slot engine + dense
(n_slots, max_seq) cache with:

  allocator  (paged_cache.BlockAllocator) — refcounted free-list over
                                            KV pages (shared via prefix
                                            cache / fork, copy-on-write)
  prefix     (prefix.PrefixIndex)         — radix trie over committed
                                            prompt pages; admission
                                            adopts matched prefixes so
                                            prefill skips them
  scheduler  (scheduler.Scheduler)        — admission control, priority,
                                            deadlines, chunked prefill
  engine     (this file)                  — dynamic decode batch against
                                            the paged pool, streaming
                                            callbacks, preemption
  telemetry  (telemetry.Telemetry)        — TTFT/TPOT/queue percentiles,
                                            KV occupancy

The cache layer is a unified per-layer DECODE STATE: attention layers
keep paged KV pages, recurrent layers (mamba2 conv+SSM state, m/sLSTM
cells) keep fixed-size per-lane slots in a pooled StateArena
(serve/state.py).  Both are flattened into one cache dict for
`DecoderLM.serve_step`, so admission, chunked prefill, per-lane
sampling, deadlines, and preemption are IDENTICAL for every family —
hybrid zamba interleaves paged attention layers with arena layers in
one lane, and recurrent prefill is one masked-scan device call per
chunk, not one call per token.

Every step runs at most two jitted graphs with shape-stable arguments:
one chunked BATCH PREFILL call (b = max_batch, s = prefill_chunk) and
one decode call — `DecoderLM.serve_step` (b = max_batch, s = 1), or,
when the engine is built with a `repro.spec.SpecConfig`, one
`paged_verify_step` (b = max_batch, s = k + 1) that verifies a drafted
window and emits a variable number of tokens per lane (speculative
decoding; see repro/spec/).  Per-lane positions make one sequence's
prefill unable to clobber another's cache rows (the seed
`_prefill_slot` bug).

Prefix caching and speculative decoding remain attention-only
capabilities: adopting or rolling back KV pages cannot adopt or roll
back a recurrent state, so requesting either on a model with recurrent
state layers raises a ValueError naming the capability (never silent
state corruption).  `ServeEngine` + `Request` remain as the seed-API
shim; every token-input family now routes to the paged runtime.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field, replace as dc_replace
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DecoderLM
from repro.obs.energy import EnergyMeter
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import get_tracer
from repro.quant.ptq import quantize_params
from repro.quant.qarray import QTensor, dequant_counters

from .config import ServeConfig
from .paged_cache import PagedKVCache
from .prefix import PrefixIndex
from .sampling import SamplingParams, processed_probs, sample_tokens
from .scheduler import Scheduler, ServeRequest
from .state import StateArena
from .telemetry import Telemetry


# attention-only capability guards: one message source for the engine
# and the launcher, so the policy and its wording cannot drift apart
_CAPABILITY_REASONS = {
    "speculative-decoding": "verify/rollback cannot rewind",
    "prefix-cache": "page adoption cannot reproduce",
    "parallel-sampling": "forked KV pages cannot clone",
}


def capability_error(model: DecoderLM, capability: str) -> str:
    return (f"capability {capability!r} requires a paged-attention-only "
            f"model; family {model.cfg.family!r} carries recurrent "
            f"per-lane state that {_CAPABILITY_REASONS[capability]}")


_UNSET = object()
_legacy_warned = False      # deprecation shim warns once per process

_KV_DTYPE_NAMES = {"bfloat16": "bf16", "float32": "f32", "int8": "int8"}


def _config_from_legacy(max_batch, max_seq, page_size, n_pages,
                        prefill_chunk, kv_dtype, eos_id, seed,
                        prefix_cache) -> ServeConfig:
    """Map the pre-ServeConfig kwargs onto a ServeConfig (fp precision:
    the old engine always served float weights)."""
    kv = "bf16" if kv_dtype is _UNSET else \
        _KV_DTYPE_NAMES[jnp.dtype(kv_dtype).name]
    return ServeConfig(
        precision="fp", kv_dtype=kv,
        max_batch=8 if max_batch is _UNSET else max_batch,
        max_seq=256 if max_seq is _UNSET else max_seq,
        page_size=16 if page_size is _UNSET else page_size,
        n_pages=None if n_pages is _UNSET else n_pages,
        prefill_chunk=16 if prefill_chunk is _UNSET else prefill_chunk,
        eos_id=None if eos_id is _UNSET else eos_id,
        seed=0 if seed is _UNSET else seed,
        prefix_cache=None if prefix_cache is _UNSET else prefix_cache)


class PagedServeEngine:
    def __init__(self, model: DecoderLM, params: Any,
                 config: Optional[ServeConfig] = None, *,
                 max_batch=_UNSET, max_seq=_UNSET, page_size=_UNSET,
                 n_pages=_UNSET, prefill_chunk=_UNSET, kv_dtype=_UNSET,
                 eos_id=_UNSET, seed=_UNSET,
                 spec: Optional[Any] = None,
                 prefix_cache=_UNSET,
                 clock=time.monotonic):
        legacy = {k: v for k, v in [
            ("max_batch", max_batch), ("max_seq", max_seq),
            ("page_size", page_size), ("n_pages", n_pages),
            ("prefill_chunk", prefill_chunk), ("kv_dtype", kv_dtype),
            ("eos_id", eos_id), ("seed", seed),
            ("prefix_cache", prefix_cache)] if v is not _UNSET}
        if config is None:
            if legacy:
                global _legacy_warned
                if not _legacy_warned:
                    _legacy_warned = True
                    warnings.warn(
                        "PagedServeEngine(max_batch=..., kv_dtype=..., ...)"
                        " kwargs are deprecated; pass a"
                        " serve.ServeConfig instead",
                        DeprecationWarning, stacklevel=2)
            config = _config_from_legacy(
                max_batch, max_seq, page_size, n_pages, prefill_chunk,
                kv_dtype, eos_id, seed, prefix_cache)
        elif legacy:
            raise ValueError(
                "pass either a ServeConfig or legacy kwargs, not both: "
                + ", ".join(sorted(legacy)))
        if (config.kv_dtype == "auto"
                and config.resolved_kv_dtype() == jnp.int8
                and model.cfg.attn_kind == "mla"):
            # auto means "best supported": MLA latent pools stay float
            # (attention.paged_cache_spec rejects int8 for them), so
            # auto degrades to bf16 instead of crashing — only an
            # EXPLICIT kv_dtype="int8" is a capability error.  Pin the
            # resolution into the config so /metrics reports what the
            # engine actually allocated.
            config = dc_replace(config, kv_dtype="bf16")
        self.config = config
        max_batch, max_seq = config.max_batch, config.max_seq
        page_size, n_pages = config.page_size, config.n_pages
        prefill_chunk, eos_id = config.prefill_chunk, config.eos_id
        seed, prefix_cache = config.seed, config.prefix_cache
        kv_dtype = config.resolved_kv_dtype()
        # tensor parallelism: a ("model",) mesh of tp devices.  Raises
        # here — not at first step — when tp does not divide the
        # model's head/FFN dims or the backend lacks the devices.
        self.mesh = None
        if config.tp > 1:
            from repro.dist import serve_mesh
            model.validate_tp(config.tp)
            self.mesh = serve_mesh(config.tp)
        if config.quantized() and not any(
                isinstance(l, QTensor) for l in jax.tree_util.tree_leaves(
                    params, is_leaf=lambda x: isinstance(x, QTensor))):
            # launcher may hand us raw float params; the precision field
            # is authoritative, so quantize here
            params = quantize_params(params, bits=config.weight_bits(),
                                     group=config.quant_group)
        assert model.cfg.embed_inputs, "engine serves token-input models"
        assert max_seq % page_size == 0, (max_seq, page_size)
        # capability guards: prefix sharing and speculative decoding act
        # on attention KV pages alone; a model with recurrent state
        # layers cannot adopt or roll back that state, so asking is a
        # hard error — never silent state corruption
        if spec is not None and not model.supports_paged():
            raise ValueError(capability_error(model,
                                             "speculative-decoding"))
        if prefix_cache is None:        # auto: on iff fully paged
            prefix_cache = model.supports_paged()
        elif prefix_cache and not model.supports_paged():
            raise ValueError(capability_error(model, "prefix-cache"))
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._clock = clock
        if n_pages is None:      # dense-equivalent worst case: never OOM
            n_pages = max_batch * (max_seq // page_size)
        # unified per-layer decode state: paged KV pools for attention
        # layers (block tables, COW, ...) plus a StateArena of per-lane
        # slots for recurrent layers.  PagedKVCache doubles as the
        # token-budget ledger for families with no attention at all
        # (pools == {}): pages_needed gates admission and growth
        # uniformly, so scheduler and preemption logic are
        # family-agnostic.
        state_specs = model.decode_state_specs(max_batch, n_pages,
                                               page_size, kv_dtype)
        self.cache = PagedKVCache(model, n_pages, page_size, max_seq,
                                  kv_dtype, specs=state_specs["paged"])
        self.arena: Optional[StateArena] = (
            StateArena(model, max_batch, specs=state_specs["arena"])
            if model.has_recurrent_state() else None)
        self._paged_keys = tuple(self.cache.pools)
        self._state_shardings = None
        if self.mesh is not None:
            self._shard_runtime_state(state_specs)
        # prefix sharing: committed prompt pages live in a radix trie and
        # are adopted by later requests with the same prefix (see
        # prefix.py); allocation pressure evicts trie-only pages LRU
        self.prefix: Optional[PrefixIndex] = None
        if prefix_cache:
            self.prefix = PrefixIndex(self.cache.allocator, page_size)
            self.cache.prefix_index = self.prefix
        self.scheduler = Scheduler(max_batch,
                                   prefill_chunk=min(prefill_chunk, max_seq))
        self.telemetry = Telemetry()
        # observability: process tracer (opt-in, /debug/trace), always-on
        # flight recorder (postmortem ring; replica sets the label), and
        # the CIM energy meter (simulated J / tokens-per-J in summary())
        self.tracer = get_tracer()
        self.scheduler.tracer = self.tracer
        self.recorder = FlightRecorder(label="engine", clock=clock)
        # the energy meter charges at the SERVED precision: int4 hits
        # the paper's CIM operating point (e_mac_int4, 4 bit-serial
        # passes), fp pays 16-bit storage and pass counts
        self.energy = EnergyMeter(
            model.cfg, w_bits=config.weight_bits(),
            a_bits=8 if config.quantized() else 16, tp=config.tp)
        self._last_t0 = 0.0
        self._cow_seen = 0          # deltas -> cow_copy / prefix_evict
        self._evict_seen = 0        # trace instants per step
        self.lanes: List[Optional[ServeRequest]] = [None] * max_batch
        self._step_fn = self._jit_step(model.serve_step)
        self._key = jax.random.PRNGKey(seed)
        self._next_eid = 0
        if spec is not None:            # SpecConfig -> speculative decode
            from repro.spec import SpecDecoder
            self.spec: Optional[SpecDecoder] = SpecDecoder(
                model, spec, max_batch=max_batch, max_seq=max_seq,
                kv_dtype=kv_dtype)
            if self.mesh is not None:
                # the verify window runs the very same sharded layout
                # as decode (the draft model stays single-device: it is
                # deliberately small enough not to need the mesh)
                self.spec.verify_fn = self._jit_step(
                    model.paged_verify_step)
        else:
            self.spec = None

    # -- tensor parallelism --------------------------------------------
    def _shard_runtime_state(self, state_specs) -> None:
        """Commit weights, KV pools, and arena slots to the serve mesh.

        Weights shard by their declared TP axes (QTensor leaves keep
        data and scales on one consistent pspec — see
        dist.qtree_shardings); pool leaves shard on the KV-head group
        dim (and the matching INT8 scale-pool dim), page axis
        replicated so the host-side block tables stay per-shard
        identical; arena leaves shard their cell head dims with the
        lane axis replicated.  Everything host-fed (tokens, tables,
        lengths) enters uncommitted and is replicated by GSPMD."""
        from repro.dist import (SERVE_RULES, qtree_shardings, replicated,
                                tree_shardings)
        mesh = self.mesh
        self._replicated = replicated(mesh)
        self.params = jax.device_put(
            self.params, qtree_shardings(self.model.param_specs(),
                                         self.params, mesh, SERVE_RULES))
        pool_sh = tree_shardings(state_specs["paged"], mesh, SERVE_RULES)
        self.cache.pools = jax.device_put(self.cache.pools, pool_sh)
        self._state_shardings = dict(pool_sh)
        if self.arena is not None:
            arena_sh = tree_shardings(state_specs["arena"], mesh,
                                      SERVE_RULES)
            self.arena.state = jax.device_put(self.arena.state, arena_sh)
            self._state_shardings.update(arena_sh)

    def _jit_step(self, fn):
        """Jit a (params, state, inputs, tables, lengths, n_new) step.

        tp == 1: plain jit, byte-for-byte the pre-TP path.  tp > 1: the
        step traces inside `use_mesh_rules`, so the model's
        `constrain(..)` hints become real sharding constraints, and
        out_shardings pin logits replicated (host sampling reads one
        gathered copy) and the returned state back onto its canonical
        pool/arena shardings — donation then reuses the input buffers
        shard-for-shard and the layout can never drift step to step."""
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=(1,))
        from repro.dist import SERVE_RULES, use_mesh_rules
        mesh = self.mesh

        def traced(params, state, inputs, tables, lengths, n_new):
            with use_mesh_rules(mesh, SERVE_RULES):
                return fn(params, state, inputs, tables, lengths, n_new)

        return jax.jit(traced, donate_argnums=(1,),
                       out_shardings=(self._replicated,
                                      dict(self._state_shardings)))

    # ------------------------------------------------------------------
    def _event(self, kind: str, **fields: Any) -> None:
        """One engine lifecycle event: always lands in the flight
        recorder (postmortem ring), mirrored to the tracer as an
        instant when tracing is on."""
        self.recorder.record(kind, **fields)
        if self.tracer.enabled:
            self.tracer.instant(kind, cat="engine", **fields)

    # ------------------------------------------------------------------
    @property
    def n_running(self) -> int:
        return sum(r is not None for r in self.lanes)

    @property
    def busy(self) -> bool:
        return self.n_running > 0 or self.scheduler.n_queued > 0

    def submit(self, req: ServeRequest) -> None:
        if req.fork_from is not None and not self.model.supports_paged():
            raise ValueError(capability_error(self.model,
                                              "parallel-sampling"))
        now = self._clock()
        req.eid = self._next_eid      # rid is the caller's label and may
        self._next_eid += 1           # collide; eid keys cache/telemetry
        self.telemetry.enqueue(req.eid, now)
        self.scheduler.submit(req, now)
        self._event("submit", eid=req.eid, rid=req.trace_id,
                    prompt_len=req.prompt_len)

    def cancel(self, eid: int) -> bool:
        """Abort a submitted request wherever it is in its lifecycle —
        queued, mid-prefill, mid-decode, or preempted-with-snapshot.
        Frees its KV pages and lane (decref: pages shared with the
        prefix trie or a fork survive), releases drafter state, closes
        the telemetry trace.  Returns False when `eid` is unknown or
        already finished.  NOT thread-safe against a concurrent
        `step()`: callers off the engine thread route through the
        gateway's EngineDriver, which runs cancels between steps."""
        now = self._clock()
        queued = self.scheduler.cancel(eid)
        if queued is not None:      # mid-queue (possibly preempted: any
            queued.done = True      # saved arena snapshot dies with it)
            queued.saved_state = None
            self.telemetry.cancel(eid, now)
            self._event("cancel", eid=eid, rid=queued.trace_id,
                        where="queued")
            return True
        for lane, req in enumerate(self.lanes):
            if req is not None and req.eid == eid:
                req.done = True
                req.cancelled = True
                self.cache.release(eid)
                self.lanes[lane] = None
                if self.spec is not None:
                    self.spec.drafter.release(lane)
                self.telemetry.cancel(eid, now)
                self._event("cancel", eid=eid, rid=req.trace_id,
                            where="lane", lane=lane)
                return True
        return False

    def run(self, requests: List[ServeRequest]) -> List[ServeRequest]:
        for r in requests:
            self.submit(r)
        while self.busy:
            self.step()
        return requests

    # ------------------------------------------------------------------
    def _dispatch(self, fn, tokens: np.ndarray, tables: np.ndarray,
                  lengths: np.ndarray, n_new: np.ndarray):
        """Run one jitted step: flatten paged pools + arena slots into
        the unified cache dict (their key sets are disjoint by
        construction), split the returned state back.  Returns
        (logits, graph seconds)."""
        state = dict(self.cache.pools)
        if self.arena is not None:
            state.update(self.arena.state)
        t0 = time.monotonic()
        logits, state = fn(
            self.params, state, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(tables), jnp.asarray(lengths), jnp.asarray(n_new))
        if self.mesh is not None:
            # one gathered host copy: every downstream consumer
            # (sampling, logprobs, verify walks) runs on identical
            # bytes regardless of tp — the byte-identity invariant
            # lives here
            logits = jax.device_get(logits)
        dt = time.monotonic() - t0
        self._last_t0 = t0      # span start for tracer.complete()
        if self.arena is not None:
            self.arena.state = {k: state[k] for k in self.arena.keys}
            self.cache.pools = {k: state[k] for k in self._paged_keys}
        else:
            self.cache.pools = state
        return logits, dt

    def _tables(self) -> np.ndarray:
        tab = np.zeros((self.max_batch, self.cache.max_pages), np.int32)
        for i, req in enumerate(self.lanes):
            if req is not None:
                tab[i] = self.cache.table_for(req.eid)
        return tab

    def _lengths(self) -> np.ndarray:
        ln = np.zeros(self.max_batch, np.int32)
        for i, req in enumerate(self.lanes):
            if req is not None:
                ln[i] = self.cache.seqs[req.eid].length
        return ln

    def _sample_rows(self, rows: jax.Array) -> np.ndarray:
        """rows: (max_batch, vocab) -> (max_batch,) tokens, per-lane
        sampling params, PRNG key threaded through the engine."""
        temp = np.zeros(self.max_batch, np.float32)
        topk = np.zeros(self.max_batch, np.int32)
        topp = np.ones(self.max_batch, np.float32)
        for i, req in enumerate(self.lanes):
            if req is not None:
                temp[i] = req.sampling.temperature
                topk[i] = req.sampling.top_k
                topp[i] = req.sampling.top_p
        self._key, sub = jax.random.split(self._key)
        return np.asarray(sample_tokens(sub, rows, temp, topk, topp))

    def _emit(self, req: ServeRequest, token: int, now: float,
              decode: bool = True, row=None) -> None:
        req.out_tokens.append(token)
        if req.logprobs and row is not None:
            req.out_logprobs.append(
                self._logprob_entropy(row, token, req.sampling))
        self.telemetry.token(req.eid, now, decode=decode)
        if req.on_token is not None:
            req.on_token(req.rid, token)

    @staticmethod
    def _logprob_entropy(row, token: int, sampling: SamplingParams):
        """(logprob, entropy) of `token` under the PROCESSED sampling
        distribution (temperature/top-k/top-p applied) — the
        distribution the token was actually drawn from, so greedy
        decoding reports logprob 0 and entropy 0.  Host-side O(vocab),
        computed only for requests that asked for logprobs."""
        p = processed_probs(np.asarray(row, np.float32),
                            sampling.temperature, sampling.top_k,
                            sampling.top_p)
        pt = float(p[token])
        nz = p[p > 0.0]
        # + 0.0 normalizes the one-hot case's -0.0 before it hits JSON
        ent = float(-np.sum(nz * np.log(nz)) + 0.0) if nz.size else 0.0
        return (float(np.log(max(pt, 1e-12))), ent)

    def _maybe_finish(self, lane: int, now: float) -> None:
        req = self.lanes[lane]
        seq = self.cache.seqs[req.eid]
        hit_eos = (self.eos_id is not None and req.out_tokens
                   and req.out_tokens[-1] == self.eos_id)
        if (len(req.out_tokens) >= req.max_new_tokens or hit_eos
                or seq.length >= self.max_seq):
            req.done = True
            self.telemetry.done(req.eid, now)
            self._event("finish", eid=req.eid, rid=req.trace_id,
                        lane=lane, tokens=len(req.out_tokens),
                        reason="eos" if hit_eos else "budget")
            if self.prefix is not None and seq.length > req.prompt_len:
                # generated-suffix caching: the finished lane's KV holds
                # prompt + generated rows — commit the full pages past
                # the prompt too, so a follow-up turn that extends this
                # completion (chat history growing turn by turn) adopts
                # them instead of re-prefilling.  Materialized tokens
                # run to seq.length (the final emitted token was never
                # fed back), and insert() only commits full pages.  The
                # prompt of a preempted-then-resumed request already
                # contains out_tokens[:prompt_folded] — appending past
                # the fold cursor keeps trie keys equal to the actual
                # page contents.
                full = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.out_tokens[req.prompt_folded:],
                                np.int32)])[:seq.length]
                self.prefix.insert(full, seq.pages)
            self.cache.release(req.eid)
            self.lanes[lane] = None
            if self.spec is not None:
                self.spec.drafter.release(lane)

    def _preempt(self, lane: int) -> None:
        """Pool exhausted mid-decode: evict this lane and requeue it.

        Pure-recurrent families snapshot the lane's StateArena slot to
        host — constant-size, exact — and resume from it on re-admission
        without re-prefilling a single token.  Families with attention
        layers lose their KV pages at eviction, so they requeue with
        (prompt + generated) as the new prompt and rebuild everything by
        prefill when pages free up (a hybrid's restored mamba state
        would be double-advanced by that rebuild, hence no snapshot)."""
        req = self.lanes[lane]
        self._event("preempt", eid=req.eid, rid=req.trace_id, lane=lane,
                    tokens=len(req.out_tokens))
        if self.arena is not None and self.model.n_paged_layers() == 0:
            req.saved_state = self.arena.save_lane(lane)
            req.saved_length = self.cache.seqs[req.eid].length
            req.saved_prefill_done = req.prefill_done
        else:
            # fold only the tokens generated SINCE the last fold: on a
            # second preemption out_tokens[:prompt_folded] are already
            # part of the prompt, and re-appending them would rebuild
            # (and re-serve) a history with duplicated runs
            req.prompt = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.out_tokens[req.prompt_folded:],
                            np.int32)])
            req.prompt_folded = len(req.out_tokens)
            req.prefill_done = 0
        # a preempted fork child rebuilds (prompt + generated) by
        # prefill: its new prompt has diverged from the parent's pages,
        # so re-admitting through the fork path would adopt KV rows for
        # tokens it never saw — sever the link (and its skip accounting)
        req.fork_from = None
        req.forked_tokens = 0
        self.cache.release(req.eid)
        self.lanes[lane] = None
        if self.spec is not None:
            self.spec.drafter.release(lane)
        self.scheduler.submit(req, self._clock(), resubmit=True)

    # ------------------------------------------------------------------
    def step(self) -> None:
        now = self._clock()

        def _reject(r: ServeRequest) -> None:
            self.telemetry.done(r.eid, now)
            self._event("reject", eid=r.eid, rid=r.trace_id,
                        reason=r.reject_reason, truncated=r.truncated)

        for req in self.scheduler.admit(
                now, self.n_running, self.cache, on_reject=_reject):
            lane = self.lanes.index(None)
            self.lanes[lane] = req
            self.telemetry.admit(req.eid, now)
            self._event("fork_admit" if req.fork_from is not None
                        else "admit",
                        eid=req.eid, rid=req.trace_id, lane=lane,
                        prompt_len=req.prompt_len,
                        prefix_cached=req.prefix_cached,
                        resumed=req.saved_state is not None)
            if self.arena is not None:
                if req.saved_state is not None:
                    # resumed preemption: scatter the host snapshot back
                    # and pick up exactly where the lane left off
                    self.arena.restore_lane(lane, req.saved_state)
                    self.cache.seqs[req.eid].length = req.saved_length
                    req.prefill_done = req.saved_prefill_done
                    req.saved_state = None
                else:       # fresh admission must never inherit a dead
                    self.arena.reset_lane(lane)     # lane's state
            if req.fork_from is not None:   # admitted via fork (even a
                # 1-token prompt sharing 0 pages): the trie was never
                # probed, so this is not a prefix lookup/miss
                self.telemetry.fork(req.forked_tokens)
            elif self.prefix is not None:
                self.telemetry.prefix(req.prefix_cached)

        prefill_s = self._prefill_phase()
        if self.spec is not None:
            decode_s, decode_lanes = self._decode_phase_spec()
        else:
            decode_s, decode_lanes = self._decode_phase()
        # page-sharing machinery reports deltas, not per-call hooks:
        # surface them as per-step instants when tracing
        if self.tracer.enabled:
            if self.cache.cow_copies > self._cow_seen:
                self.tracer.instant(
                    "cow_copy", cat="engine",
                    n=self.cache.cow_copies - self._cow_seen)
            evicted = (self.prefix.pages_evicted
                       if self.prefix is not None else 0)
            if evicted > self._evict_seen:
                self.tracer.instant("prefix_evict", cat="engine",
                                    n=evicted - self._evict_seen)
        self._cow_seen = self.cache.cow_copies
        self._evict_seen = (self.prefix.pages_evicted
                            if self.prefix is not None else 0)
        # arena slots are engine lanes 1:1, so slot fill is running
        # lanes over max_batch — sampled only when an arena exists
        state_occ = (self.n_running / self.max_batch
                     if self.arena is not None else None)
        self.telemetry.step(self.cache.occupancy(), self.n_running,
                            decode_s=decode_s, prefill_s=prefill_s,
                            decode_lanes=decode_lanes,
                            state_occupancy=state_occ,
                            family=self.model.cfg.family)

    def _prefill_phase(self) -> float:
        """One chunked BATCH prefill call for every lane with prompt
        tokens left; lanes finishing their prompt sample their first
        output token from this call's logits."""
        pre = [i for i, r in enumerate(self.lanes)
               if r is not None and r.prefill_remaining > 0]
        if not pre:
            return 0.0
        s = self.scheduler.prefill_chunk
        tokens = np.zeros((self.max_batch, s), np.int32)
        n_new = np.zeros(self.max_batch, np.int32)
        finishing = False
        for i in list(pre):
            req = self.lanes[i]
            q = self.scheduler.prefill_quota(req)
            # prompt pages were allocated at admission, but a forked /
            # resubmitted lane may start mid-page on a shared page:
            # copy-on-write it before the chunk lands
            if not self.cache.prepare_write(req.eid, q):
                self._preempt(i)
                pre.remove(i)
                continue
            tokens[i, :q] = req.prompt[req.prefill_done:req.prefill_done + q]
            n_new[i] = q
            finishing |= q == req.prefill_remaining
        if not pre:
            return 0.0
        logits, dt = self._dispatch(self._step_fn, tokens, self._tables(),
                                    self._lengths(), n_new)

        if finishing:       # only sample when some lane ends its prompt
            last = jnp.take_along_axis(
                logits, jnp.asarray(np.maximum(n_new - 1, 0)
                                    )[:, None, None], axis=1)[:, 0, :]
            nxt = self._sample_rows(last)
        now = self._clock()
        chunk_rids = [self.lanes[i].trace_id for i in pre]
        chunk_tokens = 0
        for i in pre:
            req = self.lanes[i]
            q = int(n_new[i])
            req.prefill_done += q
            chunk_tokens += q
            self.cache.seqs[req.eid].length += q
            self.telemetry.prefill_tokens += q
            if req.prefill_remaining == 0:
                if self.prefix is not None:
                    # prompt fully materialized: commit its full pages
                    # so later requests with the same prefix skip them
                    self.prefix.insert(np.asarray(req.prompt, np.int32),
                                       self.cache.seqs[req.eid].pages)
                self._emit(req, int(nxt[i]), now, decode=False,
                           row=np.asarray(last[i])
                           if req.logprobs else None)
                self._maybe_finish(i, now)
        self.energy.charge_prefill(chunk_tokens)
        self.recorder.record("prefill_chunk", lanes=len(pre),
                             tokens=chunk_tokens, dur_s=dt)
        if self.tracer.enabled:
            self.tracer.complete(
                "prefill_chunk", self._last_t0, dt, cat="engine",
                rids=chunk_rids, lanes=len(pre), tokens=chunk_tokens)
        return dt

    def _decode_ready(self) -> List[int]:
        """Lanes with their prompt fully cached and at least one emitted
        token (a lane that finished prefill this same step joins
        immediately: its first token is this call's input, written at
        position seqs[eid].length)."""
        return [i for i, r in enumerate(self.lanes)
                if r is not None and r.prefill_remaining == 0
                and r.out_tokens]

    def _decode_phase(self) -> tuple:
        """One token for every decode-ready lane.  Returns (graph
        seconds, lanes advanced)."""
        ready = []
        for i in self._decode_ready():
            req = self.lanes[i]
            # the token we feed is the last emitted one; this decode call
            # itself writes its KV row at position seqs[rid].length
            # (prepare_write also copy-on-writes a shared tail page)
            if not self.cache.prepare_write(req.eid, 1):
                self._preempt(i)
                continue
            ready.append(i)
        if not ready:
            return 0.0, 0

        tokens = np.zeros((self.max_batch, 1), np.int32)
        n_new = np.zeros(self.max_batch, np.int32)
        for i in ready:
            req = self.lanes[i]
            tokens[i, 0] = req.out_tokens[-1]
            n_new[i] = 1
        lens = self._lengths()
        logits, dt = self._dispatch(self._step_fn, tokens, self._tables(),
                                    lens, n_new)

        nxt = self._sample_rows(logits[:, 0, :])
        now = self._clock()
        rids = [self.lanes[i].trace_id for i in ready]
        self.energy.charge_decode(len(ready), float(lens[ready].mean()))
        self.recorder.record("decode_step", lanes=len(ready), dur_s=dt)
        if self.tracer.enabled:
            self.tracer.complete("decode_step", self._last_t0, dt,
                                 cat="engine", rids=rids,
                                 lanes=len(ready))
        for i in ready:
            req = self.lanes[i]
            self.cache.seqs[req.eid].length += 1
            self._emit(req, int(nxt[i]), now,
                       row=np.asarray(logits[i, 0, :])
                       if req.logprobs else None)
            self._maybe_finish(i, now)
        return dt, len(ready)

    def _decode_phase_spec(self) -> tuple:
        """Speculative decode: draft up to k tokens per lane, verify the
        whole window in ONE `paged_verify_step` call (always
        (max_batch, k + 1) — shape-stable under jit), emit the accepted
        prefix plus the bonus token, roll rejected KV rows back.

        Lanes with `req.spec == False`, or whose drafter found nothing,
        ride the same call with an empty window — for them this IS a
        plain decode step, so greedy output is byte-identical to the
        non-speculative engine either way.
        """
        spec = self.spec
        k = spec.cfg.k              # verify graph width: ALWAYS k_max +
        k_draft = spec.current_k()  # 1; autok only narrows how much the
        dec = self._decode_ready()  # drafter proposes (no retrace)
        if not dec:
            return 0.0, 0

        histories: List[Optional[np.ndarray]] = [None] * self.max_batch
        smp: List[Optional[SamplingParams]] = [None] * self.max_batch
        for i in dec:
            req = self.lanes[i]
            if req.spec:
                # out_tokens past the preemption fold cursor: a resumed
                # request's prompt already holds the earlier ones
                histories[i] = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.out_tokens[req.prompt_folded:],
                                np.int32)])
                smp[i] = req.sampling
        # drafting is part of the decode budget speculation spends —
        # timing it keeps tokens_per_s_decode (and spec_bench's speedup
        # column) honest about what a model drafter costs
        t0 = time.monotonic()
        prop = spec.drafter.propose(histories, k_draft, smp)
        draft_s = time.monotonic() - t0

        tokens = np.zeros((self.max_batch, k + 1), np.int32)
        n_new = np.zeros(self.max_batch, np.int32)
        ready: List[tuple] = []                 # (lane, n_draft)
        for i in dec:
            req = self.lanes[i]
            nd = int(prop.n[i]) if histories[i] is not None else 0
            # the window writes 1 + nd KV rows and may emit 1 + nd
            # tokens; cap at the sequence budget AND the request's
            # remaining token budget (no point verifying tokens
            # emitted[:budget] would discard), then shrink until the
            # pool can hold it (a shrunk window beats a preemption)
            nd = max(0, min(nd,
                            self.max_seq
                            - self.cache.seqs[req.eid].length - 1,
                            req.max_new_tokens - len(req.out_tokens) - 1))
            while nd > 0 and not self.cache.prepare_write(req.eid, 1 + nd):
                nd -= 1
            if nd == 0 and not self.cache.prepare_write(req.eid, 1):
                self._preempt(i)
                continue
            tokens[i, 0] = req.out_tokens[-1]
            tokens[i, 1:1 + nd] = prop.tokens[i, :nd]
            n_new[i] = 1 + nd
            ready.append((i, nd))
        if not ready:
            return 0.0, 0
        lengths = self._lengths()
        tables = self._tables()

        # nothing drafted anywhere this step: the (b, k+1) verify graph
        # would burn (k+1)x decode compute on an effectively plain step,
        # so dispatch the ordinary (b, 1) decode graph instead
        plain = all(nd == 0 for _, nd in ready)
        step_fn = self._step_fn if plain else spec.verify_fn
        step_tokens = tokens[:, :1] if plain else tokens

        logits, dt = self._dispatch(step_fn, step_tokens, tables, lengths,
                                    n_new)
        verify_s = dt
        dt += draft_s

        logits_np = np.asarray(logits)
        now = self._clock()
        drafted = accepted = n_emitted = 0
        lanes_idx = [i for i, _ in ready]
        rids = [self.lanes[i].trace_id for i in lanes_idx]
        for i, nd in ready:
            req = self.lanes[i]
            q_rows = prop.probs[i, :nd] if prop.probs is not None else None
            n_acc, emitted = spec.accept(
                logits_np[i, :nd + 1], tokens[i, 1:1 + nd], q_rows,
                req.sampling)
            drafted += nd
            accepted += n_acc
            seq = self.cache.seqs[req.eid]
            seq.length += n_acc + 1             # keep input + accepted rows
            self.cache.trim(req.eid, seq.length)  # free rejected pages
            if self.eos_id is not None and self.eos_id in emitted:
                emitted = emitted[:emitted.index(self.eos_id) + 1]
            budget = req.max_new_tokens - len(req.out_tokens)
            # emitted[j] was accepted/sampled from verify-logits row j,
            # so that row is its (target-model) logprob source
            for j, tok in enumerate(emitted[:budget]):
                self._emit(req, tok, now,
                           row=logits_np[i, j] if req.logprobs else None)
                n_emitted += 1
            self._maybe_finish(i, now)
        self.telemetry.spec(drafted, accepted)
        spec.observe(drafted, accepted)
        self.energy.charge_decode(
            n_emitted, float(lengths[lanes_idx].mean()))
        self.recorder.record("spec_verify", lanes=len(ready),
                             drafted=drafted, accepted=accepted,
                             dur_s=dt)
        if self.tracer.enabled:
            if draft_s > 0.0:
                self.tracer.complete("spec_draft", t0, draft_s,
                                     cat="engine", rids=rids)
            self.tracer.complete("spec_verify", self._last_t0, verify_s,
                                 cat="engine", rids=rids,
                                 lanes=len(ready), drafted=drafted,
                                 accepted=accepted)
        return dt, len(ready)

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        s = self.telemetry.summary()
        s.update(self.energy.summary())
        # trace-time dequant counters: full_dequant counts whole-weight
        # float materializations traced into any graph this process;
        # a quantized hot path keeps the delta at 0 (api_bench asserts)
        dq = dequant_counters()
        s["weight_full_dequants"] = float(dq["full_dequant"])
        s["weight_fused_dequants"] = float(dq["fused_dequant"])
        s["cow_copies"] = float(self.cache.cow_copies)
        s["kv_pages_shared"] = float(self.cache.pages_shared)
        if self.spec is not None:
            s["spec_k_now"] = float(self.spec.current_k())
        if self.arena is not None:
            s["state_bytes"] = float(self.arena.state_bytes())
        if self.prefix is not None:
            s["prefix_pages_resident"] = float(self.prefix.n_pages)
            s["prefix_pages_evicted"] = float(self.prefix.pages_evicted)
        return s

    def throughput(self) -> float:
        """Decode-graph token rate (matches summary's
        decode_tokens_per_s; prefill time/tokens are reported
        separately)."""
        s = self.telemetry
        return s.decode_tokens / s.decode_s if s.decode_s else 0.0


# ============================================================================
# legacy compatibility shim
# ============================================================================
@dataclass
class Request:
    """Legacy request (seed API); prefer scheduler.ServeRequest."""
    prompt: np.ndarray
    max_new_tokens: int = 32
    rid: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Seed-API shim over the paged runtime.

    Every token-input family routes to `PagedServeEngine`
    (n_slots -> max_batch, worst-case page count so old workloads can
    never OOM): attention KV lives in paged pools, recurrent state in
    per-lane StateArena slots, so recurrent families continuous-batch
    like everyone else — the old lockstep slot loop (equal-prompt-length
    grouping, one jitted call per prompt token) is gone.
    """

    def __init__(self, model: DecoderLM, params: Any, n_slots: int = 4,
                 max_seq: int = 256, greedy: bool = True,
                 sampling: Optional[SamplingParams] = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.sampling = sampling
        # largest page size dividing max_seq (any max_seq works, as
        # the seed API allowed; page_size 1 = one token per page)
        page_size = next(p for p in (16, 8, 4, 2, 1)
                         if max_seq % p == 0)
        self.engine = PagedServeEngine(
            model, params, ServeConfig(
                precision="fp", kv_dtype="bf16", max_batch=n_slots,
                max_seq=max_seq, page_size=page_size,
                prefill_chunk=min(16, max_seq)))
        self.stats: Dict[str, float] = {"tokens": 0, "steps": 0,
                                        "decode_s": 0.0}

    def run(self, requests: List[Request]) -> List[Request]:
        sampling = self.sampling if self.sampling is not None else \
            SamplingParams(temperature=0.0 if self.greedy else 1.0)
        sreqs = [ServeRequest(prompt=np.asarray(r.prompt, np.int32),
                              max_new_tokens=r.max_new_tokens,
                              rid=i, sampling=sampling)
                 for i, r in enumerate(requests)]
        self.engine.run(sreqs)
        for r, sr in zip(requests, sreqs):
            r.out_tokens = sr.out_tokens
            r.done = sr.done
        t = self.engine.telemetry
        self.stats = {"tokens": t.tokens, "steps": t.steps,
                      "decode_tokens": t.decode_tokens,
                      "decode_s": t.decode_s}
        return requests

    def throughput(self) -> float:
        n = self.stats.get("decode_tokens", self.stats["tokens"])
        return n / self.stats["decode_s"] if self.stats["decode_s"] else 0.0
