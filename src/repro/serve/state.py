"""StateArena: pooled per-lane recurrent decode state.

Attention layers page their KV because it GROWS with the sequence;
recurrent layers (mamba2 conv+SSM state, m/sLSTM cells, zamba's
interleaved mamba groups) carry CONSTANT-size per-sequence state, so
the serving runtime pools it as fixed-size per-lane slots instead: one
device-resident pytree (from `DecoderLM.arena_state_specs`) whose
`BATCH` axis rows are engine lanes.  `serve_step` reads/writes the
whole arena every call, masking out lanes with `n_new == 0`, which is
what lets mixed-length recurrent requests enter and leave the running
batch at any chunk boundary — continuous batching without the old
equal-prompt-length lockstep grouping.

Lane lifecycle (engine-driven):
  admit (fresh)      -> reset_lane(lane): zero the slot
  preempt            -> save_lane(lane):  gather the lane's rows to host
  re-admit (resumed) -> restore_lane(lane, saved): scatter them back

Save -> evict -> restore is bit-identical (property-tested): the slot
holds raw arrays, no re-quantization or recompute, so a preempted
pure-recurrent request resumes mid-generation without re-prefilling a
single token.

The lane axis differs per leaf (layer-stack dims are scanned in front
of batch), so the arena records each leaf's `BATCH`-axis index from its
ParamSpec at construction.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import BATCH, tree_map_specs


class StateArena:
    def __init__(self, model, max_batch: int, specs=None):
        """`specs` takes a precomputed ParamSpec tree (the "arena" half
        of `DecoderLM.decode_state_specs`); defaults to asking the model
        directly."""
        self.max_batch = max_batch
        if specs is None:
            specs = model.arena_state_specs(max_batch)
        self._lane_axis = tree_map_specs(
            lambda sp: sp.axes.index(BATCH), specs)
        self.state: Dict[str, Any] = tree_map_specs(
            lambda sp: jnp.zeros(sp.shape, sp.dtype), specs)
        self.keys = tuple(self.state)

    # -- lane ops -------------------------------------------------------
    def _leaves(self):
        leaves, treedef = jax.tree_util.tree_flatten(self.state)
        axes = treedef.flatten_up_to(self._lane_axis)
        return leaves, treedef, axes

    def reset_lane(self, lane: int) -> None:
        """Zero a lane's slot across every leaf (fresh admission must
        never inherit a dead request's state)."""
        leaves, treedef, axes = self._leaves()
        out = [leaf.at[(slice(None),) * ax + (lane,)].set(0)
               for leaf, ax in zip(leaves, axes)]
        self.state = jax.tree_util.tree_unflatten(treedef, out)

    def save_lane(self, lane: int) -> Any:
        """Gather one lane's rows to host (numpy) for preemption — the
        whole recurrent state of a sequence, a few small tensors."""
        leaves, treedef, axes = self._leaves()
        out = [np.asarray(jnp.take(leaf, lane, axis=ax))
               for leaf, ax in zip(leaves, axes)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_lane(self, lane: int, saved: Any) -> None:
        """Scatter a host snapshot back into a lane's slot."""
        leaves, treedef, axes = self._leaves()
        vals = treedef.flatten_up_to(saved)
        out = [leaf.at[(slice(None),) * ax + (lane,)].set(
                   jnp.asarray(v, leaf.dtype))
               for leaf, ax, v in zip(leaves, axes, vals)]
        self.state = jax.tree_util.tree_unflatten(treedef, out)

    # -- accounting -----------------------------------------------------
    def state_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.state))
