"""Serving telemetry: latency percentiles, throughput, KV occupancy.

Per-request timeline: enqueue -> admit (queue time) -> first token
(TTFT) -> done; TPOT is the mean inter-token gap after the first.
Engine-level gauges (KV occupancy, batch size) are sampled every step.
All clocks are caller-supplied monotonic seconds, so tests can drive
synthetic time.

A decode step is NOT one token: speculative decoding emits a variable
number of tokens per lane per step.  Tokens are therefore counted where
they are emitted (`token`), while `step` separately counts decode-graph
invocations and the lane-steps behind them, so throughput and
tokens-per-step stay honest for any emission width (for the plain
engine `tokens_per_decode_step` is exactly 1.0).  `spec` accumulates
the drafted/accepted ledger behind the acceptance rate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..obs.digest import QuantileDigest


@dataclass
class RequestTrace:
    rid: int
    t_enqueue: float = 0.0
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None
    t_done: Optional[float] = None
    n_tokens: int = 0
    cancelled: bool = False

    @property
    def queue_s(self) -> Optional[float]:
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_enqueue

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_enqueue

    @property
    def tpot_s(self) -> Optional[float]:
        if self.t_done is None or self.t_first_token is None \
                or self.n_tokens < 2:
            return None
        return (self.t_done - self.t_first_token) / (self.n_tokens - 1)


class _Window:
    """Bounded sample window with a cached numpy view.

    Percentile/histogram rollups need the samples as an ndarray; before
    this class every `/metrics` scrape rebuilt that array by scanning
    the retained traces.  Here samples are appended once at the
    lifecycle event that produces them, and the array is materialized
    at most ONCE between appends — a scrape storm against an idle
    server costs one build total.  The cap halves the window when
    exceeded (amortized O(1)), same policy the ITL buffer always had.
    """

    __slots__ = ("_vals", "_cap", "_arr")

    def __init__(self, cap: int):
        self._vals: List[float] = []
        self._cap = cap
        self._arr: Optional[np.ndarray] = None

    def append(self, v: float) -> None:
        self._vals.append(v)
        if len(self._vals) > self._cap:
            del self._vals[:self._cap // 2]
        self._arr = None

    def array(self) -> np.ndarray:
        if self._arr is None:
            self._arr = np.asarray(self._vals, np.float64)
        return self._arr

    def __len__(self) -> int:
        return len(self._vals)

    def __iter__(self):
        return iter(self._vals)

    def mean(self, default: float = float("nan")) -> float:
        return float(self.array().mean()) if self._vals else default

    def peak(self, default: float = float("nan")) -> float:
        return float(self.array().max()) if self._vals else default


# log-spaced latency buckets: 100 us .. 10 s plus an overflow bin — wide
# enough for a jitted CPU smoke run and a loaded TPU server alike
_HIST_EDGES = np.logspace(-4, 1, 11)


def _hist(vals) -> Dict[str, List]:
    """Fixed-bucket histogram of latency seconds: `edges_s` brackets
    every count; the first bucket reaches down to 0 and the last is
    unbounded above, so no sample is ever silently dropped."""
    arr = vals.array() if isinstance(vals, _Window) \
        else np.asarray(vals, np.float64)
    edges = [0.0] + list(_HIST_EDGES) + [float("inf")]
    counts, _ = np.histogram(arr, bins=edges)
    return {"edges_s": [0.0] + [float(e) for e in _HIST_EDGES] + ["inf"],
            "counts": [int(c) for c in counts]}


# retention caps: the gateway turned the engine into a long-running
# server, so per-request traces and per-token gap samples can no longer
# grow with total traffic served.  Percentiles/histograms roll over the
# most recent window; monotonic counters (requests, tokens, ...) are
# kept separately and never pruned.  Offline runs and every test/bench
# config sit far below both caps, so their rollups are exact.
MAX_DONE_TRACES = 4096
MAX_ITL_SAMPLES = 16384


class Telemetry:
    def __init__(self):
        self.traces: Dict[int, RequestTrace] = {}
        self.requests_total = 0
        self._done_order: List[int] = []     # finished eids, oldest first
        self.occupancy_samples = _Window(MAX_ITL_SAMPLES)
        self.state_occupancy_samples = _Window(MAX_ITL_SAMPLES)
        self.decode_family: Optional[str] = None     # labels lane_steps_*
        self.batch_samples = _Window(MAX_ITL_SAMPLES)
        # latency sample windows, appended at the lifecycle event that
        # defines each metric (queue at admit, ttft at first token,
        # tpot at retire) — summary() never scans traces again
        self._ttft = _Window(MAX_DONE_TRACES)
        self._tpot = _Window(MAX_DONE_TRACES)
        self._queue = _Window(MAX_DONE_TRACES)
        # mergeable quantile sketches behind every reported percentile:
        # cumulative (never pruned — bounded by construction), appended
        # at the same lifecycle events as the windows above.  Windows
        # stay for means + fixed-bucket histograms; rank statistics come
        # from the sketches so fleet rollups can MERGE instead of
        # averaging percentiles (obs/digest.py).
        self._digests: Dict[str, QuantileDigest] = {
            "ttft_s": QuantileDigest(), "tpot_s": QuantileDigest(),
            "itl_s": QuantileDigest(), "queue_s": QuantileDigest(),
        }
        # bumped on every digest append so publishers (the replica tap)
        # can skip re-serializing an unchanged sketch, like the prefix
        # fingerprint's version gate
        self.digest_version = 0
        self.decode_s = 0.0
        self.prefill_s = 0.0
        self.steps = 0
        self.decode_steps = 0        # decode-graph invocations
        self.decode_lane_steps = 0   # active lanes summed over decode steps
        self.tokens = 0
        self.decode_tokens = 0       # emitted by the decode graph
        self.prefill_tokens = 0
        self.spec_drafted = 0        # draft tokens sent to verification
        self.spec_accepted = 0       # draft tokens the target accepted
        self.prefix_lookups = 0      # admissions probing the prefix cache
        self.prefix_hits = 0         # admissions that adopted >= 1 page
        self.prefill_tokens_skipped = 0   # prompt tokens never prefilled
        self.fork_admissions = 0     # lanes admitted via PagedKVCache.fork
        self.cancelled = 0           # requests aborted before completion
        self.itl_samples = _Window(MAX_ITL_SAMPLES)  # emitted-token gaps
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None

    # -- request lifecycle ---------------------------------------------
    def enqueue(self, rid: int, now: float):
        self.traces[rid] = RequestTrace(rid=rid, t_enqueue=now)
        self.requests_total += 1
        if self.t_start is None:
            self.t_start = now

    def _retire(self, rid: int):
        """Bound trace retention: finished traces past the window are
        dropped oldest-first (live traces are never touched).  The
        closing trace's TPOT lands in its sample window here — `done`
        and `cancel` both retire, so cancelled requests keep
        contributing their measured inter-token pace, as the
        trace-scanning rollup always had them."""
        tr = self.traces.get(rid)
        if tr is not None and tr.tpot_s is not None:
            self._tpot.append(tr.tpot_s)
            self._digests["tpot_s"].add(tr.tpot_s)
            self.digest_version += 1
        self._done_order.append(rid)
        while len(self._done_order) > MAX_DONE_TRACES:
            self.traces.pop(self._done_order.pop(0), None)

    def admit(self, rid: int, now: float):
        tr = self.traces[rid]
        tr.t_admit = now
        self._queue.append(tr.queue_s)
        self._digests["queue_s"].add(tr.queue_s)
        self.digest_version += 1

    def token(self, rid: int, now: float, decode: bool = True):
        """decode=False marks a token emitted by the prefill graph (each
        request's first), kept out of the decode-rate denominator."""
        tr = self.traces[rid]
        if tr.t_first_token is None:
            tr.t_first_token = now
            self._ttft.append(tr.ttft_s)
            self._digests["ttft_s"].add(tr.ttft_s)
            self.digest_version += 1
        elif tr.t_last_token is not None:
            # measured gap between consecutive emissions of one request
            # (the streaming client's experience, unlike tpot's
            # first-to-done mean)
            gap = max(now - tr.t_last_token, 0.0)
            self.itl_samples.append(gap)
            self._digests["itl_s"].add(gap)
            self.digest_version += 1
        tr.t_last_token = now
        tr.n_tokens += 1
        self.tokens += 1
        if decode:
            self.decode_tokens += 1
        self.t_end = now

    def done(self, rid: int, now: float):
        self.traces[rid].t_done = now
        self.t_end = now
        self._retire(rid)

    def forget(self, rid: int):
        """Request handed off to another engine before running here
        (fleet drain/requeue): drop its trace AND its requests_total
        count — it is re-enqueued (and counted) on the replica that
        actually serves it, so leaving it here would double-count every
        fleet-level rollup.  Only legal for a request that never
        admitted; a trace with progress must close via done/cancel."""
        tr = self.traces.get(rid)
        if tr is not None and tr.t_admit is None and tr.t_done is None:
            del self.traces[rid]
            self.requests_total -= 1

    def cancel(self, rid: int, now: float):
        """Request aborted (client disconnect / explicit cancel): the
        trace closes so percentile rollups stay well-defined, and the
        request is counted separately from clean completions."""
        tr = self.traces[rid]
        tr.t_done = now
        tr.cancelled = True
        self.cancelled += 1
        self.t_end = now
        self._retire(rid)

    # -- engine gauges --------------------------------------------------
    def step(self, occupancy: float, batch: int, decode_s: float = 0.0,
             prefill_s: float = 0.0, decode_lanes: int = 0,
             state_occupancy: Optional[float] = None,
             family: Optional[str] = None):
        """`decode_lanes`: lanes the decode graph advanced this step (0
        on prefill-only steps) — the denominator of tokens-per-step,
        which `token` alone cannot provide once steps emit more than one
        token.  `state_occupancy` is the StateArena lane-slot fill
        (None when the model has no recurrent state); `family` labels
        the `lane_steps_<family>` rollup (one engine serves one model,
        so this is a label, not a second counter)."""
        self.occupancy_samples.append(occupancy)
        if state_occupancy is not None:
            self.state_occupancy_samples.append(state_occupancy)
        self.batch_samples.append(batch)
        self.decode_s += decode_s
        self.prefill_s += prefill_s
        self.steps += 1
        if decode_lanes:
            self.decode_steps += 1
            self.decode_lane_steps += decode_lanes
            if family is not None:
                self.decode_family = family

    def spec(self, drafted: int, accepted: int):
        """One verify step's ledger: `drafted` tokens proposed across
        the batch, `accepted` of them kept by the target."""
        self.spec_drafted += drafted
        self.spec_accepted += accepted

    def prefix(self, cached_tokens: int):
        """One admission's prefix-cache outcome: `cached_tokens` prompt
        tokens were adopted from resident pages (0 = miss)."""
        self.prefix_lookups += 1
        if cached_tokens > 0:
            self.prefix_hits += 1
            self.prefill_tokens_skipped += cached_tokens

    def fork(self, cached_tokens: int):
        """One admission served by `PagedKVCache.fork` (parallel
        sampling): `cached_tokens` prompt tokens were adopted from the
        parent lane instead of prefilled.  Kept out of the prefix-cache
        hit rate — the trie was never probed."""
        self.fork_admissions += 1
        self.prefill_tokens_skipped += cached_tokens

    # -- cheap gauge view ----------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """O(1) counter view for per-dispatch polling: no percentile
        math, no trace scans, no numpy — a fleet router reads this (via
        the driver's step tap) on every routing decision, where
        `summary()` would be orders of magnitude too heavy."""
        return {
            "requests_total": float(self.requests_total),
            "tokens": float(self.tokens),
            "decode_tokens": float(self.decode_tokens),
            "prefill_tokens": float(self.prefill_tokens),
            "prefix_lookups": float(self.prefix_lookups),
            "prefix_hits": float(self.prefix_hits),
            "prefill_tokens_skipped": float(self.prefill_tokens_skipped),
            "fork_admissions": float(self.fork_admissions),
            "cancelled": float(self.cancelled),
            "decode_s": float(self.decode_s),
        }

    # -- rollup ---------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        # latency percentiles come from the cumulative sketches; a
        # metric with no samples yet is ABSENT from the rollup (not
        # NaN) — exporters render nothing, fleet merges skip it, and
        # check_bench never diffs a number that does not exist
        ttft = self._ttft
        pct: Dict[str, float] = {}
        for name, dig in self._digests.items():
            if dig.count == 0:
                continue
            for p in (50, 95, 99):
                pct[f"{name[:-2]}_p{p}_s"] = dig.quantile(p)
        wall = ((self.t_end - self.t_start)
                if self.t_start is not None and self.t_end is not None
                and self.t_end > self.t_start else 0.0)
        return {
            "requests": float(self.requests_total),
            "tokens": float(self.tokens),
            "prefill_tokens": float(self.prefill_tokens),
            "steps": float(self.steps),
            "decode_steps": float(self.decode_steps),
            "tokens_per_s": self.tokens / wall if wall else float("nan"),
            "decode_tokens_per_s": (self.decode_tokens / self.decode_s
                                    if self.decode_s else float("nan")),
            "tokens_per_decode_step": (
                self.decode_tokens / self.decode_lane_steps
                if self.decode_lane_steps else float("nan")),
            "spec_drafted": float(self.spec_drafted),
            "spec_accepted": float(self.spec_accepted),
            "spec_acceptance_rate": (self.spec_accepted / self.spec_drafted
                                     if self.spec_drafted else float("nan")),
            "prefix_lookups": float(self.prefix_lookups),
            "prefix_hits": float(self.prefix_hits),
            "prefix_hit_rate": (self.prefix_hits / self.prefix_lookups
                                if self.prefix_lookups else float("nan")),
            "prefill_tokens_skipped": float(self.prefill_tokens_skipped),
            "fork_admissions": float(self.fork_admissions),
            "cancelled": float(self.cancelled),
            "ttft_mean_s": ttft.mean(),
            **pct,
            "kv_occupancy_mean": self.occupancy_samples.mean(0.0),
            "kv_occupancy_peak": self.occupancy_samples.peak(0.0),
            "state_slot_occupancy_mean":
                self.state_occupancy_samples.mean(),
            "state_slot_occupancy_peak":
                self.state_occupancy_samples.peak(),
            "batch_mean": self.batch_samples.mean(0.0),
            **({f"lane_steps_{self.decode_family}":
                float(self.decode_lane_steps)}
               if self.decode_family is not None else {}),
        }

    def digests(self) -> Dict[str, Dict]:
        """Serialized quantile sketches keyed by metric — the mergeable
        form of every percentile in `summary()`.  The replica tap
        publishes these (version-gated on `digest_version`); the fleet
        router merges them for mathematically correct fleet p95/p99."""
        return {name: dig.to_dict()
                for name, dig in self._digests.items()}

    def histograms(self) -> Dict[str, Dict[str, List]]:
        """Latency distributions as fixed log-spaced buckets (the
        gateway `/metrics` payload: percentiles compress, histograms
        compose across scrapes).  Fed by the same incrementally
        maintained windows as `summary()` — no trace scan."""
        return {"ttft_s": _hist(self._ttft), "queue_s": _hist(self._queue),
                "itl_s": _hist(self.itl_samples)}
