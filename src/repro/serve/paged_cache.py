"""Paged KV cache: free-list block allocator + per-request block tables.

The device side is a pool of `n_pages` fixed-size pages per layer
(allocated once, shape-stable for jit); the host side is this allocator
handing page ids to requests as they grow.  Memory is sized to the
WORKLOAD (total tokens in flight), not to worst-case
`n_slots * max_seq` — the dense cache's waste is exactly what EdgeCIM
identifies as the edge bottleneck.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


class OutOfPagesError(RuntimeError):
    pass


class BlockAllocator:
    """Free-list allocator over `n_pages` page ids with owner tracking.

    Invariants (property-tested in tests/test_paged_cache.py):
      * a page is never handed out twice without an intervening free
      * free(owner) returns exactly the pages that owner held
      * n_free + sum(held) == n_pages at all times
    """

    def __init__(self, n_pages: int):
        assert n_pages > 0
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._held: Dict[int, List[int]] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def n_held(self, owner: int) -> int:
        return len(self._held.get(owner, ()))

    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_pages

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, owner: int, n: int = 1) -> List[int]:
        if len(self._free) < n:
            raise OutOfPagesError(
                f"need {n} pages, {len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        self._held.setdefault(owner, []).extend(pages)
        return pages

    def free(self, owner: int) -> List[int]:
        pages = self._held.pop(owner, [])
        self._free.extend(pages)
        return pages

    def free_pages(self, owner: int, pages: List[int]) -> None:
        """Return specific pages from `owner`'s holding (speculative
        rollback frees the TAIL of a block table, not the whole
        sequence).  Freeing a page the owner does not hold is an error —
        it would double-free."""
        held = self._held.get(owner, [])
        for p in pages:
            held.remove(p)      # ValueError on double-free, by design
        if not held:
            self._held.pop(owner, None)
        self._free.extend(pages)


@dataclass
class SequenceState:
    """Host-side view of one request's cache residency."""
    rid: int
    pages: List[int] = field(default_factory=list)
    length: int = 0                     # tokens materialized in the pool

    def capacity(self, page_size: int) -> int:
        return len(self.pages) * page_size


class PagedKVCache:
    """Device pools + block tables for a dynamic batch.

    `pools` is the model's paged cache pytree (per-layer page pools);
    `table_for` assembles the padded (max_pages,) block-table row a lane
    feeds to `DecoderLM.paged_step`.  Page 0 pads unused table entries —
    padded slots are masked by length, never read into scores.
    """

    def __init__(self, model, n_pages: int, page_size: int, max_seq: int,
                 kv_dtype=jnp.bfloat16):
        assert max_seq % page_size == 0
        self.page_size = page_size
        self.max_pages = max_seq // page_size
        self.allocator = BlockAllocator(n_pages)
        self.seqs: Dict[int, SequenceState] = {}
        specs = model.paged_cache_specs(n_pages, page_size, kv_dtype)
        from repro.models.common import spec_structs
        self.pools = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec_structs(specs))

    # -- residency ------------------------------------------------------
    def admit(self, rid: int, prompt_len: int) -> SequenceState:
        need = -(-max(prompt_len, 1) // self.page_size)
        seq = SequenceState(rid=rid, pages=self.allocator.alloc(rid, need))
        self.seqs[rid] = seq
        return seq

    def pages_needed(self, prompt_len: int) -> int:
        return -(-max(prompt_len, 1) // self.page_size)

    def ensure_room(self, rid: int, extra_tokens: int = 1) -> bool:
        """Grow the request's page list to fit `extra_tokens` more; False
        if the pool is exhausted (caller may preempt/queue)."""
        seq = self.seqs[rid]
        need_total = seq.length + extra_tokens
        if need_total > self.max_pages * self.page_size:
            return False
        while seq.capacity(self.page_size) < need_total:
            if not self.allocator.can_alloc(1):
                return False
            seq.pages.extend(self.allocator.alloc(rid, 1))
        return True

    def release(self, rid: int) -> None:
        self.allocator.free(rid)
        self.seqs.pop(rid, None)

    def trim(self, rid: int, new_length: int) -> int:
        """Roll back to `new_length` tokens (speculative reject): drop
        block-table entries past the last live page and free them.
        Stale rows beyond `new_length` inside kept pages are never read
        (every consumer masks by length) and are overwritten in place by
        the next append.  Returns the number of pages freed."""
        seq = self.seqs[rid]
        assert 0 <= new_length <= seq.length, (new_length, seq.length)
        seq.length = new_length
        keep = -(-max(new_length, 1) // self.page_size)
        drop = seq.pages[keep:]
        if drop:
            seq.pages = seq.pages[:keep]
            self.allocator.free_pages(rid, drop)
        return len(drop)

    # -- device-facing views -------------------------------------------
    def table_for(self, rid: int) -> np.ndarray:
        seq = self.seqs[rid]
        row = np.zeros(self.max_pages, np.int32)
        row[:len(seq.pages)] = seq.pages
        return row

    def occupancy(self) -> float:
        return self.allocator.occupancy()

    def kv_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.pools))
