"""Paged KV cache: refcounted block allocator + per-request block tables.

The device side is a pool of `n_pages` fixed-size pages per layer
(allocated once, shape-stable for jit); the host side is this allocator
handing page ids to requests as they grow.  Memory is sized to the
WORKLOAD (total tokens in flight), not to worst-case
`n_slots * max_seq` — the dense cache's waste is exactly what EdgeCIM
identifies as the edge bottleneck.

Pages are REFCOUNTED so sequences can share them: prefix caching
(serve/prefix.py) pins full prompt pages in a radix trie, and
`PagedKVCache.fork` lets a new sequence adopt another's prefix.  A
sequence about to WRITE into a page with refcount > 1 first copies it
and patches its own block table (copy-on-write) — the Pallas
`paged_flash_decode` / `paged_flash_verify` kernels read through block
tables and need no changes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, donate_argnums=(0,))
def _copy_pool_pages(pools, src: jax.Array, dst: jax.Array):
    """Batched KV page copy (rows of pages `src` -> pages `dst`).

    Jitted with the pool donated so XLA scatters in place; an eager
    `.at[].set()` would instead materialize a full copy of every
    (n_layers, n_pages, page_size, ...) leaf per copied page.  Page is
    axis 1 on every leaf."""
    return jax.tree_util.tree_map(
        lambda leaf: leaf.at[:, dst].set(leaf[:, src]), pools)


class OutOfPagesError(RuntimeError):
    pass


class BlockAllocator:
    """Free-list allocator over `n_pages` page ids with owner tracking
    and per-page refcounts.

    Invariants (property-tested in tests/test_paged_cache.py and
    tests/test_prefix_cache.py):
      * a free page is never handed out twice without reaching
        refcount 0 in between
      * every allocated page has refcount == number of owner-ledger
        entries naming it, and refcounts are never negative
      * n_free + (unique allocated pages) == n_pages at all times
    `free`/`free_pages` DECREF and only collect pages that hit
    refcount 0; `share` increfs an allocated page into another owner's
    ledger.  Freeing under an unknown owner, or a page the owner does
    not hold, raises — a silent no-op there would mask double-frees.
    """

    def __init__(self, n_pages: int):
        assert n_pages > 0
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        self._held: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}      # page -> refcount (absent: free)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def n_held(self, owner: int) -> int:
        return len(self._held.get(owner, ()))

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_pages

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, owner: int, n: int = 1) -> List[int]:
        if len(self._free) < n:
            raise OutOfPagesError(
                f"need {n} pages, {len(self._free)} free of {self.n_pages}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._held.setdefault(owner, []).extend(pages)
        return pages

    def share(self, owner: int, pages: Iterable[int]) -> None:
        """Incref `pages` (which must be allocated) into `owner`'s
        ledger: the owner now holds them like its own, and `free`/
        `free_pages` decref symmetrically.  Sharing a free page raises
        (a hard error, not an assert: silently reviving a free page
        would hand it out twice)."""
        pages = list(pages)
        for p in pages:
            if self._ref.get(p, 0) <= 0:
                raise ValueError(f"share of free page {p}")
            self._ref[p] += 1
        if pages:
            self._held.setdefault(owner, []).extend(pages)

    def _decref(self, page: int, collected: List[int]) -> None:
        r = self._ref[page] - 1
        if r < 0:
            raise RuntimeError(f"refcount underflow on page {page}")
        if r == 0:
            del self._ref[page]
            self._free.append(page)
            collected.append(page)
        else:
            self._ref[page] = r

    def free(self, owner: int) -> List[int]:
        """Decref every page `owner` holds; returns the pages that hit
        refcount 0 (actually reclaimed).  Unknown owner raises."""
        if owner not in self._held:
            raise KeyError(f"free of unknown owner {owner}")
        collected: List[int] = []
        for p in self._held.pop(owner):
            self._decref(p, collected)
        return collected

    def free_pages(self, owner: int, pages: List[int]) -> List[int]:
        """Decref specific pages from `owner`'s holding (speculative
        rollback frees the TAIL of a block table, not the whole
        sequence).  Freeing a page the owner does not hold raises —
        it would double-free.  Returns the pages reclaimed."""
        if owner not in self._held:
            raise KeyError(f"free_pages of unknown owner {owner}")
        held = self._held[owner]
        collected: List[int] = []
        for p in pages:
            held.remove(p)      # ValueError on double-free, by design
            self._decref(p, collected)
        if not held:
            self._held.pop(owner, None)
        return collected


@dataclass
class SequenceState:
    """Host-side view of one request's cache residency."""
    rid: int
    pages: List[int] = field(default_factory=list)
    length: int = 0                     # tokens materialized in the pool

    def capacity(self, page_size: int) -> int:
        return len(self.pages) * page_size


class PagedKVCache:
    """Device pools + block tables for a dynamic batch.

    `pools` is the model's paged cache pytree (per-layer page pools);
    `table_for` assembles the padded (max_pages,) block-table row a lane
    feeds to `DecoderLM.paged_step`.  Page 0 pads unused table entries —
    padded slots are masked by length, never read into scores.

    When `prefix_index` is attached (serve/prefix.py), admission can
    adopt trie-resident prompt pages (`seq.length` starts past them) and
    allocation pressure reclaims refcount-1 trie pages LRU-first before
    giving up.  Writes go through `prepare_write`, which copy-on-writes
    any shared page in the write range.

    For families with no attention layers at all (xlstm, pure-mamba
    zamba) `pools` is {} and the cache degenerates to a host-side token
    budget: pages still gate admission/growth/preemption, so the
    scheduler and engine stay family-agnostic while the actual decode
    state lives in the per-lane StateArena (serve/state.py).
    """

    def __init__(self, model, n_pages: int, page_size: int, max_seq: int,
                 kv_dtype=jnp.bfloat16, specs=None):
        """`specs` takes a precomputed pool ParamSpec tree (the "paged"
        half of `DecoderLM.decode_state_specs`); defaults to asking the
        model directly."""
        assert max_seq % page_size == 0
        self.page_size = page_size
        self.max_pages = max_seq // page_size
        self.allocator = BlockAllocator(n_pages)
        self.seqs: Dict[int, SequenceState] = {}
        self.prefix_index = None            # set by the engine (optional)
        self.cow_copies = 0                 # pages copied on write
        self.pages_shared = 0               # pages adopted via share/fork
        if specs is None:
            specs = model.paged_cache_specs(n_pages, page_size, kv_dtype)
        from repro.models.common import spec_structs
        self.pools = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec_structs(specs))

    # -- residency ------------------------------------------------------
    def _reclaim(self, n: int) -> bool:
        """True once `n` pages are free, evicting refcount-1 prefix-trie
        pages (LRU) to get there if an index is attached."""
        if self.allocator.can_alloc(n):
            return True
        if self.prefix_index is not None:
            self.prefix_index.evict(n - self.allocator.n_free)
        return self.allocator.can_alloc(n)

    def probe_admit(self, prompt_len: int, prompt=None):
        """Admission probe: fresh prompt pages + 1 growth page must be
        free or reclaimable (matched trie pages are excluded from the
        reclaimable count — admission would pin, not evict, them).
        Probes never touch LRU stamps: only an actual admission
        refreshes a prefix's recency.  Returns the matched trie node
        path (possibly empty) to pass into `admit` — the prompt is
        walked once per admission, not once for the probe and again for
        the adoption — or None when the request cannot fit now."""
        nodes = []
        if self.prefix_index is not None and prompt is not None:
            nodes = self.prefix_index.match_nodes(
                np.asarray(prompt, np.int32))
        fresh = self.pages_needed(prompt_len) - len(nodes) + 1
        need = fresh - self.allocator.n_free
        if need <= 0:       # free pages suffice: skip the trie walk (the
            return nodes    # common case on the per-step scheduler path)
        if self.prefix_index is None:
            return None
        shared = {n.page for n in nodes}
        if self.prefix_index.n_evictable(exclude=shared,
                                         limit=need) < need:
            return None
        return nodes

    def can_admit(self, prompt_len: int, prompt=None) -> bool:
        return self.probe_admit(prompt_len, prompt) is not None

    def admit(self, rid: int, prompt_len: int, prompt=None,
              match=None) -> SequenceState:
        """Allocate residency for a prompt.  With a prefix index and the
        prompt's tokens, trie-matched full pages are ADOPTED (shared,
        refcount+1) and `seq.length` starts at the matched token count —
        the caller prefills only the tail.  `match` takes a node path
        from `probe_admit` to reuse instead of re-walking the trie.
        Raises OutOfPagesError when the fresh remainder cannot be
        allocated even after eviction."""
        if match is None:
            match = []
            if self.prefix_index is not None and prompt is not None:
                match = self.prefix_index.match_nodes(
                    np.asarray(prompt, np.int32))
        shared = [n.page for n in match]
        cached = len(shared) * self.page_size
        # pin matched pages under this owner BEFORE any eviction runs:
        # a just-matched page must never be reclaimed out from under us
        self.allocator.share(rid, shared)
        fresh = self.pages_needed(prompt_len) - len(shared)
        if not self._reclaim(fresh):
            if shared:
                self.allocator.free(rid)
            raise OutOfPagesError(
                f"need {fresh} pages, {self.allocator.n_free} free of "
                f"{self.allocator.n_pages}")
        pages = shared + self.allocator.alloc(rid, fresh)
        if match:
            self.prefix_index.touch(match)
        seq = SequenceState(rid=rid, pages=pages, length=cached)
        self.pages_shared += len(shared)
        self.seqs[rid] = seq
        return seq

    def fork(self, new_rid: int, src_rid: int, prefix_len: int
             ) -> SequenceState:
        """New sequence sharing `src_rid`'s first `prefix_len` tokens
        (beam/parallel sampling from one prompt).  Shared pages are
        adopted by refcount; a later write into a partially-shared tail
        page triggers copy-on-write via `prepare_write`."""
        src = self.seqs[src_rid]
        assert 0 <= prefix_len <= src.length, (prefix_len, src.length)
        assert new_rid not in self.seqs, new_rid
        n_shared = -(-prefix_len // self.page_size)
        shared = src.pages[:n_shared]
        self.allocator.share(new_rid, shared)
        pages = list(shared)
        if not pages:               # every sequence holds >= 1 page, the
            if not self._reclaim(1):  # same floor admit() guarantees
                raise OutOfPagesError("fork: no page for empty prefix")
            pages = self.allocator.alloc(new_rid, 1)
        seq = SequenceState(rid=new_rid, pages=pages,
                            length=prefix_len)
        self.pages_shared += len(shared)
        self.seqs[new_rid] = seq
        return seq

    def pages_needed(self, prompt_len: int) -> int:
        return -(-max(prompt_len, 1) // self.page_size)

    def ensure_room(self, rid: int, extra_tokens: int = 1) -> bool:
        """Grow the request's page list to fit `extra_tokens` more; False
        if the pool is exhausted even after prefix-index eviction (caller
        may preempt/queue)."""
        seq = self.seqs[rid]
        need_total = seq.length + extra_tokens
        if need_total > self.max_pages * self.page_size:
            return False
        while seq.capacity(self.page_size) < need_total:
            if not self._reclaim(1):
                return False
            seq.pages.extend(self.allocator.alloc(rid, 1))
        return True

    # -- copy-on-write --------------------------------------------------
    def cow_for_write(self, rid: int, n_tokens: int) -> bool:
        """Copy-on-write every shared page the next `n_tokens`-token
        append will touch: copy their rows to fresh pages in ONE device
        call, patch this sequence's block table, decref the originals.
        False if the copy targets cannot be allocated (pool
        exhausted)."""
        seq = self.seqs[rid]
        if n_tokens <= 0:
            return True
        first = seq.length // self.page_size
        last = (seq.length + n_tokens - 1) // self.page_size
        idxs = [i for i in range(first, min(last + 1, len(seq.pages)))
                if self.allocator.refcount(seq.pages[i]) > 1]
        if not idxs:
            return True
        if not self._reclaim(len(idxs)):
            return False
        fresh = self.allocator.alloc(rid, len(idxs))
        olds = [seq.pages[i] for i in idxs]
        self.pools = _copy_pool_pages(self.pools,
                                      jnp.asarray(olds, jnp.int32),
                                      jnp.asarray(fresh, jnp.int32))
        for i, new in zip(idxs, fresh):
            seq.pages[i] = new
        self.allocator.free_pages(rid, olds)    # decref, never collects
        self.cow_copies += len(idxs)
        return True

    def prepare_write(self, rid: int, n_tokens: int) -> bool:
        """Make the next `n_tokens`-token append safe: capacity grown
        (`ensure_room`) and shared pages in the write range copied
        (`cow_for_write`).  False on pool exhaustion."""
        return (self.ensure_room(rid, n_tokens)
                and self.cow_for_write(rid, n_tokens))

    def release(self, rid: int) -> None:
        self.allocator.free(rid)
        self.seqs.pop(rid, None)

    def trim(self, rid: int, new_length: int) -> int:
        """Roll back to `new_length` tokens (speculative reject): drop
        block-table entries past the last live page and decref them —
        a page the prefix trie (or a fork) still references survives
        with its rows intact.  Stale rows beyond `new_length` inside
        kept pages are never read (every consumer masks by length) and
        are overwritten in place by the next append.  Returns the number
        of table entries dropped."""
        seq = self.seqs[rid]
        assert 0 <= new_length <= seq.length, (new_length, seq.length)
        seq.length = new_length
        keep = -(-max(new_length, 1) // self.page_size)
        drop = seq.pages[keep:]
        if drop:
            seq.pages = seq.pages[:keep]
            self.allocator.free_pages(rid, drop)
        return len(drop)

    # -- device-facing views -------------------------------------------
    def table_for(self, rid: int) -> np.ndarray:
        seq = self.seqs[rid]
        row = np.zeros(self.max_pages, np.int32)
        row[:len(seq.pages)] = seq.pages
        return row

    def occupancy(self) -> float:
        return self.allocator.occupancy()

    def n_free_or_cached(self) -> int:
        """Pages free or held ONLY by the prefix index (reclaimable on
        demand) — the drain invariant tests check against n_pages."""
        n = self.allocator.n_free
        if self.prefix_index is not None:
            n += self.prefix_index.n_evictable()
        return n

    def kv_bytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.pools))
