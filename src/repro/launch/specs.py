"""Dry-run cell construction: ShapeDtypeStruct inputs, step functions,
and shardings for every (architecture x input-shape x mesh x precision).

No allocation happens here: params, optimizer state, KV caches, and
batches are all ShapeDtypeStructs (a 235B-param cell lowers on a laptop).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import SHAPES, get_config, supports_long
from repro.dist.axes import (MeshRules, MULTI_POD_RULES, SINGLE_POD_RULES,
                             rules_for_mesh)
from repro.dist.shard import (qtree_shardings, tree_shardings,
                              use_mesh_rules)
from repro.models import DecoderLM
from repro.models.common import ParamSpec, spec_structs, tree_map_specs
from repro.quant.ptq import quantize_structs
from repro.train.adamw import AdamW, AdamWState, cosine_schedule

# long-context rules: batch=1 cannot shard -> KV sequence spreads over
# both mesh axes (split-K over the whole pod)
LONG_SINGLE_RULES = MeshRules({
    "batch": None, "fsdp": "data", "tp": "model", "expert": "model",
    "kv_seq": ("model", "data"), "seq": None, "layers": None,
})
LONG_MULTI_RULES = MeshRules({
    "batch": None, "fsdp": "data", "tp": "model", "expert": "model",
    "kv_seq": ("pod", "model", "data"), "seq": None, "layers": None,
})

# serve-mode rules (§Perf iteration): decode is read-only over weights, so
# FSDP sharding only adds a per-step all-gather; weights shard over the
# model axis and replicate over data (batch) — the classic train-vs-serve
# sharding split.  KV stays split-K over `model`.
SERVE_SINGLE_RULES = MeshRules({
    "batch": ("data",), "fsdp": None, "tp": "model", "expert": "model",
    "kv_seq": "model", "seq": "data", "layers": None,
})
SERVE_MULTI_RULES = MeshRules({
    "batch": ("pod", "data"), "fsdp": None, "tp": "model",
    "expert": "model", "kv_seq": "model", "seq": "data", "layers": None,
})
SERVE_LONG_SINGLE_RULES = MeshRules({
    "batch": None, "fsdp": None, "tp": "model", "expert": "model",
    "kv_seq": ("model", "data"), "seq": None, "layers": None,
})
SERVE_LONG_MULTI_RULES = MeshRules({
    "batch": None, "fsdp": None, "tp": "model", "expert": "model",
    "kv_seq": ("pod", "model", "data"), "seq": None, "layers": None,
})

# reduced shapes for the subprocess integration tests (same code path,
# tiny dims, 8 host devices)
SMOKE_SHAPES = {
    "train_4k": (64, 8, "train"),
    "prefill_32k": (64, 4, "prefill"),
    "decode_32k": (64, 8, "decode"),
    "long_500k": (128, 1, "decode"),
}


def rules_for(mesh: Mesh, shape_id: str,
              serve_sharding: bool = False) -> MeshRules:
    multi = "pod" in mesh.axis_names
    if serve_sharding:
        if shape_id == "long_500k":
            return SERVE_LONG_MULTI_RULES if multi else                 SERVE_LONG_SINGLE_RULES
        return SERVE_MULTI_RULES if multi else SERVE_SINGLE_RULES
    if shape_id == "long_500k":
        return LONG_MULTI_RULES if multi else LONG_SINGLE_RULES
    return MULTI_POD_RULES if multi else SINGLE_POD_RULES


# ----------------------------------------------------------------------------
# model input structs
# ----------------------------------------------------------------------------
def input_specs(arch_id: str, shape_id: str, multi_pod: bool = False,
                shapes: Optional[dict] = None, cfg: Optional[Any] = None
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = cfg if cfg is not None else get_config(arch_id)
    seq, batch, kind = (shapes or SHAPES)[shape_id]
    s = 1 if kind == "decode" else seq
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embed_inputs:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
    else:
        specs["embeddings"] = jax.ShapeDtypeStruct((batch, s, cfg.d_model),
                                                   jnp.bfloat16)
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
    return specs


def input_shardings(arch_id: str, shape_id: str, mesh: Mesh,
                    rules: MeshRules) -> Dict[str, NamedSharding]:
    cfg = get_config(arch_id)
    _, _, kind = SHAPES[shape_id]
    b = rules.get("batch")
    out: Dict[str, NamedSharding] = {}
    if cfg.embed_inputs:
        out["tokens"] = NamedSharding(mesh, P(b, None))
    else:
        out["embeddings"] = NamedSharding(mesh, P(b, None, None))
    if kind == "train":
        out["labels"] = NamedSharding(mesh, P(b, None))
    return out


# ----------------------------------------------------------------------------
# cell = (step fn, arg structs, shardings)
# ----------------------------------------------------------------------------
@dataclass
class Cell:
    arch: str
    shape_id: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate: Tuple[int, ...]
    model: DecoderLM


def _opt_structs(specs):
    mu = tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), specs)
    nu = tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), specs)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu, nu=nu)


def _opt_shardings(specs, mesh, rules):
    sh = tree_shardings(specs, mesh, rules)
    return AdamWState(step=NamedSharding(mesh, P()),
                      mu=sh, nu=jax.tree_util.tree_map(lambda x: x, sh))


def build_cell(arch_id: str, shape_id: str, mesh: Mesh,
               quant: str = "bf16", cfg: Optional[Any] = None,
               shapes: Optional[dict] = None,
               serve_sharding: bool = False) -> Cell:
    """quant: bf16 | int8 | int4 (decode shapes only — the paper's serve
    precision axis).  `cfg` overrides the registry config (probes pass
    reduced-layer unrolled variants); `shapes` overrides SHAPES (smoke);
    `serve_sharding` uses the no-FSDP decode rules (§Perf)."""
    cfg = cfg if cfg is not None else get_config(arch_id)
    seq, batch, kind = (shapes or SHAPES)[shape_id]
    rules = rules_for(mesh, shape_id, serve_sharding)
    model = DecoderLM(cfg)
    specs = model.param_specs()
    param_sh = tree_shardings(specs, mesh, rules)
    inp = input_specs(arch_id, shape_id, "pod" in mesh.axis_names,
                      shapes=shapes, cfg=cfg)
    inp_sh = input_shardings(arch_id, shape_id, mesh, rules)

    if kind == "train":
        params = spec_structs(specs)
        opt = AdamW(lr=cosine_schedule(3e-4, 100, 10000))
        opt_state = _opt_structs(specs)
        opt_sh = _opt_shardings(specs, mesh, rules)

        def train_step(p, s, batch_):
            with use_mesh_rules(mesh, rules):
                loss, grads = jax.value_and_grad(model.loss)(p, batch_)
                p2, s2 = opt.update(grads, s, p)
                return p2, s2, loss

        return Cell(arch_id, shape_id, kind, train_step,
                    (params, opt_state, inp),
                    (param_sh, opt_sh, inp_sh), donate=(0, 1), model=model)

    if kind == "prefill":
        params = spec_structs(specs)

        if cfg.family in ("dense", "moe"):
            def prefill_step(p, batch_):
                with use_mesh_rules(mesh, rules):
                    return model.prefill(p, batch_)
        else:
            def prefill_step(p, batch_):
                with use_mesh_rules(mesh, rules):
                    return model.forward(p, batch_)[:, -1:, :]

        return Cell(arch_id, shape_id, kind, prefill_step, (params, inp),
                    (param_sh, inp_sh), donate=(), model=model)

    # ---- decode -----------------------------------------------------------
    if quant in ("int4", "int8"):
        bits = 4 if quant == "int4" else 8
        params = quantize_structs(specs, bits=bits, group=128)
        param_sh = qtree_shardings(specs, params, mesh, rules)
    else:
        params = spec_structs(specs)
    cache_specs = model.cache_specs(batch, seq)
    cache = spec_structs(cache_specs)
    cache_sh = tree_shardings(cache_specs, mesh, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(p, c, batch_, pos_):
        with use_mesh_rules(mesh, rules):
            return model.decode_step(p, c, batch_, pos_)

    return Cell(arch_id, shape_id, kind, serve_step,
                (params, cache, inp, pos),
                (param_sh, cache_sh, inp_sh, NamedSharding(mesh, P())),
                donate=(1,), model=model)
