"""Serving launcher (CLI driver for the e2e serve story).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --quant int4 --requests 8 --tokens 32
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="int4",
                    choices=["bf16", "int8", "int4"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_smoke_config
    from repro.models import DecoderLM, init_params
    from repro.quant import quantize_params, quantized_fraction
    from repro.serve import Request, ServeEngine

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(dtype="float32", remat=False)
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} takes frontend-stub embeddings; the "
                         "token engine serves token-input archs")
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)
    if args.quant != "bf16":
        params = quantize_params(params, bits=4 if args.quant == "int4"
                                 else 8, group=16 if args.smoke else 128)
        print(f"[serve] {quantized_fraction(params)*100:.0f}% of param "
              f"bytes quantized ({args.quant})")
    eng = ServeEngine(model, params, n_slots=args.slots,
                      max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=args.tokens, rid=i)
            for i in range(args.requests)]
    done = eng.run(reqs)
    print(f"[serve] {sum(len(r.out_tokens) for r in done)} tokens, "
          f"{eng.throughput():.0f} tok/s decode "
          f"({jax.default_backend()} backend)")


if __name__ == "__main__":
    main()
