"""Serving launcher (CLI driver for the e2e serve story).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --quant int4 --requests 8 --tokens 32

Every token-input family runs on the unified continuous-batching
engine: attention layers on paged KV, recurrent layers (xlstm/zamba) on
per-lane StateArena slots.  Prefix caching and speculative decoding are
attention-only capabilities — `--spec` on a recurrent-state family is a
hard error, and `--no-prefix-cache` is auto-implied for hybrid/
recurrent families (see `check_capabilities`).
"""
import argparse

import numpy as np


def check_capabilities(model, spec_mode: str, no_prefix_cache: bool):
    """Validate CLI capability flags against the model's decode-state
    layout; returns the `prefix_cache` flag for `PagedServeEngine`.

    Prefix sharing and speculative decoding operate on attention KV
    pages only.  A model with recurrent state layers cannot rewind or
    adopt that state, so `--spec` raises a ValueError naming the
    capability, and the prefix cache is auto-disabled (`--no-prefix-
    cache` implied) rather than erroring — there is no affirmative
    prefix flag to contradict.
    """
    from repro.serve.engine import capability_error
    if model.supports_paged():
        return not no_prefix_cache
    if spec_mode != "off":
        raise ValueError(f"--spec {spec_mode}: "
                         + capability_error(model, "speculative-decoding"))
    if not no_prefix_cache:
        print(f"[serve] family {model.cfg.family!r} has recurrent state "
              "layers: --no-prefix-cache implied (prefix sharing is an "
              "attention-only capability)")
    return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--precision", default=None,
                    choices=["fp", "int8", "int4"],
                    help="serving precision (ServeConfig.precision): "
                         "int4 is the paper's CIM operating point "
                         "(default); fp serves float weights + bf16 KV")
    ap.add_argument("--quant", default=None,
                    choices=["bf16", "int8", "int4"],
                    help="DEPRECATED alias for --precision "
                         "(bf16 maps to fp)")
    ap.add_argument("--kv-dtype", default="auto",
                    choices=["auto", "bf16", "f32", "int8"],
                    help="paged KV pool storage; auto follows precision "
                         "(int8 pools when quantized)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4,
                    help="max concurrent decode lanes")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=0,
                    help="KV pool pages (0 = dense-equivalent worst case)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "model"],
                    help="speculative decoding drafter (model: a 1-layer "
                         "half-width smoke draft of the same arch)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft window (tokens per verify step)")
    ap.add_argument("--spec-autok", action="store_true",
                    help="autotune the per-step draft length 1..k from "
                         "an EMA of the measured acceptance rate")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix-trie prefix sharing of prompt "
                         "KV pages (enabled by default)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve HTTP instead of the offline request "
                         "sweep: SSE streaming POST /v1/completions + "
                         "GET /metrics until Ctrl-C")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8151)
    ap.add_argument("--max-pending", type=int, default=32,
                    help="gateway backpressure: samples in flight PER "
                         "REPLICA before new requests shed fleet-wide "
                         "with 429 + Retry-After")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind the "
                         "gateway (same model; --gateway mode only)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel devices per engine (shards "
                         "heads/FFN/vocab over a ('model',) mesh; "
                         "composes with --replicas as replicas x tp; "
                         "on CPU force a host mesh with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--policy", default="least-loaded",
                    choices=["rr", "least-loaded", "prefix"],
                    help="fleet dispatch policy: rr cycles replicas, "
                         "least-loaded follows pending depth + KV "
                         "occupancy, prefix routes repeated prompts to "
                         "the replica holding their committed KV pages")
    ap.add_argument("--trace", action="store_true",
                    help="record request/engine spans in the in-memory "
                         "tracer; dump a Perfetto-loadable Chrome trace "
                         "from GET /debug/trace (equivalent to "
                         "REPRO_TRACE=1)")
    ap.add_argument("--slo", default=None, nargs="*", metavar="SPEC",
                    help="enable the SLO engine (--gateway mode): pass "
                         "spec strings like 'ttft_p95_s < 0.5' "
                         "'error_rate < 0.01', or no specs for the "
                         "defaults; burn-rate alerts + per-replica "
                         "drift audit served at GET /debug/slo")
    ap.add_argument("--slo-timescale", type=float, default=1.0,
                    help="compress the SRE burn-rate windows by this "
                         "factor (1/600 maps the 1h page window to "
                         "6 s — bench/smoke timescales)")
    ap.add_argument("--access-log", default=None, metavar="PATH",
                    help="append one structured JSON line per gateway "
                         "request (rid, replica, policy, status, ttft, "
                         "tokens) to PATH ('-' for stderr)")
    args = ap.parse_args()

    if args.trace:
        from repro.obs import get_tracer
        get_tracer().enable()

    import warnings

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_smoke_config
    from repro.models import DecoderLM, init_params
    from repro.quant import quantized_fraction
    from repro.serve import (PagedServeEngine, SamplingParams, ServeConfig,
                             ServeRequest)

    # --quant predates ServeConfig; keep it working as an alias
    precision = args.precision
    if args.quant is not None:
        if precision is not None:
            raise SystemExit("pass --precision or --quant, not both")
        warnings.warn("--quant is deprecated; use --precision "
                      "(bf16 -> fp)", DeprecationWarning)
        precision = {"bf16": "fp", "int8": "int8",
                     "int4": "int4"}[args.quant]
    if precision is None:
        precision = "int4"          # the paper's operating point

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(dtype="float32", remat=False)
    if not cfg.embed_inputs:
        raise SystemExit(f"{args.arch} takes frontend-stub embeddings; the "
                         "token engine serves token-input archs")
    model = DecoderLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0),
                         dtype_override=jnp.float32)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
               for n in rng.integers(4, 17, size=args.requests)]

    if args.max_seq % args.page_size:
        raise SystemExit(f"--max-seq {args.max_seq} must be a multiple of "
                         f"--page-size {args.page_size}")
    prefix_cache = check_capabilities(model, args.spec, args.no_prefix_cache)
    spec_cfg = None
    if args.spec != "off":
        from repro.spec import SpecConfig
        if args.spec == "model":
            dcfg = cfg.replace(name=cfg.name + "-draft", n_layers=1,
                               d_model=max(cfg.d_model // 2, 32),
                               d_ff=max(cfg.d_ff // 2, 64))
            draft = DecoderLM(dcfg)
            dparams = init_params(draft.param_specs(),
                                  jax.random.PRNGKey(7),
                                  dtype_override=jnp.float32)
            spec_cfg = SpecConfig(k=args.spec_k, drafter="model",
                                  draft_model=draft,
                                  draft_params=dparams,
                                  draft_page_size=args.page_size,
                                  autok=args.spec_autok)
        else:
            spec_cfg = SpecConfig(k=args.spec_k, drafter="ngram",
                                  autok=args.spec_autok)
    if args.replicas < 1:
        raise SystemExit(f"--replicas {args.replicas}: need at least 1")
    if args.replicas > 1 and not args.gateway:
        raise SystemExit("--replicas > 1 requires --gateway (the offline "
                         "sweep runs one engine)")
    if args.slo is not None and not args.gateway:
        raise SystemExit("--slo requires --gateway (burn-rate alerting "
                         "evaluates the live serving loop)")

    if args.tp < 1:
        raise SystemExit(f"--tp {args.tp}: need at least 1")

    serve_cfg = ServeConfig(
        precision=precision, kv_dtype=args.kv_dtype,
        quant_group=16 if args.smoke else 128,
        max_batch=args.batch, max_seq=args.max_seq,
        page_size=args.page_size, n_pages=args.pages or None,
        prefix_cache=prefix_cache, replicas=args.replicas,
        policy=args.policy, max_pending=args.max_pending,
        tp=args.tp)

    def build_engine():
        # the engine quantizes float params itself when the config says
        # so; replicas then share the packed tensors (first engine
        # captures them below so later builds skip re-quantizing)
        return PagedServeEngine(model, params, serve_cfg, spec=spec_cfg)

    eng = build_engine()
    params = eng.params          # share (possibly packed) weights
    if serve_cfg.quantized():
        # report from the ENGINE's config: it pins auto-resolutions the
        # request couldn't know about (e.g. MLA degrades auto-int8 KV
        # back to bf16)
        print(f"[serve] {quantized_fraction(params)*100:.0f}% of param "
              f"bytes quantized ({precision}, kv "
              f"{eng.config.as_dict()['kv_dtype_resolved']})")
    if args.gateway:
        import asyncio
        from repro.api import Gateway
        from repro.fleet import FleetRouter
        # replicas share params (read-only under jit): N engines cost N
        # KV pools + N driver threads, not N copies of the weights
        engines = [eng] + [build_engine()
                           for _ in range(args.replicas - 1)]
        router = FleetRouter(engines)
        import sys
        access_log = (sys.stderr if args.access_log == "-"
                      else args.access_log)
        slos = slo_policy = None
        if args.slo is not None:
            from repro.obs.slo import DEFAULT_SLOS, BurnRatePolicy
            slos = list(args.slo) or list(DEFAULT_SLOS)
            slo_policy = BurnRatePolicy(timescale=args.slo_timescale)
            print(f"[serve] SLOs: {', '.join(slos)} "
                  f"(timescale {args.slo_timescale:g}, GET /debug/slo)")
        gw = Gateway(router, access_log=access_log, slos=slos,
                     slo_policy=slo_policy)
        try:
            asyncio.run(gw.serve_forever(args.host, args.port))
        except KeyboardInterrupt:
            print("[api] gateway stopped")
        return
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    reqs = [ServeRequest(prompt=p, max_new_tokens=args.tokens, rid=i,
                         sampling=sampling)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    m = eng.summary()
    spec_msg = ""
    if spec_cfg is not None:
        acc = m["spec_acceptance_rate"]
        acc_txt = (f"{acc*100:.0f}%" if np.isfinite(acc)
                   else "n/a (0 drafted)")
        spec_msg = (f", spec[{args.spec} k={args.spec_k}] "
                    f"acc {acc_txt} "
                    f"{m['tokens_per_decode_step']:.2f} tok/step")
    prefix_msg = ""
    if prefix_cache:
        hr = m["prefix_hit_rate"]
        prefix_msg = (f", prefix hit "
                      f"{hr*100:.0f}%" if np.isfinite(hr) else
                      ", prefix hit n/a")
        prefix_msg += (f" ({int(m['prefill_tokens_skipped'])} prefill "
                       f"tokens skipped)")
    state_msg = ""
    if eng.arena is not None:
        state_msg = (f", state slots peak "
                     f"{m['state_slot_occupancy_peak']*100:.0f}% "
                     f"({int(m['state_bytes'])/1024:.0f} KiB arena)")
    print(f"[serve] {int(m['tokens'])} tokens, "
          f"{eng.throughput():.0f} tok/s decode, "
          f"ttft p50 {m['ttft_p50_s']*1e3:.0f} ms / "
          f"p99 {m['ttft_p99_s']*1e3:.0f} ms, "
          f"tpot p50 {m['tpot_p50_s']*1e3:.1f} ms, "
          f"kv occupancy peak {m['kv_occupancy_peak']*100:.0f}%"
          f"{spec_msg}{prefix_msg}{state_msg} "
          f"({jax.default_backend()} backend"
          f"{f', tp={args.tp}' if args.tp > 1 else ''})")


if __name__ == "__main__":
    main()
