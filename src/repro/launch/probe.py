"""Roofline probes: trip-count-correct FLOPs/bytes/collective accounting.

XLA's `cost_analysis()` counts a while-loop (lax.scan) body ONCE.  The
dry-run's layer stacks are scanned, so raw numbers undercount by ~n_layers.
Probes fix this with two-point layer extrapolation on UNROLLED variants:

    lower+compile the same (width, seq, batch, mesh, precision) cell at
    two small unrolled layer counts  ->  per-layer slope + fixed cost
    ->  total = fixed + n_units_full * slope

Linearity in layer count is exact (identical per-layer compute), so the
extrapolation is too.  Inner scans (attention q-chunks, mamba SSD chunks)
are unrolled by the same flag.  The ONLY remaining scans are the
mLSTM/sLSTM time recurrences (unrollable at 4k-32k steps); their bodies'
cost is closed-form and corrected analytically below (body cost x trips).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs.registry import SHAPES, get_config
from repro.models.config import ModelConfig


def probe_variants(cfg: ModelConfig) -> Tuple[Tuple[ModelConfig, int],
                                              Tuple[ModelConfig, int], int]:
    """Two reduced-layer variants (cfg, units) + full unit count.
    A 'unit' is the repeating block (layer, or group for xlstm/zamba)."""
    if cfg.family in ("dense", "moe"):
        n_first = (cfg.moe.first_dense_layers if cfg.moe else 0)
        u_full = cfg.n_layers - n_first
        mk = lambda u: cfg.replace(n_layers=n_first + u, unroll=True)
        return (mk(1), 1), (mk(2), 2), u_full
    if cfg.family == "xlstm":
        per = cfg.ssm.slstm_every
        u_full = cfg.n_layers // per
        mk = lambda g: cfg.replace(n_layers=per * g, unroll=True)
        return (mk(1), 1), (mk(2), 2), u_full
    if cfg.family == "zamba":
        per = cfg.zamba.shared_every
        u_full = cfg.n_layers // per
        tail = cfg.n_layers - u_full * per
        mk = lambda g: cfg.replace(n_layers=per * g + tail, unroll=True)
        return (mk(1), 1), (mk(2), 2), u_full
    raise ValueError(cfg.family)


def time_scan_corrections(cfg: ModelConfig, shape_id: str,
                          n_devices: int) -> Dict[str, float]:
    """Analytic (flops, bytes) for the mLSTM/sLSTM time-recurrence bodies,
    which stay as lax.scan even in unrolled probes.  Per-device numbers.

    mLSTM step/head: C update (f*C + i*vk^T) ~ 4*dh^2 MAC-ish ops, read-
    modify-write of C (dh^2 f32) x3 + Cq matvec 2*dh^2.
    sLSTM step: recurrent gates R_h (d x 4dh blockdiag) = 8*d*dh flops.
    Train multiplies by 4 (fwd + remat-fwd + ~2x bwd).
    """
    seq, batch, kind = SHAPES[shape_id]
    if kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}

    if cfg.family == "zamba":
        # Mamba2 SSD chunk scan: body counted once, trips = seq/chunk.
        from repro.models.ssm import mamba2_dims
        di, nh, ds = mamba2_dims(cfg)
        l = cfg.ssm.chunk
        p_ = cfg.ssm.head_dim
        body_f = batch * (2.0 * l * l * ds + 4.0 * l * l * nh
                          + 2.0 * l * l * nh * p_
                          + 6.0 * l * nh * p_ * ds)
        body_b = batch * (12.0 * l * l * nh            # decay/w tensor rw f32
                          + 8.0 * l * nh * p_          # xs/y
                          + 8.0 * nh * p_ * ds) * 4.0
        trips = float(seq // l - 1)
        f = cfg.n_layers * body_f * trips
        byt = cfg.n_layers * body_b * trips
        if kind == "train":
            f *= 4.0
            byt *= 3.0
        return {"flops": f / n_devices, "bytes": byt / n_devices}

    if cfg.family != "xlstm":
        return {"flops": 0.0, "bytes": 0.0}
    from repro.models.ssm import mlstm_dims, slstm_dims
    di, nh, dh = mlstm_dims(cfg)
    d, nh2, dh2 = slstm_dims(cfg)
    per = cfg.ssm.slstm_every
    n_groups = cfg.n_layers // per

    mlstm_f = batch * nh * (8.0 * dh * dh)
    mlstm_b = batch * nh * (3.0 * dh * dh) * 4.0          # C rmw, f32
    slstm_f = batch * (8.0 * d * dh2) + 12.0 * batch * d
    slstm_b = (3.0 * batch * 4.0 * d + nh2 * dh2 * 4.0 * dh2) * 4.0

    trips = float(seq - 1)
    f = n_groups * ((per - 1) * mlstm_f + slstm_f) * trips
    byt = n_groups * ((per - 1) * mlstm_b + slstm_b) * trips
    if kind == "train":
        f *= 4.0
        byt *= 3.0
    return {"flops": f / n_devices, "bytes": byt / n_devices}


def extrapolate(m1: Dict[str, float], m2: Dict[str, float], u1: int, u2: int,
                u_full: int) -> Dict[str, float]:
    """fixed + slope*units for every shared numeric key."""
    out = {}
    for k in m1:
        if not isinstance(m1[k], (int, float)):
            continue
        slope = (m2[k] - m1[k]) / float(u2 - u1)
        fixed = m1[k] - u1 * slope
        out[k] = fixed + u_full * slope
    return out
