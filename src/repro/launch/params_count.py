"""Active-parameter accounting for MODEL_FLOPS (roofline 'useful work').

6*N*D with N = parameters touched per token: dense models use all
non-embedding params (+ LM head once per *output* position); MoE models
count only routed-active + shared experts; recurrent blocks count their
projection weights (state updates are O(d*state), included).
"""
from __future__ import annotations

from repro.configs.registry import SHAPES
from repro.models import DecoderLM
from repro.models.common import param_count
from repro.models.config import ModelConfig


def total_params(cfg: ModelConfig) -> int:
    return DecoderLM(cfg).n_params()


def active_params(cfg: ModelConfig) -> float:
    """Parameters participating per token (MoE: top_k+shared only)."""
    n = float(total_params(cfg))
    if cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = cfg.n_layers - m.first_dense_layers
        per_expert = 3 * cfg.d_model * m.d_ff_expert
        inactive = (m.n_experts - m.top_k) * per_expert * n_moe_layers
        n -= inactive
    # embeddings: lookup is O(d)/token, not a matmul — drop the table,
    # keep the LM head (tied or not, the head matmul is real compute)
    n -= cfg.vocab * cfg.d_model * (0 if cfg.tie_embeddings else 1)
    return n


def total_tokens(shape_id: str) -> float:
    seq, batch, kind = SHAPES[shape_id]
    if kind == "decode":
        return float(batch)          # one token per sequence per step
    return float(seq) * batch


def bytes_per_param(quant: str) -> float:
    if quant == "int4":
        return 0.5 * (1.0 + 16.0 / (128.0 * 4))   # + group scales
    if quant == "int8":
        return 1.0 * (1.0 + 16.0 / (128.0 * 8))
    return 2.0                                     # bf16


def decode_model_bytes(cfg: ModelConfig, shape_id: str, quant: str,
                       n_devices: int) -> float:
    """Idealized HBM bytes per decode step per device: every active
    parameter read once + the KV/state stream (the paper's bandwidth
    wall, Sec. III-C).  Local-attention layers read only their window."""
    seq, batch, kind = SHAPES[shape_id]
    assert kind == "decode"
    w_bytes = active_params(cfg) * bytes_per_param(quant)

    kv_bytes = 0.0
    if cfg.family in ("dense", "moe"):
        n_attn = cfg.n_layers
        if cfg.attn_kind == "mla":
            row = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            kv_bytes = n_attn * batch * seq * row * 2.0
        else:
            row = 2 * cfg.n_kv_heads * cfg.hd()
            if cfg.local_window and cfg.local_pattern:
                n_local = sum(cfg.is_local_layer(i)
                              for i in range(cfg.n_layers))
                n_global = cfg.n_layers - n_local
                kv_bytes = (n_global * seq
                            + n_local * min(seq, cfg.local_window)
                            ) * batch * row * 2.0
            else:
                kv_bytes = n_attn * batch * seq * row * 2.0
    elif cfg.family == "xlstm":
        from repro.models.ssm import mlstm_dims, slstm_dims
        di, nh, dh = mlstm_dims(cfg)
        per = cfg.ssm.slstm_every
        n_groups = cfg.n_layers // per
        state = n_groups * ((per - 1) * nh * dh * dh + 4 * cfg.d_model)
        kv_bytes = 2.0 * state * 4.0 * batch          # read+write f32
    elif cfg.family == "zamba":
        from repro.models.ssm import mamba2_dims
        di, nh, ds = mamba2_dims(cfg)
        n_mamba = cfg.n_layers
        n_attn = cfg.n_layers // cfg.zamba.shared_every
        state = n_mamba * nh * cfg.ssm.head_dim * ds
        kv_bytes = (2.0 * state * 4.0
                    + n_attn * seq * 2 * cfg.n_kv_heads * cfg.hd() * 2.0
                    ) * batch
    return (w_bytes + kv_bytes) / n_devices
