import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  jax.jit(step, in_shardings=...).lower(*structs).compile()
then record memory_analysis (fits-per-chip proof), cost_analysis (FLOPs /
bytes for the roofline), and the collective bytes parsed from the
optimized HLO.  Success for the 16x16 single-pod mesh AND the 2x16x16
multi-pod mesh is the deliverable; results feed EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  python -m repro.launch.dryrun --arch gemma3-4b --shape decode_32k \
      --multi-pod --quant int4 --out results/
  python -m repro.launch.dryrun --all        # every cell, both meshes
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_id: str, multi_pod: bool, quant: str,
             out_dir: str = "results/dryrun", verbose: bool = True,
             serve_sharding: bool = False, tag: str = "") -> dict:
    import jax
    from repro.configs.registry import SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.roofline.analysis import (Roofline, model_flops_for,
                                         parse_collectives)

    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cell = build_cell(arch, shape_id, mesh, quant=quant,
                      serve_sharding=serve_sharding)

    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    coll = parse_collectives(hlo)
    flops = float(cost.get("flops", 0.0))
    hbytes = float(cost.get("bytes accessed", 0.0))
    mem_d = None
    if mem is not None:
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }
        mem_d["peak_bytes"] = (mem_d["argument_bytes"]
                               + mem_d["output_bytes"]
                               + mem_d["temp_bytes"]
                               - mem_d["alias_bytes"])

    rf = Roofline(
        arch=arch, shape_id=shape_id, kind=cell.kind, mesh=mesh_name,
        quant=quant, flops=flops, hlo_bytes=hbytes,
        collective_bytes=coll.total_bytes,
        model_flops=model_flops_for(arch, shape_id, n_dev),
        collective_detail=dict(coll.bytes_by_kind),
        memory_per_device=mem_d)
    rec = rf.to_dict()
    rec.update({"t_lower_s": t_lower, "t_compile_s": t_compile,
                "n_devices": n_dev, "status": "ok",
                "collective_counts": dict(coll.count_by_kind),
                "hlo_bytes_len": len(hlo)})

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{shape_id}__{mesh_name}__{quant}{tag}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        print(f"[OK] {arch} {shape_id} {mesh_name} {quant}: "
              f"compute {rf.t_compute*1e3:.2f}ms  mem {rf.t_memory*1e3:.2f}ms"
              f"  coll {rf.t_collective*1e3:.2f}ms  dom={rf.dominant}  "
              f"useful={rf.useful_flops_ratio:.2f}  "
              f"peakHBM={mem_d['peak_bytes']/2**30:.2f}GiB  "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print("  memory_analysis:", mem_d)
        print("  cost_analysis: flops=%.3e bytes=%.3e" % (flops, hbytes))
        print("  collectives:", {k: f"{v/2**20:.1f}MiB"
                                 for k, v in coll.bytes_by_kind.items()})
    return rec


def run_probe(arch: str, shape_id: str, quant: str,
              out_dir: str = "results/probe", verbose: bool = True,
              serve_sharding: bool = False, tag: str = "",
              cfg_override=None) -> dict:
    """Trip-count-correct roofline terms via two-point unrolled layer
    extrapolation (launch/probe.py) — single-pod mesh."""
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch.probe import (extrapolate, probe_variants,
                                    time_scan_corrections)
    from repro.launch.specs import build_cell
    from repro.roofline.analysis import (Roofline, model_flops_for,
                                         parse_collectives)
    from repro.configs.registry import get_config

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size
    cfg_full = cfg_override if cfg_override is not None else get_config(arch)
    (cfg1, u1), (cfg2, u2), u_full = probe_variants(cfg_full)

    def measure(cfg_v):
        cell = build_cell(arch, shape_id, mesh, quant=quant, cfg=cfg_v,
                          serve_sharding=serve_sharding)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate)
            compiled = jitted.lower(*cell.args).compile()
            cost = compiled.cost_analysis()
            coll = parse_collectives(compiled.as_text())
        return {"flops": float(cost.get("flops", 0.0)),
                "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
                "collective_bytes": coll.total_bytes,
                "_detail": dict(coll.bytes_by_kind)}, cell.kind

    m1, kind = measure(cfg1)
    m2, _ = measure(cfg2)
    tot = extrapolate(m1, m2, u1, u2, u_full)
    detail = {k: (m1["_detail"].get(k, 0.0)
                  + (m2["_detail"].get(k, 0.0) - m1["_detail"].get(k, 0.0))
                  / (u2 - u1) * (u_full - u1))
              for k in set(m1["_detail"]) | set(m2["_detail"])}
    corr = time_scan_corrections(cfg_full, shape_id, n_dev)
    tot["flops"] += corr["flops"]
    tot["hlo_bytes"] += corr["bytes"]

    rf = Roofline(arch=arch, shape_id=shape_id, kind=kind, mesh="single",
                  quant=quant, flops=tot["flops"],
                  hlo_bytes=tot["hlo_bytes"],
                  collective_bytes=tot["collective_bytes"],
                  model_flops=model_flops_for(arch, shape_id, n_dev),
                  collective_detail=detail)
    rec = rf.to_dict()
    rec.update({"status": "ok", "probe": True, "units": [u1, u2, u_full],
                "time_scan_correction": corr, "t_total_s": time.time() - t0})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{shape_id}__probe__{quant}{tag}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        print(f"[PROBE] {arch} {shape_id} {quant}: "
              f"compute {rf.t_compute*1e3:.2f}ms mem {rf.t_memory*1e3:.2f}ms "
              f"coll {rf.t_collective*1e3:.2f}ms dom={rf.dominant} "
              f"useful={rf.useful_flops_ratio:.3f} "
              f"rf={rf.roofline_fraction:.3f} ({time.time()-t0:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="bf16",
                    choices=["bf16", "int8", "int4"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="roofline probe (unrolled 2-point extrapolation)")
    ap.add_argument("--serve-sharding", action="store_true",
                    help="no-FSDP decode rules (SSPerf)")
    ap.add_argument("--tag", default="", help="suffix for result filename")
    args = ap.parse_args()

    if not args.all:
        assert args.arch and args.shape
        if args.probe:
            run_probe(args.arch, args.shape, args.quant,
                      args.out.replace("dryrun", "probe"),
                      serve_sharding=args.serve_sharding, tag=args.tag)
        else:
            run_cell(args.arch, args.shape, args.multi_pod, args.quant,
                     args.out, serve_sharding=args.serve_sharding,
                     tag=args.tag)
        return

    from repro.configs.registry import shapes_for
    from repro.configs import ARCH_IDS
    failures = []
    for arch in ARCH_IDS:
        for shape_id in shapes_for(arch):
            for multi in (False, True):
                quants = ("bf16",) if SHAPES_KIND(shape_id) != "decode" \
                    else ("bf16", "int4")
                for q in quants:
                    try:
                        run_cell(arch, shape_id, multi, q, args.out)
                    except Exception as e:  # noqa
                        traceback.print_exc()
                        failures.append((arch, shape_id, multi, q, str(e)))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)


def SHAPES_KIND(shape_id: str) -> str:
    from repro.configs.registry import SHAPES
    return SHAPES[shape_id][2]


if __name__ == "__main__":
    main()
