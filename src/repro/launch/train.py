"""Training launcher (CLI driver for the e2e train story).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 100 --ckpt-dir /tmp/ck --resume
Full-scale (real pod) runs use the same entry point without --smoke; on
this CPU container only reduced configs are trainable.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--preempt-flag", default=None)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data import DataConfig, SyntheticLM
    from repro.models import DecoderLM
    from repro.train import AdamW, TrainConfig, Trainer, cosine_schedule

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(dtype="float32", remat=False)
    model = DecoderLM(cfg)
    print(f"[train] {cfg.name}: {model.n_params()/1e6:.1f}M params")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                  global_batch=args.global_batch))
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))
    tr = Trainer(model, opt, data,
                 TrainConfig(steps=args.steps, log_every=10, ckpt_every=50,
                             ckpt_dir=args.ckpt_dir,
                             preempt_flag=args.preempt_flag,
                             microbatches=args.microbatches),
                 event_hook=lambda e: print(f"  {e.kind} @{e.step} "
                                            f"{e.payload}"))
    out = tr.run(resume=args.resume)
    print(f"[train] done @step {out['step']}  loss {out['losses'][-1]:.3f} "
          f"(floor {data.bigram_entropy():.3f})")


if __name__ == "__main__":
    main()
