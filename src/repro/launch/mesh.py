"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing never touches
jax device state — the dry-run sets XLA_FLAGS before any jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a 2-pod leading axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke tests / examples."""
    return jax.make_mesh((1, 1), ("data", "model"))
