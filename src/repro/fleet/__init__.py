"""Data-parallel fleet serving: N engine replicas behind one router.

`FleetRouter` owns the replicas (router.py), `Replica` wraps one
engine + driver with lock-free routing state (replica.py), and the
dispatch policies live in policy.py.  The API gateway builds a router
(or wraps a single engine in a one-replica fleet) and speaks only to
it — see repro.api.gateway.
"""
from .policy import (LeastLoadedPolicy, Policy, PrefixAffinityPolicy,
                     RoundRobinPolicy, make_policy)
from .replica import Replica
from .router import FleetRouter, aggregate_histograms, aggregate_summaries

__all__ = [
    "FleetRouter", "Replica", "Policy", "RoundRobinPolicy",
    "LeastLoadedPolicy", "PrefixAffinityPolicy", "make_policy",
    "aggregate_summaries", "aggregate_histograms",
]
