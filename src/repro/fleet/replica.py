"""One data-parallel engine replica behind the fleet router.

A replica is an `EngineDriver` (the one thread that owns its
`PagedServeEngine`) plus the host-side state the router needs to make
per-dispatch decisions WITHOUT crossing the thread boundary:

  pending      samples dispatched here and not yet done — event-loop-
               side, authoritative, updated synchronously at dispatch /
               release (the driver thread never touches it)
  snapshot     occupancy gauges published by the driver loop's tap
               after every step (`Telemetry.snapshot` + lane/page
               counts): at most one step stale, read lock-free (a dict
               swap is atomic in CPython)
  fingerprint  path-hash set of the engine's resident `PrefixIndex`
               prefixes, republished only when the trie's version
               moved — the prefix-affinity policy matches prompts
               against it with `prompt_page_hashes`, never touching
               the trie itself

Lifecycle: LIVE replicas take dispatches; a DRAINING replica takes no
new work but keeps stepping until its in-flight requests finish; a
replica whose driver died fail-fast (`alive == False`) is skipped by
every policy and reported per-replica in /metrics — the gateway stays
up on the survivors.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.api.driver import EngineDriver
from repro.obs.drift import DriftAuditor


class Replica:
    def __init__(self, engine, rid: int, max_pending: int = 32):
        assert max_pending >= 0
        self.engine = engine
        self.id = rid
        self.max_pending = max_pending
        self.page_size = engine.cache.page_size     # for prompt hashing
        # label the engine's flight recorder with the fleet identity so
        # a postmortem dump says WHICH replica died (and the driver
        # thread name shows up as its own track in a Chrome trace)
        engine.recorder.label = f"replica-{rid}"
        self.driver = EngineDriver(engine, tap=self._publish)
        self.driver._thread.name = f"engine-driver-{rid}"
        self.pending = 0            # samples in flight (event-loop side)
        self.draining = False
        self.dispatches = 0         # request groups routed here
        self._fp_version = -1
        self.fingerprint: frozenset = frozenset()
        self._digest_version = -1
        # serialized quantile sketches (Telemetry.digests()), published
        # by the tap like the snapshot: the router's SLO poll and fleet
        # percentile merges read these lock-free
        self.digests: Dict[str, Dict] = {}
        # digital-twin audit: predicted vs measured decode clock,
        # ticked by FleetRouter.poll_slo from the published snapshot
        self.drift = DriftAuditor()
        self.snapshot: Dict[str, float] = {
            "n_running": 0.0, "n_queued": 0.0, "kv_occupancy": 0.0,
            "kv_pages_free": float(engine.cache.allocator.n_pages)}

    # -- state ----------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self.driver.alive

    @property
    def live(self) -> bool:
        """Eligible for new dispatches."""
        return self.alive and not self.draining

    def has_capacity(self, n: int) -> bool:
        return self.pending + n <= self.max_pending

    @property
    def error(self) -> Optional[BaseException]:
        return self.driver.error

    # -- snapshot publisher (driver thread) -----------------------------
    def _publish(self, engine) -> None:
        """Driver-loop tap: build the routing snapshot ON the engine
        thread (where reading engine state is safe) and publish it by
        attribute swap.  The prefix fingerprint is rebuilt only when
        the trie's version moved — steady-state cost is a few dict
        reads per step."""
        snap = engine.telemetry.snapshot()
        snap["n_running"] = float(engine.n_running)
        snap["n_queued"] = float(engine.scheduler.n_queued)
        snap["kv_pages_free"] = float(engine.cache.allocator.n_free)
        snap["kv_occupancy"] = engine.cache.occupancy()
        energy = getattr(engine, "energy", None)
        if energy is not None:
            # the drift audit's predicted decode clock, tp-scaled like
            # sim_time_s (a tp=2 engine streams each step in half the
            # modeled single-device time)
            snap["sim_decode_s"] = energy.decode_sim_s / energy.tp
        if engine.prefix is not None:
            version = engine.prefix.version
            if version != self._fp_version:
                self._fp_version = version
                _, self.fingerprint = engine.prefix.fingerprint()
        dv = engine.telemetry.digest_version
        if dv != self._digest_version:
            self._digest_version = dv
            self.digests = engine.telemetry.digests()
        self.snapshot = snap

    # -- load metric ----------------------------------------------------
    def depth(self) -> float:
        """Pending depth for least-loaded comparison: samples dispatched
        and not yet finished (authoritative) plus the engine-side queue
        the router cannot see through `pending` alone after a drain
        re-home or direct submission."""
        return float(self.pending)

    def occupancy(self) -> float:
        return float(self.snapshot.get("kv_occupancy", 0.0))

    def describe(self) -> Dict:
        """Router-side (thread-free) view for /metrics: state + gauges;
        the engine's full summary is fetched separately via a driver
        job when the replica is alive."""
        return {"id": self.id, "alive": self.alive,
                "draining": self.draining, "pending": self.pending,
                "dispatches": self.dispatches,
                "error": repr(self.error) if self.error else None,
                "snapshot": dict(self.snapshot),
                "drift": self.drift.summary()}
