"""Dispatch policies: which live replica serves the next request group.

Every policy picks from CANDIDATES — live replicas with admission
capacity — already filtered by the router, so a policy is pure routing
preference, never admission control.

  rr            cycle through replicas in id order: perfectly fair,
                ignores load and cache state (the baseline the bench
                compares against)
  least-loaded  min (pending depth, KV occupancy): pending is the
                router's own dispatch ledger (exact), occupancy comes
                from the replica's last published step snapshot (at
                most one step stale)
  prefix        prefix-affinity: route to the replica whose resident
                radix-trie fingerprint covers the longest page-aligned
                prefix of the prompt — it adopts the matched KV pages
                instead of re-prefilling them.  Depth ties break by
                least-loaded, and a miss everywhere IS least-loaded.

A fingerprint hash collision can only misroute (the engine still walks
its exact token trie at admission), so affinity is a pure optimization
with least-loaded's behavior as its floor.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.serve.prefix import prompt_page_hashes

from .replica import Replica


class Policy:
    name = "base"

    def pick(self, candidates: Sequence[Replica],
             prompt: Optional[np.ndarray]) -> Replica:
        raise NotImplementedError


class RoundRobinPolicy(Policy):
    name = "rr"

    def __init__(self):
        self._next = 0

    def pick(self, candidates, prompt):
        # cycle over replica IDS, not the candidate list: a replica
        # dropping out (dead/saturated) must not re-deal everyone else
        cands = sorted(candidates, key=lambda r: r.id)
        chosen = next((r for r in cands if r.id >= self._next), cands[0])
        self._next = chosen.id + 1
        return chosen


class LeastLoadedPolicy(Policy):
    name = "least-loaded"

    def pick(self, candidates, prompt):
        return min(candidates,
                   key=lambda r: (r.depth(), r.occupancy(), r.id))


class PrefixAffinityPolicy(Policy):
    name = "prefix"

    def __init__(self):
        self.hits = 0       # dispatches routed by a fingerprint match
        self.misses = 0     # dispatches that fell back to least-loaded
        self._fallback = LeastLoadedPolicy()

    @staticmethod
    def score(replica: Replica, hashes: List[int]) -> int:
        """Consecutive-from-root page-prefix depth the replica's
        fingerprint covers (KV rows depend on the whole causal prefix,
        so a gap ends the usable match exactly like in the trie)."""
        fp = replica.fingerprint
        depth = 0
        for h in hashes:
            if h not in fp:
                break
            depth += 1
        return depth

    def pick(self, candidates, prompt):
        hashes: List[int] = []
        if prompt is not None and len(prompt) > 0:
            page_size = candidates[0].page_size
            hashes = prompt_page_hashes(np.asarray(prompt), page_size)
        best, best_depth = [], 0
        if hashes:
            for r in candidates:
                d = self.score(r, hashes)
                if d > best_depth:
                    best, best_depth = [r], d
                elif d == best_depth and best_depth > 0:
                    best.append(r)
        if not best:
            self.misses += 1
            return self._fallback.pick(candidates, prompt)
        self.hits += 1
        return self._fallback.pick(best, prompt)


def make_policy(policy) -> Policy:
    """Accept a policy name or an already-built Policy instance."""
    if isinstance(policy, Policy):
        return policy
    table = {"rr": RoundRobinPolicy, "round-robin": RoundRobinPolicy,
             "least-loaded": LeastLoadedPolicy,
             "prefix": PrefixAffinityPolicy,
             "prefix-affinity": PrefixAffinityPolicy}
    if policy not in table:
        raise ValueError(f"unknown dispatch policy {policy!r} "
                         f"(choose from {sorted(table)})")
    return table[policy]()
