"""FleetRouter: N data-parallel engine replicas behind one gateway.

One `PagedServeEngine` caps goodput at its own lane/page pools, and a
single long prefill inflates every stream's tail latency.  The router
scales the serving story out: it owns N replicas of the SAME model
(one `EngineDriver` thread each), dispatches every request group to
exactly one replica under a pluggable policy (policy.py), and turns the
per-engine admission machinery into fleet-level load shedding — a
request is 429'd only when EVERY live replica is at its pending cap,
with a Retry-After estimated from the least-loaded replica's measured
decode rate.

Dispatch stays on the caller's event loop: routing reads only
router-side pending ledgers and the lock-free snapshots each driver
tap publishes (replica.py), so picking a replica costs dict lookups,
not thread round-trips.  A request group (a primary and its fork
children) always lands on one replica — forked KV pages cannot span
engines.

Lifecycle:
  drain(i)   stop dispatching to replica i, re-home its not-yet-started
             queue onto healthy replicas (watchers travel along; fork
             links are severed — engine ids are per-engine), and let
             its in-flight requests finish where they run.
  death      a driver that died fail-fast (fatal step error) drops out
             of every policy's candidate set automatically; its
             in-flight requests were already failed by the driver's
             shutdown sweep.  The gateway keeps serving on survivors —
             /healthz stays 200 while >= 1 replica is live.
"""
from __future__ import annotations

import asyncio
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.digest import PERCENTILE_KEYS, merge_digest_dicts
from repro.obs.slo import BurnRatePolicy, SLOMonitor
from repro.obs.trace import get_tracer

from .policy import Policy, PrefixAffinityPolicy, make_policy
from .replica import Replica


# summary keys that SUM across replicas (counters and parallel rates);
# *_peak keys take the max; percentile keys are recomputed from merged
# quantile sketches when the caller provides them (the only correct
# fleet percentile — obs/digest.py) and dropped otherwise; everything
# else (means) is nan-averaged — approximate for a fleet, exact for one
# replica, and the per-replica breakdown always carries the honest
# numbers
_SUM_KEYS = frozenset({
    "requests", "requests_total", "tokens", "decode_tokens",
    "prefill_tokens", "steps", "decode_steps", "spec_drafted",
    "spec_accepted", "prefix_lookups", "prefix_hits",
    "prefill_tokens_skipped", "fork_admissions", "cancelled",
    "cow_copies", "kv_pages_shared", "prefix_pages_resident",
    "prefix_pages_evicted", "state_bytes", "tokens_per_s",
    "decode_tokens_per_s", "decode_s",
    "sim_energy_j", "sim_decode_energy_j", "sim_prefill_energy_j",
    "sim_time_s", "sim_decode_time_s", "sim_decode_tokens",
})


def _nanagg(vals: np.ndarray, fn) -> float:
    return float(fn(vals)) if not np.all(np.isnan(vals)) else float("nan")


def aggregate_summaries(summaries: Sequence[Dict],
                        digests: Optional[Sequence[Dict]] = None
                        ) -> Optional[Dict]:
    """Fleet rollup of per-engine `summary()` dicts: counters sum,
    peaks max; ratio metrics are recomputed from the summed numerators
    (a mean of per-replica hit rates is not the fleet hit rate).

    `digests`: per-replica `Telemetry.digests()` payloads.  When given,
    every percentile key is RECOMPUTED from the merged sketches —
    bucket-wise addition, so the fleet p95 is the p95 of the pooled
    samples (within the sketch's relative-error bound), not an average
    of per-replica p95s (which is not a percentile of anything).
    Without digests the old nan-averaging stands as a last resort for
    direct callers that only hold summaries."""
    if not summaries:
        return None
    have_digests = digests is not None
    out: Dict[str, float] = {}
    for k in sorted(set().union(*map(set, summaries))):
        if have_digests and k in PERCENTILE_KEYS:
            continue            # recomputed from merged sketches below
        vals = np.asarray([float(s[k]) for s in summaries if k in s],
                          np.float64)
        if k in _SUM_KEYS or k.startswith("lane_steps_"):
            out[k] = float(np.nansum(vals))
        elif k.endswith("_peak"):
            out[k] = _nanagg(vals, np.nanmax)
        else:
            out[k] = _nanagg(vals, np.nanmean)
    if have_digests:
        merged = merge_digests(digests)
        for key, (metric, p) in PERCENTILE_KEYS.items():
            dig = merged.get(metric)
            if dig is not None and dig.count:
                out[key] = dig.quantile(p)
    if out.get("prefix_lookups"):
        out["prefix_hit_rate"] = out["prefix_hits"] / out["prefix_lookups"]
    if out.get("spec_drafted"):
        out["spec_acceptance_rate"] = (out["spec_accepted"]
                                       / out["spec_drafted"])
    if out.get("sim_energy_j"):
        out["sim_tokens_per_j"] = (out.get("sim_decode_tokens", 0.0)
                                   / out["sim_energy_j"])
    if out.get("sim_time_s"):
        out["sim_tokens_per_s"] = (out.get("sim_decode_tokens", 0.0)
                                   / out["sim_time_s"])
    return out


def merge_digests(digests: Sequence[Dict]) -> Dict:
    """Merge per-replica `Telemetry.digests()` payloads into one
    `QuantileDigest` per metric (skipping replicas that lack one)."""
    merged = {}
    for metric in sorted(set().union(*map(set, digests)) if digests
                         else ()):
        dig = merge_digest_dicts(d.get(metric) for d in digests)
        if dig is not None:
            merged[metric] = dig
    return merged


def aggregate_histograms(hists: Sequence[Dict]) -> Optional[Dict]:
    """Histograms compose exactly: same log-spaced edges everywhere, so
    the fleet distribution is the per-bucket sum."""
    if not hists:
        return None
    out: Dict[str, Dict] = {}
    for name in hists[0]:
        counts = np.sum([h[name]["counts"] for h in hists if name in h],
                        axis=0)
        out[name] = {"edges_s": list(hists[0][name]["edges_s"]),
                     "counts": [int(c) for c in counts]}
    return out


class FleetRouter:
    def __init__(self, engines: Sequence, *, policy=None,
                 max_pending: Optional[int] = None):
        """`engines`: one built `PagedServeEngine` per replica, same
        model/params each (asserted on the config).  `max_pending` is
        the PER-REPLICA admission cap in samples; fleet capacity is
        `max_pending * n_live`.  Policy and cap default to the engines'
        shared `ServeConfig` (the single object the launcher threads
        through), overridable per-router for tests."""
        assert engines, "a fleet needs at least one engine"
        serve_cfg = engines[0].config
        if policy is None:
            policy = serve_cfg.policy
        if max_pending is None:
            max_pending = serve_cfg.max_pending
        self.serve_config = serve_cfg
        self.max_pending = max_pending
        for e in engines[1:]:
            self._check_same_model(e, engines[0])
        self.replicas = [Replica(e, i, max_pending)
                         for i, e in enumerate(engines)]
        self.policy: Policy = make_policy(policy)
        self.counters: Dict[str, int] = {"dispatched": 0, "requeued": 0,
                                         "requeue_failed": 0, "drains": 0,
                                         "adds": 0}
        self._owner: Dict[int, Replica] = {}    # id(req) -> replica
        self.tracer = get_tracer()
        # SLO layer (obs/slo.py): None until set_slos(); the drift
        # audit (per-replica, obs/drift.py) runs unconditionally on
        # every poll_slo tick — it needs no configuration
        self.slo: Optional[SLOMonitor] = None
        self._alert_subs: List[Callable[[Dict], None]] = []

    # -- SLOs / drift ----------------------------------------------------
    def set_slos(self, slos, *,
                 policy: Optional[BurnRatePolicy] = None) -> None:
        """Install declarative objectives (spec strings or `SLOSpec`s)
        evaluated per replica AND fleet-wide on every `poll_slo` tick."""
        self.slo = SLOMonitor(slos, policy=policy)

    def on_alert(self, cb: Callable[[Dict], None]) -> None:
        """Subscribe to alert events: SLO level transitions
        (kind="slo_alert") and drift alarms (kind="drift_alarm") — the
        hook a future autoscaler/drain controller consumes.  Callbacks
        run on whatever thread/loop calls `poll_slo`; exceptions are
        swallowed (a broken subscriber must not stop evaluation)."""
        self._alert_subs.append(cb)

    def poll_slo(self, now: Optional[float] = None) -> List[Dict]:
        """One evaluation tick, thread-free: reads only the lock-free
        snapshots/digests the driver taps publish.  Per live replica it
        advances the drift auditor over the measured-vs-simulated
        decode clocks; with SLOs configured it ingests every replica
        scope plus a synthetic "fleet" scope (summed counters + merged
        sketches) and re-evaluates burn rates.  Alert events are
        recorded into the scoped replica's flight recorder (fleet-scope
        events into every live replica's — a postmortem dump of any
        survivor explains the page) and delivered to `on_alert`
        subscribers.  Returns the events this tick produced."""
        now = time.monotonic() if now is None else now
        events: List[Dict] = []
        live = [rep for rep in self.replicas if rep.alive]
        for rep in live:
            snap = rep.snapshot
            if "sim_decode_s" in snap:
                ev = rep.drift.observe(now, snap.get("decode_s", 0.0),
                                       snap["sim_decode_s"])
                if ev is not None:
                    ev = {**ev, "scope": f"replica-{rep.id}"}
                    rep.engine.recorder.record(
                        "drift_alarm",
                        **{k: v for k, v in ev.items() if k != "kind"})
                    events.append(ev)
            if self.slo is not None:
                self.slo.ingest(f"replica-{rep.id}", digests=rep.digests,
                                counters=snap, now=now)
        if self.slo is not None and live:
            fleet_counters: Dict[str, float] = {}
            for rep in live:
                for k, v in rep.snapshot.items():
                    fleet_counters[k] = fleet_counters.get(k, 0.0) \
                        + float(v)
            fleet_digests = {m: d.to_dict() for m, d in
                            merge_digests([rep.digests
                                           for rep in live]).items()}
            self.slo.ingest("fleet", digests=fleet_digests,
                            counters=fleet_counters, now=now)
            for ev in self.slo.evaluate(now):
                scope = ev.get("scope", "")
                # the event dict already carries "kind" — strip it, the
                # recorder takes kind positionally
                fields = {k: v for k, v in ev.items() if k != "kind"}
                for rep in live:
                    if scope == "fleet" or scope == f"replica-{rep.id}":
                        rep.engine.recorder.record("slo_alert", **fields)
                events.append(ev)
        for ev in events:
            for cb in self._alert_subs:
                try:
                    cb(ev)
                except Exception:
                    pass
        return events

    def worst_alert_level(self) -> str:
        """Highest active SLO alert level across every scope ("ok"
        when no SLOs are configured) — /healthz's `degraded` flag."""
        return self.slo.worst_level() if self.slo is not None else "ok"

    def slo_payload(self) -> Dict:
        """JSON body for GET /debug/slo: objectives + policy + alert
        states + recent transitions, plus the per-replica drift audit."""
        payload = (self.slo.payload() if self.slo is not None
                   else {"slos": [], "states": [], "worst": "ok",
                         "transitions": []})
        payload["drift"] = {
            str(rep.id): {**rep.drift.summary(),
                          "events": list(rep.drift.events)}
            for rep in self.replicas}
        return payload

    @staticmethod
    def _check_same_model(engine, ref) -> None:
        assert (engine.model.cfg.name == ref.model.cfg.name
                and engine.model.cfg.vocab == ref.model.cfg.vocab
                and engine.max_seq == ref.max_seq), \
            "fleet replicas must serve the same model"

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "FleetRouter":
        for rep in self.replicas:
            rep.driver.start()
        return self

    def add_replica(self, engine) -> Replica:
        """Scale out at runtime — the inverse of `drain()`: wrap a
        freshly built engine (same model, typically sharing the first
        replica's params) in a `Replica`, start its driver thread, and
        enter it into rotation.  The next `route()` call sees it: every
        policy reads the live candidate list per dispatch, so rr cycles
        through it, least-loaded finds its empty queues immediately,
        and prefix-affinity starts matching once its tap publishes a
        fingerprint.  Replica ids are list indices and drained replicas
        keep their slot, so the new id is always `len(replicas)` —
        `cancel`/`/metrics` lookups stay index-stable.  Returns the new
        replica (already live; no request in flight is disturbed)."""
        self._check_same_model(engine, self.replicas[0].engine)
        rep = Replica(engine, len(self.replicas), self.max_pending)
        rep.driver.start()
        self.replicas.append(rep)
        self.counters["adds"] += 1
        if self.tracer.enabled:
            self.tracer.instant("replica_add", cat="router", replica=rep.id,
                                n_replicas=len(self.replicas))
        return rep

    def stop(self, timeout: float = 10.0) -> None:
        for rep in self.replicas:
            rep.driver.stop(timeout)

    @property
    def alive(self) -> bool:
        """Any replica's driver still running (drain-ing counts: it is
        serving its in-flight work)."""
        return any(rep.alive for rep in self.replicas)

    @property
    def n_live(self) -> int:
        return sum(rep.live for rep in self.replicas)

    # -- dispatch (event-loop side) ------------------------------------
    def route(self, prompt, n: int = 1) -> Optional[Replica]:
        """Pick the replica for a group of `n` samples over `prompt`,
        or None when every live replica is saturated (fleet-level
        shed) or none is live."""
        cands = [rep for rep in self.replicas
                 if rep.live and rep.has_capacity(n)]
        if not cands:
            return None
        return self.policy.pick(cands, prompt)

    def dispatch(self, rep: Replica, reqs: List, on_done: Callable):
        """Account the group against `rep` and submit it; returns the
        driver Future of engine ids.  Accounting happens NOW (before
        the future resolves) so a burst of arrivals sees each other's
        reservations."""
        rep.dispatches += 1
        rep.pending += len(reqs)
        self.counters["dispatched"] += 1
        if self.tracer.enabled:
            # the routing decision, with what the policy saw: per-
            # replica queue depth / liveness at pick time
            self.tracer.instant(
                "route_dispatch", cat="router",
                rid=getattr(reqs[0], "trace_id", -1),
                rids=[getattr(r, "trace_id", -1) for r in reqs],
                replica=rep.id, policy=self.policy.name,
                depths={str(r.id): r.depth() for r in self.replicas},
                live={str(r.id): r.live for r in self.replicas})
        for r in reqs:
            self._owner[id(r)] = rep
        return rep.driver.submit(reqs, on_done)

    def dispatch_failed(self, rep: Replica, reqs: List) -> None:
        """Roll back `dispatch` accounting after its future failed (the
        driver died between route and submit)."""
        rep.dispatches -= 1
        rep.pending -= len(reqs)
        self.counters["dispatched"] -= 1
        for r in reqs:
            self._owner.pop(id(r), None)

    def release(self, req) -> None:
        """One sample finished (done sweep landed on the event loop):
        free its replica's admission slot."""
        rep = self._owner.pop(id(req), None)
        if rep is not None:
            rep.pending -= 1

    async def cancel(self, reqs: List) -> int:
        """Cancel requests wherever they currently live (the owner map
        follows drain re-homes); returns how many were actually
        cancelled."""
        by_rep: Dict[int, List[int]] = {}
        for req in reqs:
            rep = self._owner.get(id(req))
            if rep is not None and rep.alive and req.eid >= 0:
                by_rep.setdefault(rep.id, []).append(req.eid)
        total = 0
        for rid, eids in by_rep.items():
            try:
                total += await asyncio.wrap_future(
                    self.replicas[rid].driver.cancel(eids))
            except RuntimeError:
                pass        # died mid-cancel: its requests died with it
        return total

    def retry_after_s(self) -> int:
        """Honest Retry-After for a fleet-level shed: the least-loaded
        live replica's pending depth times its measured per-token
        decode time (floor 1s) — an estimate of when a slot frees, not
        a constant."""
        best = None
        for rep in self.replicas:
            if not rep.live:
                continue
            s = rep.snapshot
            t_tok = (s["decode_s"] / s["decode_tokens"]
                     if s.get("decode_tokens") else 0.01)
            est = rep.pending * t_tok
            best = est if best is None else min(best, est)
        return max(1, int(np.ceil(best))) if best else 1

    # -- drain / re-home ------------------------------------------------
    def _requeue_target(self, n: int = 1) -> Optional[Replica]:
        """Least-loaded live replica for a drain re-home; capacity
        preferred, but an over-cap live replica still beats dropping a
        request (its engine-side queue absorbs the overflow)."""
        cands = [rep for rep in self.replicas
                 if rep.live and rep.has_capacity(n)]
        if not cands:
            cands = [rep for rep in self.replicas if rep.live]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.depth(), r.occupancy(), r.id))

    async def _resubmit(self, req, on_done) -> bool:
        on_done = on_done or (lambda r: None)
        for _ in range(len(self.replicas)):
            target = self._requeue_target()
            if target is None:
                break
            target.pending += 1
            self._owner[id(req)] = target
            try:
                await asyncio.wrap_future(target.driver.submit([req],
                                                               on_done))
                return True
            except RuntimeError:        # target died mid-re-home: next
                target.pending -= 1
                self._owner.pop(id(req), None)
        # no healthy replica anywhere: fail the request LOUDLY (watcher
        # fires, budgets release) — never silently drop it
        req.done = True
        req.cancelled = True
        try:
            on_done(req)
        except Exception:
            pass
        return False

    async def drain(self, index: int) -> int:
        """Drain replica `index`: no new dispatches land on it, its
        not-yet-started queue is re-homed onto healthy replicas, and
        its in-flight requests finish where they run.  Returns the
        number of requests re-homed.  The driver stays up (serving its
        tail); stop it afterwards if the replica is being retired."""
        rep = self.replicas[index]
        rep.draining = True
        self.counters["drains"] += 1
        if not rep.alive:
            return 0
        try:
            pulled = await asyncio.wrap_future(rep.driver.extract_queued())
        except RuntimeError:
            return 0
        requeued = 0
        for req, on_done in pulled:
            old = self._owner.pop(id(req), None)
            if old is not None:
                old.pending -= 1
            if await self._resubmit(req, on_done):
                requeued += 1
                self.counters["requeued"] += 1
            else:
                self.counters["requeue_failed"] += 1
        return requeued

    # -- metrics --------------------------------------------------------
    def policy_stats(self) -> Dict[str, int]:
        if isinstance(self.policy, PrefixAffinityPolicy):
            return {"affinity_hits": self.policy.hits,
                    "affinity_misses": self.policy.misses}
        return {}

    async def fleet_metrics(self) -> Dict:
        """Aggregate + per-replica metrics payload.  A drained or dead
        replica yields its router-side entry (state, counters, last
        snapshot) instead of a KeyError; the aggregate covers live
        replicas only."""
        per: Dict[str, Dict] = {}
        summaries, hists, digests = [], [], []
        n_running = n_queued = kv_free = 0
        for rep in self.replicas:
            entry = rep.describe()
            if rep.alive:
                try:
                    snap = await asyncio.wrap_future(rep.driver.call(
                        lambda eng: {
                            "engine": eng.summary(),
                            "histograms": eng.telemetry.histograms(),
                            "digests": eng.telemetry.digests(),
                            "n_running": eng.n_running,
                            "n_queued": eng.scheduler.n_queued,
                            "kv_pages_free": eng.cache.allocator.n_free}))
                    # sketches feed the fleet merge only — per-replica
                    # bucket maps would bloat every /metrics scrape
                    digests.append(snap.pop("digests"))
                    entry.update(snap)
                    summaries.append(snap["engine"])
                    hists.append(snap["histograms"])
                    n_running += snap["n_running"]
                    n_queued += snap["n_queued"]
                    kv_free += snap["kv_pages_free"]
                except RuntimeError:    # died between the alive check
                    entry["alive"] = False      # and the job: report it
                    entry["error"] = repr(rep.error) if rep.error else \
                        "engine driver not running"
            per[str(rep.id)] = entry
        payload = {
            "engine": aggregate_summaries(summaries, digests),
            "histograms": aggregate_histograms(hists),
            # the RESOLVED serving config (precision, kv dtype, pool
            # geometry): what the fleet is actually serving at, not
            # what the operator asked for
            "config": self.serve_config.as_dict(),
            "n_running": n_running, "n_queued": n_queued,
            "kv_pages_free": kv_free,
            "fleet": {"policy": self.policy.name,
                      "n_replicas": len(self.replicas),
                      "n_live": self.n_live,
                      "counters": dict(self.counters),
                      **self.policy_stats(),
                      "replicas": per}}
        if self.slo is not None:
            payload["slo"] = self.slo.payload()
        if not summaries:
            payload["error"] = "no live replica"
        return payload
