"""Pareto-front utilities for (latency, energy) design points."""
from __future__ import annotations

from typing import Iterable, List, Sequence, Tuple

from .simulator import SimReport


def is_dominated(a: Tuple[float, float], b: Tuple[float, float]) -> bool:
    """True if point `a` is dominated by `b` (b no worse in both, better in one)."""
    return (b[0] <= a[0] and b[1] <= a[1]) and (b[0] < a[0] or b[1] < a[1])


def pareto_front(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of non-dominated (latency, energy) points, sorted by latency."""
    idx = sorted(range(len(points)), key=lambda i: (points[i][0], points[i][1]))
    front: List[int] = []
    best_e = float("inf")
    for i in idx:
        if points[i][1] < best_e:
            front.append(i)
            best_e = points[i][1]
    return front


def pareto_reports(reports: Iterable[SimReport]) -> List[SimReport]:
    reps = list(reports)
    pts = [(r.latency_s, r.energy_j) for r in reps]
    return [reps[i] for i in pareto_front(pts)]
