"""DSE objective (paper Eq. 1):  minimize  L(h)^alpha * E(h)^(1-alpha).

`spec_decode` prices speculative decoding inside the objective, so the
Pareto fronts the GA traces can trade hardware against a software
speculation factor the same way they trade it against precision.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .hw import HWConfig
from .simulator import EdgeCIMSimulator, SimReport, SpecKnob
from .workload import SLMSpec


@dataclass(frozen=True)
class Objective:
    spec: SLMSpec
    alpha: float = 0.5
    prefill_tokens: int = 128
    gen_tokens: int = 128
    w_bits: int = 4
    a_bits: int = 8
    spec_decode: Optional[SpecKnob] = None

    def __post_init__(self):
        assert 0.0 <= self.alpha <= 1.0

    def evaluate(self, h: HWConfig,
                 sim: EdgeCIMSimulator | None = None) -> SimReport:
        sim = sim or EdgeCIMSimulator()
        return sim.generate(self.spec, h, self.prefill_tokens,
                            self.gen_tokens, self.w_bits, self.a_bits,
                            spec_decode=self.spec_decode)

    def cost(self, report: SimReport) -> float:
        """Scale-invariant latency-energy trade-off (Eq. 1)."""
        return (report.latency_s ** self.alpha) * \
               (report.energy_j ** (1.0 - self.alpha))

    def __call__(self, h: HWConfig,
                 sim: EdgeCIMSimulator | None = None) -> float:
        return self.cost(self.evaluate(h, sim))
