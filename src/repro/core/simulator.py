"""End-to-end analytical simulator for EdgeCIM decode (+ prefill estimate).

Reports latency, energy, and area for executing the decoding phase of a
decoder-only SLM on a candidate hardware configuration h — the evaluation
engine behind the DSE (paper Sec. III-A / IV).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import numpy as np

from .hw import HWConfig, TechConstants, DEFAULT_TECH, chip_area_mm2, peak_tops
from .stages import StageCost, stage_cost, stage_cost_vec
from .workload import SLMSpec, Stage


@dataclass(frozen=True)
class SpecKnob:
    """Speculative-decoding factor for the analytical model.

    Decode's bottleneck is the weight stream (one full pass per token);
    a verify step streams weights ONCE for a (k+1)-token window and
    keeps E[accepted+bonus] of them — the same arithmetic-intensity
    lever the DSE prices precision with, so Pareto fronts can price
    spec decode too.  Per verify step:

      weight_elems, kv_stream   x1       (shared across the window; the
                                          multi-query kernel makes one
                                          pass over the pages)
      macs, vector, writeback   x(k+1)   (every window position computes)

    plus `draft_cost_ratio` x k target-token-equivalents of drafting
    (0 for the model-free n-gram drafter; ~the parameter ratio for a
    small draft model).  `accept_rate` is the measured per-token
    acceptance probability (spec_bench.py reports it), modeled i.i.d.
    """
    k: int = 4
    accept_rate: float = 0.7
    draft_cost_ratio: float = 0.0

    def tokens_per_step(self) -> float:
        """E[tokens emitted per verify step] = (1 - a^(k+1)) / (1 - a)
        (accepted prefix of i.i.d. Bernoulli(a) draws, plus the bonus)."""
        a = min(max(self.accept_rate, 0.0), 1.0)
        if a >= 1.0:
            return float(self.k + 1)
        return float((1.0 - a ** (self.k + 1)) / (1.0 - a))


@dataclass(frozen=True)
class SimReport:
    """Simulation result for generating `gen_tokens` after `prefill_tokens`."""
    model: str
    hw: HWConfig
    w_bits: int
    a_bits: int
    prefill_tokens: int
    gen_tokens: int
    latency_s: float
    energy_j: float
    area_mm2: float
    stage_seconds: Dict[str, float]
    stage_joules: Dict[str, float]
    spec_decode: Optional[SpecKnob] = None

    @property
    def tokens_per_s(self) -> float:
        return self.gen_tokens / self.latency_s

    @property
    def tokens_per_j(self) -> float:
        return self.gen_tokens / self.energy_j

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_j

    def peak_tops(self, tech: TechConstants = DEFAULT_TECH) -> float:
        return peak_tops(self.hw, min(self.w_bits, self.a_bits), tech)

    def tops_per_w_per_mm2(self, tech: TechConstants = DEFAULT_TECH) -> float:
        avg_power = self.energy_j / self.latency_s
        return self.peak_tops(tech) / avg_power / self.area_mm2


class EdgeCIMSimulator:
    """Dataflow-aware analytical simulator (Sec. IV): captures the
    PE/tile/cluster/chip hierarchy, partitioning, active-tile pipelining,
    inter-stage dependencies, DRAM transfers, and compute/transfer overlap."""

    def __init__(self, tech: TechConstants = DEFAULT_TECH):
        self.tech = tech

    # ------------------------------------------------------------------
    def decode_token(self, spec: SLMSpec, h: HWConfig, seq: float,
                     w_bits: int = 4, a_bits: int = 8) -> StageCost:
        """Exact cost of one decode step at KV length `seq` (all layers)."""
        total = StageCost(0.0, 0.0)
        stages = spec.decode_stages(seq)
        mult = spec.layer_multiplicity()
        assert len(stages) == len(mult)
        for st, m in zip(stages, mult):
            total = total + stage_cost(st, h, w_bits, a_bits, self.tech).scale(m)
        total = total + stage_cost(spec.embed_stage(), h, w_bits, a_bits, self.tech)
        total = total + stage_cost(spec.head_stage(), h, w_bits, a_bits, self.tech)
        return total

    # ------------------------------------------------------------------
    def generate(self, spec: SLMSpec, h: HWConfig, prefill_tokens: int = 128,
                 gen_tokens: int = 128, w_bits: int = 4, a_bits: int = 8,
                 spec_decode: Optional[SpecKnob] = None) -> SimReport:
        """Full decoding run: token t sees KV length prefill + t.

        `spec_decode` prices speculative decoding: each emitted token
        costs 1/E of a (k+1)-query verify step (weights/KV streamed
        once, compute x(k+1)) plus k/E x draft_cost_ratio plain-token
        equivalents of drafting."""
        tech = self.tech
        area = chip_area_mm2(h, tech)
        if spec_decode is not None:
            E = spec_decode.tokens_per_step()
            kq = spec_decode.k + 1              # window width per verify
            draft_tok = spec_decode.k * spec_decode.draft_cost_ratio / E

            def verify_stage(st: Stage) -> Stage:
                return replace(st, macs=st.macs * kq,
                               vector_ops=st.vector_ops * kq,
                               writeback_elems=st.writeback_elems * kq)

            def spec_mix(plain_s, plain_j, ver_s, ver_j):
                return (ver_s / E + draft_tok * plain_s,
                        ver_j / E + draft_tok * plain_j)

        # ---- seq-independent stages: cost once, multiply by gen_tokens ----
        seqs = prefill_tokens + np.arange(gen_tokens, dtype=np.float64)
        stage_s: Dict[str, float] = {}
        stage_j: Dict[str, float] = {}
        total_s = 0.0
        total_j = 0.0

        stages0 = spec.decode_stages(float(seqs[0]))
        mult = spec.layer_multiplicity()
        for idx, (st, m) in enumerate(zip(stages0, mult)):
            if st.kv_stream_elems and st.name in ("attention",):
                # KV grows with seq: vectorize over all generated tokens
                kv = np.array([
                    spec.decode_stages(float(s))[idx].kv_stream_elems
                    for s in (seqs[0], seqs[-1])
                ])
                # kv stream is linear in seq -> interpolate exactly
                kv_all = np.interp(seqs, [seqs[0], seqs[-1]], kv)
                ratio = kv_all / max(st.kv_stream_elems, 1.0)
                s_vec, j_vec = stage_cost_vec(
                    np.full_like(seqs, st.weight_elems), kv_all,
                    st.macs * ratio, st.vector_ops * ratio,
                    np.full_like(seqs, st.writeback_elems),
                    h, w_bits, a_bits, tech)
                if spec_decode is not None:
                    v_s, v_j = stage_cost_vec(
                        np.full_like(seqs, st.weight_elems), kv_all,
                        st.macs * ratio * kq, st.vector_ops * ratio * kq,
                        np.full_like(seqs, st.writeback_elems * kq),
                        h, w_bits, a_bits, tech)
                    s_vec, j_vec = spec_mix(s_vec, j_vec, v_s, v_j)
                s_sum, j_sum = float(s_vec.sum()) * m, float(j_vec.sum()) * m
            else:
                c = stage_cost(st, h, w_bits, a_bits, tech).scale(m)
                sec_t, j_t = c.seconds, c.joules
                if spec_decode is not None:
                    cv = stage_cost(verify_stage(st), h, w_bits, a_bits,
                                    tech).scale(m)
                    sec_t, j_t = spec_mix(sec_t, j_t, cv.seconds, cv.joules)
                s_sum, j_sum = sec_t * gen_tokens, j_t * gen_tokens
            stage_s[st.name] = stage_s.get(st.name, 0.0) + s_sum
            stage_j[st.name] = stage_j.get(st.name, 0.0) + j_sum
            total_s += s_sum
            total_j += j_sum

        for st in (spec.embed_stage(), spec.head_stage()):
            c = stage_cost(st, h, w_bits, a_bits, tech)
            sec_t, j_t = c.seconds, c.joules
            if spec_decode is not None:
                cv = stage_cost(verify_stage(st), h, w_bits, a_bits, tech)
                sec_t, j_t = spec_mix(sec_t, j_t, cv.seconds, cv.joules)
            stage_s[st.name] = sec_t * gen_tokens
            stage_j[st.name] = j_t * gen_tokens
            total_s += sec_t * gen_tokens
            total_j += j_t * gen_tokens

        # ---- static (leakage) energy over the whole run --------------------
        p_static = area * tech.p_static_mm2
        e_static = p_static * total_s
        stage_j["static"] = e_static
        total_j += e_static

        return SimReport(
            model=spec.name, hw=h, w_bits=w_bits, a_bits=a_bits,
            prefill_tokens=prefill_tokens, gen_tokens=gen_tokens,
            latency_s=total_s, energy_j=total_j, area_mm2=area,
            stage_seconds=stage_s, stage_joules=stage_j,
            spec_decode=spec_decode,
        )

    # ------------------------------------------------------------------
    def prefill(self, spec: SLMSpec, h: HWConfig, prefill_tokens: int,
                w_bits: int = 4, a_bits: int = 8) -> StageCost:
        """Prefill estimate (GEMM regime): weights loaded once per layer and
        reused across the prompt; compute becomes multiplicative.  Used for
        the Fig. 2-style decode-dominance profiling, not for the DSE
        objective (the paper optimizes decode)."""
        tech = self.tech
        P = prefill_tokens
        total = StageCost(0.0, 0.0)
        stages = spec.decode_stages(P / 2.0)  # avg causal KV length
        mult = spec.layer_multiplicity()
        from .hw import stream_bandwidth
        from .macro import pass_cycles as _pc
        bw = stream_bandwidth(h, tech)
        for st, m in zip(stages, mult):
            w_bytes = st.weight_elems * w_bits / 8.0
            t_load = w_bytes / bw
            # P bit-serial passes per partition (inputs streamed through)
            macs = st.macs * P
            passes = macs / max(h.active_pes() * 256.0, 1.0)
            t_compute = passes * _pc(a_bits, tech) / tech.f_clk
            sec = max(t_load, t_compute) + st.vector_ops * P / tech.vector_lanes / tech.f_clk
            e = (w_bytes * 8.0 * (tech.e_dram_bit + 3 * tech.e_bus_bit)
                 + macs * tech.e_mac(min(w_bits, a_bits))
                 + st.vector_ops * P * tech.e_vec_op)
            total = total + StageCost(sec, e).scale(m)
        return total


def decode_fraction(spec: SLMSpec, h: HWConfig, prefill_tokens: int,
                    gen_tokens: int, w_bits: int = 4, a_bits: int = 8,
                    sim: EdgeCIMSimulator | None = None) -> float:
    """Fraction of end-to-end time spent decoding (paper Fig. 2: ~96.6%)."""
    sim = sim or EdgeCIMSimulator()
    pre = sim.prefill(spec, h, prefill_tokens, w_bits, a_bits)
    rep = sim.generate(spec, h, prefill_tokens, gen_tokens, w_bits, a_bits)
    return rep.latency_s / (rep.latency_s + pre.seconds)
