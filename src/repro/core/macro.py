"""Bit-serial SRAM DCIM macro model (the PE of EdgeCIM, after [25]).

A 16x16 weight-stationary macro: 16 input rows broadcast one input *bit*
per cycle; 16 columns each hold a 16-element weight vector and produce a
1b x Wb partial product per cycle, accumulated with shift-and-add across
`input_bits` cycles.  Higher precision = more input cycles (precision
reconfigurability, Sec. II-B): INT4 inputs -> 4 cycles/pass, INT8 -> 8.

Weight precision is handled by column combining: an INT8 weight occupies
two 4-bit column slices whose outputs are fused by shift-and-add, halving
effective columns.  We model this as an occupancy factor.
"""
from __future__ import annotations

from dataclasses import dataclass

from .hw import MACRO_COLS, MACRO_ROWS, TechConstants, DEFAULT_TECH


@dataclass(frozen=True)
class MacroGeometry:
    rows: int = MACRO_ROWS
    cols: int = MACRO_COLS
    weight_bits_per_cell_col: int = 4  # native column slice width

    def effective_cols(self, weight_bits: int) -> int:
        """Columns available after shift-add column fusion for wide weights."""
        slices = max(1, weight_bits // self.weight_bits_per_cell_col)
        return max(1, self.cols // slices)


DEFAULT_MACRO = MacroGeometry()


def pass_cycles(input_bits: int, tech: TechConstants = DEFAULT_TECH) -> int:
    """Cycles for one GEMV pass: one cycle per input bit + pipeline drain."""
    drain = 2 * tech.adder_tree_stage_cycles  # shift-add + output latch
    return input_bits + drain


def pass_latency(input_bits: int, tech: TechConstants = DEFAULT_TECH) -> float:
    return pass_cycles(input_bits, tech) / tech.f_clk


def pass_macs(geom: MacroGeometry = DEFAULT_MACRO) -> int:
    """MACs completed by one macro per pass (full 16x16 tile)."""
    return geom.rows * geom.cols


def macro_energy(n_macs: int, bits: int, tech: TechConstants = DEFAULT_TECH) -> float:
    """Dynamic energy of `n_macs` bit-serial MACs at the given precision."""
    return n_macs * tech.e_mac(bits)


def macro_write_energy(n_weights: int, weight_bits: int,
                       tech: TechConstants = DEFAULT_TECH) -> float:
    """Energy to (re)load weights into the SRAM cells (weight-stationary
    means this happens once per streamed partition)."""
    return n_weights * weight_bits * tech.e_buf_bit
