"""Genetic-algorithm design-space exploration (paper Sec. IV).

Exactly the paper's recipe: population 20, 50 generations, simulated
binary crossover (SBX, crossover probability = 1) and polynomial mutation
with distribution index eta = 3, over the ~3.1e6-point space H.

Genome: 9 real genes in [0, 1], each decoded to its discrete choice list
(Cv, Ch, Tv_act, Th_act, M, P^2, three bus widths).  Real-coded SBX /
polynomial mutation operate on the unit cube; decoding rounds to the
nearest valid choice — the standard discrete-SBX construction.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .hw import (ACTIVE_TILE_CHOICES, BUS_WIDTH_CHOICES, CLUSTER_CHOICES,
                 HWConfig, PE_COUNT_CHOICES, TILE_MULT_CHOICES)
from .objective import Objective
from .simulator import EdgeCIMSimulator, SimReport

GENE_CHOICES: Tuple[Sequence[int], ...] = (
    CLUSTER_CHOICES, CLUSTER_CHOICES,
    ACTIVE_TILE_CHOICES, ACTIVE_TILE_CHOICES,
    TILE_MULT_CHOICES, PE_COUNT_CHOICES,
    BUS_WIDTH_CHOICES, BUS_WIDTH_CHOICES, BUS_WIDTH_CHOICES,
)
N_GENES = len(GENE_CHOICES)


def decode(genome: np.ndarray) -> HWConfig:
    vals = []
    for g, choices in zip(genome, GENE_CHOICES):
        i = min(len(choices) - 1, int(np.clip(g, 0.0, 1.0) * len(choices)))
        vals.append(choices[i])
    return HWConfig(*vals)


def encode(h: HWConfig) -> np.ndarray:
    raw = h.as_tuple()
    g = np.empty(N_GENES)
    for k, (v, choices) in enumerate(zip(raw, GENE_CHOICES)):
        g[k] = (choices.index(v) + 0.5) / len(choices)
    return g


def sbx_crossover(p1: np.ndarray, p2: np.ndarray, rng: np.random.Generator,
                  eta: float = 3.0) -> Tuple[np.ndarray, np.ndarray]:
    """Simulated binary crossover (Deb & Agrawal 1995), per-gene."""
    u = rng.random(N_GENES)
    beta = np.where(u <= 0.5,
                    (2.0 * u) ** (1.0 / (eta + 1.0)),
                    (1.0 / (2.0 * (1.0 - u))) ** (1.0 / (eta + 1.0)))
    c1 = 0.5 * ((1 + beta) * p1 + (1 - beta) * p2)
    c2 = 0.5 * ((1 - beta) * p1 + (1 + beta) * p2)
    return np.clip(c1, 0.0, 1.0), np.clip(c2, 0.0, 1.0)


def polynomial_mutation(g: np.ndarray, rng: np.random.Generator,
                        eta: float = 3.0, p_mut: Optional[float] = None
                        ) -> np.ndarray:
    """Polynomial mutation (Deb), distribution index eta = 3 per the paper."""
    if p_mut is None:
        p_mut = 1.0 / N_GENES
    out = g.copy()
    mask = rng.random(N_GENES) < p_mut
    u = rng.random(N_GENES)
    delta = np.where(u < 0.5,
                     (2.0 * u) ** (1.0 / (eta + 1.0)) - 1.0,
                     1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta + 1.0)))
    out[mask] = np.clip(out[mask] + delta[mask], 0.0, 1.0)
    return out


@dataclass
class GAResult:
    best: HWConfig
    best_report: SimReport
    best_cost: float
    history: List[float] = field(default_factory=list)      # best cost/gen
    evaluated: List[Tuple[HWConfig, float, float, float]] = \
        field(default_factory=list)                          # (h, L, E, cost)


class GeneticDSE:
    """The paper's optimization engine."""

    def __init__(self, objective: Objective, pop_size: int = 20,
                 generations: int = 50, eta_crossover: float = 3.0,
                 eta_mutation: float = 3.0, p_crossover: float = 1.0,
                 tournament_k: int = 2, elitism: int = 2,
                 sim: Optional[EdgeCIMSimulator] = None,
                 seed: int = 0):
        self.obj = objective
        self.pop_size = pop_size
        self.generations = generations
        self.eta_c = eta_crossover
        self.eta_m = eta_mutation
        self.p_c = p_crossover
        self.tournament_k = tournament_k
        self.elitism = elitism
        self.sim = sim or EdgeCIMSimulator()
        self.rng = np.random.default_rng(seed)
        self._cache: dict = {}

    # ------------------------------------------------------------------
    def _evaluate(self, h: HWConfig, result: GAResult) -> float:
        key = h.as_tuple()
        if key in self._cache:
            return self._cache[key][0]
        rep = self.obj.evaluate(h, self.sim)
        cost = self.obj.cost(rep)
        self._cache[key] = (cost, rep)
        result.evaluated.append((h, rep.latency_s, rep.energy_j, cost))
        return cost

    def _tournament(self, pop: List[np.ndarray], costs: np.ndarray
                    ) -> np.ndarray:
        idx = self.rng.integers(0, len(pop), size=self.tournament_k)
        return pop[int(idx[np.argmin(costs[idx])])]

    # ------------------------------------------------------------------
    def run(self) -> GAResult:
        result = GAResult(best=HWConfig(), best_report=None, best_cost=math.inf)  # type: ignore
        pop = [self.rng.random(N_GENES) for _ in range(self.pop_size)]

        for _gen in range(self.generations):
            configs = [decode(g) for g in pop]
            costs = np.array([self._evaluate(h, result) for h in configs])

            order = np.argsort(costs)
            if costs[order[0]] < result.best_cost:
                best_h = configs[order[0]]
                result.best_cost = float(costs[order[0]])
                result.best = best_h
                result.best_report = self._cache[best_h.as_tuple()][1]
            result.history.append(result.best_cost)

            # next generation: elitism + SBX + polynomial mutation
            next_pop: List[np.ndarray] = [pop[i].copy() for i in order[:self.elitism]]
            while len(next_pop) < self.pop_size:
                p1 = self._tournament(pop, costs)
                p2 = self._tournament(pop, costs)
                if self.rng.random() < self.p_c:
                    c1, c2 = sbx_crossover(p1, p2, self.rng, self.eta_c)
                else:
                    c1, c2 = p1.copy(), p2.copy()
                next_pop.append(polynomial_mutation(c1, self.rng, self.eta_m))
                if len(next_pop) < self.pop_size:
                    next_pop.append(polynomial_mutation(c2, self.rng, self.eta_m))
            pop = next_pop

        return result


def run_dse(spec, alpha: float = 1.0, w_bits: int = 4, a_bits: int = 8,
            prefill_tokens: int = 128, gen_tokens: int = 128,
            seed: int = 0, pop_size: int = 20, generations: int = 50
            ) -> GAResult:
    """One-call DSE entry point used by benchmarks and the launcher."""
    obj = Objective(spec=spec, alpha=alpha, prefill_tokens=prefill_tokens,
                    gen_tokens=gen_tokens, w_bits=w_bits, a_bits=a_bits)
    ga = GeneticDSE(obj, pop_size=pop_size, generations=generations, seed=seed)
    return ga.run()
