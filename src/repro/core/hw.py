"""Hardware design space and 65nm technology constants for the EdgeCIM simulator.

The paper (Sec. IV) defines the search space H:
  * vertical/horizontal clusters          C_v, C_h      in {1..5}
  * active tiles per cluster              T_A = T_v_act * T_h_act,
                                          T_v_act, T_h_act in {2..8}
  * total tiles per cluster               T_total = M * T_A, M in {1..8}
  * PEs per tile                          P^2 in {4, 9, 16, 25, 36}
  * bus widths (inter-cluster, inter-tile, intra-tile) in {512,1024,2048,4096} bits
  => 25 * 49 * 8 * 5 * 64 = 3.136e6 configurations ("~3.1e6" in the paper).

Technology constants are calibrated against the paper's reported numbers
(Sec. V) because the authors' C++ simulator constants are unpublished.
Provenance / calibration notes inline; the calibration benchmark is
benchmarks/fig9_slm_suite.py and the tolerance tests are in
tests/test_core_simulator.py.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Iterator

# ----------------------------------------------------------------------------
# Search space (exact paper definition)
# ----------------------------------------------------------------------------
CLUSTER_CHOICES = (1, 2, 3, 4, 5)
ACTIVE_TILE_CHOICES = (2, 3, 4, 5, 6, 7, 8)
TILE_MULT_CHOICES = (1, 2, 3, 4, 5, 6, 7, 8)
PE_COUNT_CHOICES = (4, 9, 16, 25, 36)          # P^2
BUS_WIDTH_CHOICES = (512, 1024, 2048, 4096)    # bits

MACRO_ROWS = 16   # each PE is a 16x16 SRAM bit-serial DCIM macro [25]
MACRO_COLS = 16


@dataclass(frozen=True)
class HWConfig:
    """One point h in the hardware design space H."""
    c_v: int = 2
    c_h: int = 3
    t_act_v: int = 4
    t_act_h: int = 2
    m_mult: int = 1          # T_total = m_mult * T_A
    pe_count: int = 4        # P^2, PEs per tile
    bus_ic: int = 4096       # inter-cluster bus width (bits)
    bus_it: int = 4096       # inter-tile bus width (bits)
    bus_intra: int = 4096    # intra-tile bus width (bits)

    # -- derived ------------------------------------------------------------
    @property
    def n_clusters(self) -> int:
        return self.c_v * self.c_h

    @property
    def t_active(self) -> int:
        return self.t_act_v * self.t_act_h

    @property
    def t_total(self) -> int:
        return self.m_mult * self.t_active

    @property
    def pe_side(self) -> int:
        return int(round(self.pe_count ** 0.5))

    @property
    def macs_per_pe_pass(self) -> int:
        return MACRO_ROWS * MACRO_COLS

    def active_pes(self) -> int:
        """PEs computing concurrently chip-wide (active tiles only)."""
        return self.n_clusters * self.t_active * self.pe_count

    def total_pes(self) -> int:
        return self.n_clusters * self.t_total * self.pe_count

    def active_weight_capacity(self) -> int:
        """INT elements held by the active tiles of one cluster."""
        return self.t_active * self.pe_count * MACRO_ROWS * MACRO_COLS

    def validate(self) -> None:
        assert self.c_v in CLUSTER_CHOICES and self.c_h in CLUSTER_CHOICES
        assert self.t_act_v in ACTIVE_TILE_CHOICES
        assert self.t_act_h in ACTIVE_TILE_CHOICES
        assert self.m_mult in TILE_MULT_CHOICES
        assert self.pe_count in PE_COUNT_CHOICES
        for b in (self.bus_ic, self.bus_it, self.bus_intra):
            assert b in BUS_WIDTH_CHOICES

    def as_tuple(self) -> tuple:
        return dataclasses.astuple(self)


def search_space_size() -> int:
    return (len(CLUSTER_CHOICES) ** 2 * len(ACTIVE_TILE_CHOICES) ** 2 *
            len(TILE_MULT_CHOICES) * len(PE_COUNT_CHOICES) *
            len(BUS_WIDTH_CHOICES) ** 3)


def iter_search_space() -> Iterator[HWConfig]:
    """Exhaustive iterator (3.1M points) — used only by tests on slices."""
    for cv, ch, tav, tah, m, p2, bic, bit_, bintra in itertools.product(
            CLUSTER_CHOICES, CLUSTER_CHOICES, ACTIVE_TILE_CHOICES,
            ACTIVE_TILE_CHOICES, TILE_MULT_CHOICES, PE_COUNT_CHOICES,
            BUS_WIDTH_CHOICES, BUS_WIDTH_CHOICES, BUS_WIDTH_CHOICES):
        yield HWConfig(cv, ch, tav, tah, m, p2, bic, bit_, bintra)


# ----------------------------------------------------------------------------
# Technology constants (65nm, calibrated)
# ----------------------------------------------------------------------------
@dataclass(frozen=True)
class TechConstants:
    """65nm CMOS + LPDDR5X system constants.

    Calibration provenance:
      f_clk           : bit-serial DCIM macros at 65nm; [25] runs at 22nm —
                        conservatively derated to 500 MHz.
      dram_*          : LPDDR5X-9600, 16 channels x 16-bit (paper Sec. IV):
                        9600 MT/s * 2 B = 19.2 GB/s/ch -> 307.2 GB/s peak;
                        utilization 0.80 (typical LPDDR efficiency) gives the
                        ~246 GB/s effective stream rate that reproduces the
                        paper's LLaMA3.2-1B 400 tok/s headline.
      e_dram_bit      : 0.60 pJ/bit — interface-level transfer energy. The
                        paper's tokens/J figures imply sub-datasheet DRAM
                        energy accounting (device-core energy excluded); we
                        match their accounting and note it in EXPERIMENTS.md.
      e_mac_int8      : [25] reports 89 TOPS/W INT8 at 22nm => 11.2 fJ/op;
                        scaled (65/22)^2 for capacitance+voltage => ~0.196
                        pJ/MAC (2 ops/MAC). INT4 bit-serial halves input
                        toggling => 0.098 pJ/MAC.
      a_pe_mm2        : fits the paper's h* area of 11.83 mm^2 for
                        (6 clusters x 8 tiles x 4 PEs) with the buffer model.
      sram (CACTI-ish): 65nm 6T SRAM ~0.525 um^2/bit + periphery factor.
      p_static_mm2    : 15 mW/mm^2 leakage at 65nm (CACTI-6.0 ballpark).
      bus             : f_bus = f_clk; energy from Dally et al. [34]
                        (~0.1 pJ/bit/mm on-chip wire, ~2 mm avg hop).
    """
    f_clk: float = 500e6                 # Hz, macro + vector units
    f_bus: float = 500e6                 # Hz, on-chip buses
    adder_tree_stage_cycles: int = 1     # pipelined adder-tree stage

    dram_bw_peak: float = 307.2e9        # B/s (LPDDR5X-9600 x16 ch)
    dram_util: float = 0.80
    dram_latency: float = 60e-9          # first-word latency per burst
    e_dram_bit: float = 0.60e-12         # J/bit

    e_mac_int8: float = 0.196e-12        # J/MAC
    e_mac_int4: float = 0.098e-12        # J/MAC

    e_buf_bit: float = 0.012e-12         # J/bit SRAM buffer access (65nm)
    e_bus_bit: float = 0.05e-12          # J/bit/hop on-chip (~0.1 pJ/bit/mm [34], short hops)
    e_vec_op: float = 0.8e-12            # J per vector-unit elementwise op

    a_pe_mm2: float = 0.011              # mm^2 per 16x16 DCIM macro + logic
    a_sram_mm2_per_kb: float = 0.0043    # mm^2 per KB (65nm, w/ periphery)
    a_aux_mm2: float = 1.2               # softmax/norm/act/quant units
    a_noc_mm2_per_cluster: float = 0.15

    p_static_mm2: float = 15e-3          # W/mm^2 leakage

    vector_lanes: int = 64               # lanes of each auxiliary unit

    # on-chip buffer sizing (bytes)
    global_buffer_kb: int = 1024
    cluster_buffer_kb: int = 128
    tile_buffer_kb: int = 8

    def dram_bw(self) -> float:
        return self.dram_bw_peak * self.dram_util

    def e_mac(self, bits: int) -> float:
        return self.e_mac_int4 if bits <= 4 else self.e_mac_int8


DEFAULT_TECH = TechConstants()


def chip_area_mm2(h: HWConfig, tech: TechConstants = DEFAULT_TECH) -> float:
    """Area model: PEs + buffer hierarchy + aux units + NoC."""
    pe_area = h.total_pes() * tech.a_pe_mm2
    buf_kb = (tech.global_buffer_kb
              + h.n_clusters * tech.cluster_buffer_kb
              + h.n_clusters * h.t_total * tech.tile_buffer_kb)
    buf_area = buf_kb * tech.a_sram_mm2_per_kb
    noc_area = h.n_clusters * tech.a_noc_mm2_per_cluster
    return pe_area + buf_area + tech.a_aux_mm2 + noc_area


def peak_tops(h: HWConfig, bits: int, tech: TechConstants = DEFAULT_TECH) -> float:
    """Peak INT throughput (2 ops per MAC) of the active tiles.

    Bit-serial: one input bit per cycle => a full `bits`-bit GEMV pass over
    the 16x16 macro takes `bits` cycles.
    """
    passes_per_s = tech.f_clk / bits
    macs_per_s = h.active_pes() * h.macs_per_pe_pass * passes_per_s
    return 2.0 * macs_per_s / 1e12


def stream_bandwidth(h: HWConfig, tech: TechConstants = DEFAULT_TECH) -> float:
    """Effective weight-stream bandwidth DRAM -> active tiles (B/s).

    The 2D hierarchical bus (Sec. III-B): one inter-cluster trunk from the
    global buffer, per-cluster inter-tile buses in parallel, per-active-tile
    intra-tile buses in parallel. The stream rate is the min of DRAM and
    every bus level's aggregate capacity along the broadcast path.
    """
    bw_dram = tech.dram_bw()
    bw_ic = h.bus_ic / 8.0 * tech.f_bus
    bw_it = h.n_clusters * h.bus_it / 8.0 * tech.f_bus
    bw_intra = h.n_clusters * h.t_active * h.bus_intra / 8.0 * tech.f_bus
    return min(bw_dram, bw_ic, bw_it, bw_intra)
