"""EdgeCIM core: the paper's contribution — analytical CIM simulator + GA DSE.

Public API:
    HWConfig, TechConstants      hardware design point / 65nm constants
    SLMSpec                      workload description
    EdgeCIMSimulator, SimReport  analytical simulation
    Objective                    L^a * E^(1-a) cost (Eq. 1)
    GeneticDSE, run_dse          the paper's GA optimization engine
    pareto_front                 Pareto utilities
"""
from .hw import (HWConfig, TechConstants, DEFAULT_TECH, chip_area_mm2,
                 peak_tops, stream_bandwidth, search_space_size)
from .workload import SLMSpec, Stage, make_dense_spec
from .simulator import (EdgeCIMSimulator, SimReport, SpecKnob,
                        decode_fraction)
from .objective import Objective
from .dse import GeneticDSE, GAResult, run_dse, decode, encode
from .pareto import pareto_front, pareto_reports

__all__ = [
    "HWConfig", "TechConstants", "DEFAULT_TECH", "chip_area_mm2", "peak_tops",
    "stream_bandwidth", "search_space_size", "SLMSpec", "Stage",
    "make_dense_spec", "EdgeCIMSimulator", "SimReport", "SpecKnob",
    "decode_fraction",
    "Objective", "GeneticDSE", "GAResult", "run_dse", "decode", "encode",
    "pareto_front", "pareto_reports",
]
