"""SLM workload description for the EdgeCIM analytical simulator.

Turns a decoder-only SLM architecture into per-layer *stage* GEMV
descriptors matching the paper's decode pipeline (Fig. 5 / Sec. III-C):

    Projection -> Attention -> Linear -> FFN   (+ embedding, + LM head)

Supports the paper's 12 SLM benchmarks (dense GQA/MHA transformers) and
the assigned-architecture families: MLA (latent KV cache), MoE (active
experts streamed), and SSM/hybrid blocks (recurrent state streamed in
place of the KV cache — see DESIGN.md SS4: EdgeCIM's attention blocking is
inapplicable without a KV cache; the state stream takes its place).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class Stage:
    """Analytical cost terms of one pipeline stage for ONE decode token.

    weight_elems:    INT weight elements streamed from DRAM this token
    macs:            multiply-accumulates performed on the macros
    kv_stream_elems: KV-cache / recurrent-state elements streamed (activation
                     precision), overlapped with compute like weights
    writeback_elems: elements written back to DRAM (KV append, state update)
    vector_ops:      elementwise ops on the auxiliary units (softmax, norm,
                     activation, elementwise-mul, quantize, transpose)
    n_units:         independent mapping units (heads/clusters parallelism
                     cap - informs pipeline fill count)
    """
    name: str
    weight_elems: float = 0.0
    macs: float = 0.0
    kv_stream_elems: float = 0.0
    writeback_elems: float = 0.0
    vector_ops: float = 0.0
    n_units: int = 1


@dataclass(frozen=True)
class SLMSpec:
    """Architecture description sufficient for stage-cost generation."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    ffn_gated: bool = True              # SwiGLU/GeGLU: 3 mats; else 2 (GELU)
    qkv_bias: bool = False
    tie_embeddings: bool = True

    # attention flavor
    attn_kind: str = "gqa"              # gqa | mla | none
    mla_kv_lora: int = 0
    mla_rope_dim: int = 0
    mla_q_nope: int = 0

    # MoE
    n_experts: int = 0                  # routed experts (0 = dense)
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0

    # SSM / hybrid: fraction of layers that are recurrent-state blocks
    n_ssm_layers: int = 0
    ssm_state_elems_per_layer: float = 0.0   # recurrent state size (elements)
    ssm_weight_elems_per_layer: float = 0.0  # in-projection/conv/out weights
    ssm_macs_per_layer: float = 0.0

    # local/global attention (gemma-style): window caps the attended KV
    local_window: int = 0               # 0 = all layers global
    local_ratio: float = 0.0            # fraction of attn layers that are local

    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    # ------------------------------------------------------------------
    # parameter accounting
    # ------------------------------------------------------------------
    def attn_layer_weights(self) -> float:
        d, hd = self.d_model, self.hd()
        if self.attn_kind == "mla":
            w_q = d * self.n_heads * (self.mla_q_nope + self.mla_rope_dim)
            w_dkv = d * (self.mla_kv_lora + self.mla_rope_dim)
            w_uk = self.n_heads * self.mla_q_nope * self.mla_kv_lora
            w_uv = self.n_heads * hd * self.mla_kv_lora
            w_o = self.n_heads * hd * d
            return w_q + w_dkv + w_uk + w_uv + w_o
        w_q = d * self.n_heads * hd
        w_kv = 2 * d * self.n_kv_heads * hd
        w_o = self.n_heads * hd * d
        return w_q + w_kv + w_o

    def ffn_layer_weights_active(self) -> float:
        """FFN weights streamed per token (MoE: only active experts)."""
        n_mats = 3 if self.ffn_gated else 2
        if self.n_experts > 0:
            active = self.top_k + self.n_shared_experts
            router = self.d_model * self.n_experts
            return active * n_mats * self.d_model * self.d_ff_expert + router
        return n_mats * self.d_model * self.d_ff

    def ffn_layer_weights_total(self) -> float:
        n_mats = 3 if self.ffn_gated else 2
        if self.n_experts > 0:
            total = self.n_experts + self.n_shared_experts
            router = self.d_model * self.n_experts
            return total * n_mats * self.d_model * self.d_ff_expert + router
        return n_mats * self.d_model * self.d_ff

    def n_attn_layers(self) -> int:
        return self.n_layers - self.n_ssm_layers

    def total_params(self) -> float:
        """Total stored parameters (for model-size / DRAM-footprint checks)."""
        per_attn = self.attn_layer_weights() + self.ffn_layer_weights_total()
        ssm = self.n_ssm_layers * (self.ssm_weight_elems_per_layer +
                                   (0 if self.n_ssm_layers == 0 else 0))
        embed = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_attn_layers() * per_attn + ssm + embed

    def active_params_per_token(self) -> float:
        """Weights streamed from DRAM per decode token (the bandwidth wall)."""
        per_attn = self.attn_layer_weights() + self.ffn_layer_weights_active()
        ssm = self.n_ssm_layers * self.ssm_weight_elems_per_layer
        lm_head = self.vocab * self.d_model
        return self.n_attn_layers() * per_attn + ssm + lm_head

    # ------------------------------------------------------------------
    # stage generation (ONE decode token at KV length `seq`)
    # ------------------------------------------------------------------
    def kv_elems_per_attn_layer(self, seq: float, is_local: bool = False) -> float:
        if self.attn_kind == "mla":
            width = self.mla_kv_lora + self.mla_rope_dim
            return seq * width
        eff_seq = min(seq, self.local_window) if (is_local and self.local_window) else seq
        return 2.0 * eff_seq * self.n_kv_heads * self.hd()

    def decode_stages(self, seq: float) -> List[Stage]:
        """Per-layer stage list for one decode step with KV length `seq`.

        Local/global alternation is averaged across attention layers.
        """
        d, hd, H = self.d_model, self.hd(), self.n_heads
        stages: List[Stage] = []

        n_attn = self.n_attn_layers()
        if n_attn > 0:
            # --- Projection ------------------------------------------------
            if self.attn_kind == "mla":
                proj_w = (d * H * (self.mla_q_nope + self.mla_rope_dim)
                          + d * (self.mla_kv_lora + self.mla_rope_dim)
                          + H * self.mla_q_nope * self.mla_kv_lora)
            else:
                proj_w = d * H * hd + 2 * d * self.n_kv_heads * hd
            bias = (H * hd + 2 * self.n_kv_heads * hd) if self.qkv_bias else 0
            stages.append(Stage(
                "projection",
                weight_elems=proj_w + bias,
                macs=proj_w,
                writeback_elems=(self.mla_kv_lora + self.mla_rope_dim)
                if self.attn_kind == "mla" else 2 * self.n_kv_heads * hd,
                vector_ops=3 * d,   # pre-norm + RoPE + quantize K/V
                n_units=max(self.n_kv_heads, 1),
            ))

            # --- Attention ---------------------------------------------------
            kv_global = self.kv_elems_per_attn_layer(seq, is_local=False)
            kv_local = self.kv_elems_per_attn_layer(seq, is_local=True)
            kv = (self.local_ratio * kv_local
                  + (1.0 - self.local_ratio) * kv_global)
            if self.attn_kind == "mla":
                width = self.mla_kv_lora + self.mla_rope_dim
                sc_seq = kv / width
                macs = H * sc_seq * width + H * sc_seq * self.mla_kv_lora \
                    + H * hd * self.mla_kv_lora
                softmax_elems = H * sc_seq
            else:
                sc_seq = kv / (2.0 * self.n_kv_heads * hd)
                macs = 2.0 * H * hd * sc_seq
                softmax_elems = H * sc_seq
            stages.append(Stage(
                "attention",
                kv_stream_elems=kv,
                macs=macs,
                vector_ops=3.0 * softmax_elems,  # exp + sum + scale (blockwise)
                n_units=max(self.n_kv_heads, 1),
            ))

            # --- Linear (output projection) ---------------------------------
            stages.append(Stage(
                "linear",
                weight_elems=H * hd * d,
                macs=H * hd * d,
                vector_ops=2 * d,   # residual add + post-norm
                n_units=1,
            ))

            # --- FFN ----------------------------------------------------------
            ffn_w = self.ffn_layer_weights_active()
            ff_width = self.d_ff_expert if self.n_experts > 0 else self.d_ff
            n_act = (self.top_k + self.n_shared_experts) if self.n_experts else 1
            stages.append(Stage(
                "ffn",
                weight_elems=ffn_w,
                macs=ffn_w,  # GEMV: one MAC per weight
                vector_ops=(2 * d                      # pre-norm + residual
                            + n_act * 2 * ff_width     # act + elementwise mul
                            + (self.n_experts or 0)),  # router softmax/top-k
                n_units=1,
            ))

        # --- SSM layers (state stream replaces KV; see DESIGN.md SS4) ------
        if self.n_ssm_layers > 0:
            stages.append(Stage(
                "ssm",
                weight_elems=self.ssm_weight_elems_per_layer,
                macs=self.ssm_macs_per_layer,
                kv_stream_elems=self.ssm_state_elems_per_layer,
                writeback_elems=self.ssm_state_elems_per_layer,
                vector_ops=6 * d,
                n_units=1,
            ))

        return stages

    def layer_multiplicity(self) -> List[float]:
        """How many times each stage list entry repeats across the model."""
        mult = []
        if self.n_attn_layers() > 0:
            mult += [float(self.n_attn_layers())] * 4
        if self.n_ssm_layers > 0:
            mult += [float(self.n_ssm_layers)]
        return mult

    def head_stage(self) -> Stage:
        """Final norm + LM head GEMV over the vocabulary."""
        return Stage(
            "lm_head",
            weight_elems=float(self.vocab) * self.d_model,
            macs=float(self.vocab) * self.d_model,
            vector_ops=2 * self.d_model + self.vocab,  # norm + softmax/argmax
            n_units=1,
        )

    def embed_stage(self) -> Stage:
        return Stage("embedding", kv_stream_elems=float(self.d_model))


def make_dense_spec(name: str, n_layers: int, d_model: int, n_heads: int,
                    n_kv_heads: int, d_ff: int, vocab: int,
                    head_dim: Optional[int] = None, ffn_gated: bool = True,
                    **kw) -> SLMSpec:
    return SLMSpec(name=name, n_layers=n_layers, d_model=d_model,
                   n_heads=n_heads, n_kv_heads=n_kv_heads, d_ff=d_ff,
                   vocab=vocab, head_dim=head_dim, ffn_gated=ffn_gated, **kw)
