"""Off-chip DRAM, on-chip buffer, and bus models for the EdgeCIM simulator.

DRAM:   LPDDR5X, 16 channels (paper Sec. IV). Modeled as a stream engine
        with peak bandwidth * utilization and a fixed first-word latency
        per transfer burst.
Buffer: CACTI-6.0-style energy/area fits (constants in hw.TechConstants).
Bus:    2D hierarchical bus (Sec. III-B); stream bandwidth computed in
        hw.stream_bandwidth; per-bit hop energy here.
"""
from __future__ import annotations

from dataclasses import dataclass

from .hw import HWConfig, TechConstants, DEFAULT_TECH


@dataclass(frozen=True)
class TransferCost:
    seconds: float
    joules: float

    def __add__(self, other: "TransferCost") -> "TransferCost":
        return TransferCost(self.seconds + other.seconds,
                            self.joules + other.joules)


def dram_stream(nbytes: float, h: HWConfig,
                tech: TechConstants = DEFAULT_TECH,
                bursts: int = 1) -> TransferCost:
    """Stream `nbytes` from DRAM through the bus hierarchy to the tiles.

    Time: limited by the min bandwidth level (DRAM or any bus tier) plus
    `bursts` first-word latencies (one per independent partition fetch).
    Energy: DRAM interface energy + one hop per bus tier traversed
    (global buffer -> cluster -> tile -> macro write is counted by caller).
    """
    from .hw import stream_bandwidth
    bw = stream_bandwidth(h, tech)
    seconds = nbytes / bw + bursts * tech.dram_latency
    bits = nbytes * 8.0
    joules = bits * (tech.e_dram_bit + 3 * tech.e_bus_bit)
    return TransferCost(seconds, joules)


def dram_write(nbytes: float, tech: TechConstants = DEFAULT_TECH) -> TransferCost:
    """Write-back to DRAM (quantized KV append): bandwidth-symmetric."""
    seconds = nbytes / tech.dram_bw()
    joules = nbytes * 8.0 * (tech.e_dram_bit + 3 * tech.e_bus_bit)
    return TransferCost(seconds, joules)


def buffer_access_energy(nbytes: float, tech: TechConstants = DEFAULT_TECH) -> float:
    return nbytes * 8.0 * tech.e_buf_bit


def onchip_move(nbytes: float, hops: int, h: HWConfig,
                tech: TechConstants = DEFAULT_TECH) -> TransferCost:
    """Move intermediate results across `hops` bus tiers (adder-tree outputs,
    cluster->global buffer concatenation, ...)."""
    bw = min(h.bus_ic, h.bus_it, h.bus_intra) / 8.0 * tech.f_bus
    seconds = nbytes / bw
    joules = nbytes * 8.0 * tech.e_bus_bit * hops + buffer_access_energy(nbytes, tech)
    return TransferCost(seconds, joules)
