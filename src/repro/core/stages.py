"""Per-stage latency/energy with the active-tile pipelined mapping (Sec. III-C).

Execution model for one stage (one layer, one decode token):

  * The stage's operand matrix (weights, or KV/state blocks) is partitioned
    into chunks sized to the chip-wide *active* capacity:
    n_clusters * T_A * P^2 * 256 elements.
  * Chunks stream DRAM -> global buffer -> (bus hierarchy) -> macro cells.
    The memory controller pipelines bursts, so the DRAM first-word latency
    is paid once per stage (pipeline fill), not per chunk.
  * Writing a chunk into the SRAM cells takes MACRO_ROWS cycles (row-wise
    write, macros in parallel); the bit-serial compute pass takes
    input_bits + drain cycles plus the adder-tree depth.
  * Active-tile pipelining (the paper's key scheduling idea): with
    M = T_total / T_A >= 2 there are spare tiles to prefetch+write into
    while the active set computes, so per-chunk time is
        max(t_load, t_write, t_compute)            (fully pipelined)
    With M == 1 the cells are busy computing and cannot be rewritten:
        t_load_hidden? no ->  t_load + t_write + t_compute  (serialized)
    (buffer prefetch still hides the DRAM latency).  This is exactly the
    parallelism/bandwidth/area trade-off the DSE explores.
  * Auxiliary ops run on dedicated vector units and sit on stage boundaries.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .hw import HWConfig, MACRO_ROWS, TechConstants, DEFAULT_TECH, stream_bandwidth
from .macro import pass_cycles, macro_energy, macro_write_energy
from .workload import Stage


@dataclass(frozen=True)
class StageCost:
    seconds: float
    joules: float

    def __add__(self, other: "StageCost") -> "StageCost":
        return StageCost(self.seconds + other.seconds, self.joules + other.joules)

    def scale(self, k: float) -> "StageCost":
        return StageCost(self.seconds * k, self.joules * k)


ZERO = StageCost(0.0, 0.0)


def _adder_tree_cycles(h: HWConfig, tech: TechConstants) -> int:
    """Vertical reduction depth: PEs within tile, tiles within cluster,
    clusters at chip level (log2 stages, pipelined)."""
    depth = (math.ceil(math.log2(max(h.pe_side, 2)))
             + math.ceil(math.log2(max(h.t_act_v, 2)))
             + math.ceil(math.log2(max(h.c_v, 2))))
    return depth * tech.adder_tree_stage_cycles


def _chunk_times(h: HWConfig, w_bits: int, a_bits: int, tech: TechConstants,
                 bytes_per_chunk):
    bw = stream_bandwidth(h, tech)
    t_load = bytes_per_chunk / bw
    t_write = MACRO_ROWS / tech.f_clk
    t_compute = (pass_cycles(a_bits, tech) + _adder_tree_cycles(h, tech)) / tech.f_clk
    return t_load, t_write, t_compute


def stage_cost(st: Stage, h: HWConfig, w_bits: int, a_bits: int,
               tech: TechConstants = DEFAULT_TECH) -> StageCost:
    """Latency + dynamic energy of one stage instance (one layer, one token)."""
    chunk_elems = float(h.n_clusters * h.active_weight_capacity())

    # ---- streamed bytes (weights at w_bits, KV/state at a_bits) -----------
    # group-scale metadata overhead: 16-bit scale per 128-element group
    scale_overhead = 1.0 + 16.0 / (128.0 * w_bits)
    w_bytes = st.weight_elems * w_bits / 8.0 * scale_overhead
    kv_bytes = st.kv_stream_elems * a_bits / 8.0
    stream_elems = st.weight_elems + st.kv_stream_elems
    stream_bytes = w_bytes + kv_bytes

    n_chunks = max(1.0, math.ceil(stream_elems / chunk_elems))
    t_load, t_write, t_compute = _chunk_times(
        h, w_bits, a_bits, tech, stream_bytes / n_chunks)

    if h.m_mult >= 2:   # active-tile overlap: spare tiles absorb the write
        per_chunk = max(t_load, t_write, t_compute)
        t_stream = tech.dram_latency + t_load + \
            (n_chunks - 1) * per_chunk + t_write + t_compute
    else:               # no spare tiles: write+compute serialize with load
        t_stream = tech.dram_latency + n_chunks * (t_load + t_write + t_compute)

    # ---- auxiliary vector ops ---------------------------------------------
    t_aux = st.vector_ops / tech.vector_lanes / tech.f_clk

    # ---- write-back (KV append / state update) -----------------------------
    wb_bytes = st.writeback_elems * a_bits / 8.0
    t_wb = wb_bytes / tech.dram_bw() if wb_bytes else 0.0

    seconds = t_stream + t_aux + t_wb

    # ---- energy -------------------------------------------------------------
    e = (stream_bytes * 8.0 * (tech.e_dram_bit + 3 * tech.e_bus_bit)
         + macro_write_energy(stream_elems, w_bits, tech)
         + macro_energy(st.macs, min(w_bits, a_bits), tech)
         + st.vector_ops * tech.e_vec_op
         + wb_bytes * 8.0 * (tech.e_dram_bit + 3 * tech.e_bus_bit))

    return StageCost(seconds, e)


def stage_cost_vec(st_weight_elems: np.ndarray, st_kv_elems: np.ndarray,
                   st_macs: np.ndarray, st_vec_ops: np.ndarray,
                   st_wb_elems: np.ndarray, h: HWConfig, w_bits: int,
                   a_bits: int, tech: TechConstants = DEFAULT_TECH
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized over numpy arrays (per-token KV growth during generation).
    Mirrors `stage_cost` exactly — tested for equality in tests/."""
    chunk_elems = float(h.n_clusters * h.active_weight_capacity())
    scale_overhead = 1.0 + 16.0 / (128.0 * w_bits)

    w_bytes = st_weight_elems * w_bits / 8.0 * scale_overhead
    kv_bytes = st_kv_elems * a_bits / 8.0
    stream_elems = st_weight_elems + st_kv_elems
    stream_bytes = w_bytes + kv_bytes

    n_chunks = np.maximum(1.0, np.ceil(stream_elems / chunk_elems))
    t_load, t_write, t_compute = _chunk_times(
        h, w_bits, a_bits, tech, stream_bytes / n_chunks)

    if h.m_mult >= 2:
        per_chunk = np.maximum(np.maximum(t_load, t_write), t_compute)
        t_stream = tech.dram_latency + t_load + \
            (n_chunks - 1) * per_chunk + t_write + t_compute
    else:
        t_stream = tech.dram_latency + n_chunks * (t_load + t_write + t_compute)

    t_aux = st_vec_ops / tech.vector_lanes / tech.f_clk
    wb_bytes = st_wb_elems * a_bits / 8.0
    t_wb = wb_bytes / tech.dram_bw()
    seconds = t_stream + t_aux + t_wb

    e = (stream_bytes * 8.0 * (tech.e_dram_bit + 3 * tech.e_bus_bit)
         + stream_elems * w_bits * tech.e_buf_bit
         + st_macs * tech.e_mac(min(w_bits, a_bits))
         + st_vec_ops * tech.e_vec_op
         + wb_bytes * 8.0 * (tech.e_dram_bit + 3 * tech.e_bus_bit))
    return seconds, e
