"""Async streaming gateway over `PagedServeEngine` (stdlib asyncio).

This is the online front door the offline runtime was missing: traffic
arrives asynchronously, tokens stream back as they decode, and clients
disconnect whenever they like — the regime where edge-inference
latency/energy trade-offs actually bite.

Threading model: the asyncio event loop owns sockets and parsing; the
`EngineDriver` thread owns the engine.  A request crosses over exactly
twice — submission (a driver job) and per-token fan-out
(`loop.call_soon_threadsafe` into the request's asyncio.Queue) — so
the engine stays lock-free and the event loop never blocks on jax.

Endpoints:
  POST /v1/completions   token-id prompt -> SSE token stream (or one
                         JSON body with stream=false).  `n > 1` samples
                         share the prompt's KV pages via
                         `PagedKVCache.fork` (copy-on-write tails).
  GET  /metrics          engine summary + latency histograms + gateway
                         counters, strict JSON.
  GET  /healthz          liveness.

Overload: a bounded admission budget (`max_pending` samples in flight)
turns excess load into HTTP 429 + `Retry-After` instead of an unbounded
queue — open-loop arrivals cannot OOM the paged pool from the outside.

Cancellation: a client that disconnects mid-stream (or mid-prefill)
aborts its samples via `PagedServeEngine.cancel`, which frees KV pages
and lanes and decrefs (never frees) shared prefix pages.
"""
from __future__ import annotations

import asyncio
import contextlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .driver import EngineDriver
from .protocol import (CompletionRequest, ProtocolError, error_response,
                       http_response, json_response, parse_completion,
                       read_http_request, sse_done, sse_event)

_SSE_HEADERS = (b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n"
                b"Connection: close\r\n\r\n")


def _finish_reason(req, eos_id: Optional[int]) -> str:
    if req.cancelled:
        return "cancelled"
    if req.rejected:
        return "rejected"
    if req.truncated:
        return "truncated"
    if (eos_id is not None and req.out_tokens
            and req.out_tokens[-1] == eos_id):
        return "stop"
    return "length"


class Gateway:
    """Serve an already-built engine.  The gateway takes ownership of
    stepping it: nothing else may call `engine.step()`/`run()` while
    the gateway is running."""

    def __init__(self, engine, *, max_pending: int = 32, max_n: int = 8):
        assert max_pending >= 0 and max_n >= 1
        self.engine = engine
        self.driver = EngineDriver(engine)
        self.max_pending = max_pending
        self.max_n = max_n
        # n>1 rides PagedKVCache.fork, an attention-only capability;
        # recurrent-state families serve n independent lanes instead
        self._can_fork = engine.model.supports_paged()
        self._inflight = 0              # event-loop thread only
        self.counters: Dict[str, int] = {
            "http_requests": 0, "accepted_samples": 0, "rejected_429": 0,
            "bad_requests": 0, "disconnects": 0, "completed_samples": 0}
        self._server: Optional[asyncio.AbstractServer] = None

    # -- lifecycle ------------------------------------------------------
    async def start(self, host: str = "127.0.0.1", port: int = 0
                    ) -> Tuple[str, int]:
        self.driver.start()
        self._server = await asyncio.start_server(self._handle, host,
                                                  port)
        sock = self._server.sockets[0].getsockname()
        return sock[0], sock[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        # driver.stop() joins the engine thread (a mid-flight jitted
        # step can take seconds): keep it off the event loop
        await asyncio.get_running_loop().run_in_executor(
            None, self.driver.stop)

    async def serve_forever(self, host: str = "127.0.0.1",
                            port: int = 8151) -> None:
        h, p = await self.start(host, port)
        print(f"[api] gateway listening on http://{h}:{p} "
              f"(POST /v1/completions, GET /metrics)")
        try:
            await self._server.serve_forever()
        finally:
            await self.stop()

    # -- connection handling -------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self.counters["http_requests"] += 1
        try:
            try:
                method, path, _, body = await read_http_request(reader)
            except ProtocolError as e:
                self.counters["bad_requests"] += 1
                writer.write(error_response(400, "Bad Request", e.message))
                return
            except (ConnectionError, asyncio.IncompleteReadError,
                    asyncio.LimitOverrunError):
                return
            if method == "POST" and path == "/v1/completions":
                await self._completions(body, reader, writer)
            elif method == "GET" and path in ("/metrics", "/v1/metrics"):
                writer.write(json_response(200, "OK",
                                           await self._metrics()))
            elif method == "GET" and path == "/healthz":
                # a dead driver answers 503, not 200-with-false: a
                # status-code liveness probe must see the failure
                alive = self.driver.alive
                body = {"ok": alive,
                        "error": (repr(self.driver.error)
                                  if self.driver.error else None)}
                writer.write(json_response(200 if alive else 503,
                                           "OK" if alive
                                           else "Service Unavailable",
                                           body))
            else:
                writer.write(error_response(404, "Not Found",
                                            f"no route {method} {path}"))
        except (ConnectionResetError, BrokenPipeError):
            self.counters["disconnects"] += 1
        finally:
            with contextlib.suppress(Exception):
                if not writer.is_closing():
                    await writer.drain()
                writer.close()
                await writer.wait_closed()

    # -- /v1/completions -----------------------------------------------
    def _build_requests(self, creq: CompletionRequest, q: asyncio.Queue,
                        loop) -> List:
        from repro.serve import SamplingParams, ServeRequest
        sampling = SamplingParams(temperature=creq.temperature,
                                  top_k=creq.top_k, top_p=creq.top_p)

        def on_token(rid: int, tok: int) -> None:     # driver thread
            loop.call_soon_threadsafe(q.put_nowait, ("token", rid, tok))

        prompt = np.asarray(creq.prompt, np.int32)
        primary = ServeRequest(prompt=prompt,
                               max_new_tokens=creq.max_tokens, rid=0,
                               priority=creq.priority,
                               deadline_s=creq.deadline_s,
                               sampling=sampling, spec=creq.spec,
                               on_token=on_token)
        reqs = [primary]
        for i in range(1, creq.n):
            reqs.append(ServeRequest(
                prompt=prompt.copy(), max_new_tokens=creq.max_tokens,
                rid=i, priority=creq.priority, deadline_s=creq.deadline_s,
                sampling=sampling, spec=creq.spec, on_token=on_token,
                fork_from=primary if self._can_fork else None))
        return reqs

    async def _completions(self, body: bytes,
                           reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            creq = parse_completion(body, vocab=self.engine.model.cfg.vocab,
                                    max_n=self.max_n,
                                    max_prompt_len=self.engine.max_seq)
        except ProtocolError as e:
            self.counters["bad_requests"] += 1
            writer.write(error_response(400, "Bad Request", e.message))
            return
        if not self.driver.alive:
            # fail fast: submitting to a dead engine thread would hang
            # this handler forever and leak the inflight budget
            writer.write(error_response(
                503, "Service Unavailable", "engine driver not running"))
            return
        if self._inflight + creq.n > self.max_pending:
            self.counters["rejected_429"] += 1
            writer.write(error_response(
                429, "Too Many Requests",
                f"{self._inflight} samples in flight of {self.max_pending}"
                " allowed; retry shortly", {"Retry-After": "1"}))
            return

        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()

        def on_done(req) -> None:                     # driver thread
            loop.call_soon_threadsafe(self._sample_done, q, req)

        reqs = self._build_requests(creq, q, loop)
        self._inflight += creq.n
        self.counters["accepted_samples"] += creq.n
        try:
            eids = await asyncio.wrap_future(
                self.driver.submit(reqs, on_done))
        except RuntimeError:
            self._inflight -= creq.n    # never submitted: restore the
            self.counters["accepted_samples"] -= creq.n     # budget
            writer.write(error_response(
                503, "Service Unavailable", "engine driver not running"))
            return
        if creq.stream:
            await self._stream_sse(creq, q, eids, reader, writer)
        else:
            await self._respond_json(creq, q, eids, reqs, writer)

    def _sample_done(self, q: asyncio.Queue, req) -> None:
        self._inflight -= 1
        self.counters["completed_samples"] += 1
        q.put_nowait(("done", req.rid, req))

    async def _abort(self, eids: List[int]) -> None:
        self.counters["disconnects"] += 1
        try:
            await asyncio.wrap_future(self.driver.cancel(eids))
        except RuntimeError:
            pass    # driver died: its requests died with it

    async def _next_event(self, q: asyncio.Queue,
                          reader: asyncio.StreamReader,
                          eof_box: List) -> Optional[Tuple]:
        """Next fan-out event, or None when the client went away.
        `eof_box[0]` is the pending 1-byte read watching the client
        socket: a read error or b'' (EOF — SSE clients hold their write
        side open for the connection's life, so EOF means gone) is a
        disconnect, while a stray trailing byte (e.g. a CRLF after the
        body) just re-arms the watch instead of killing the stream."""
        get = asyncio.ensure_future(q.get())
        while True:
            await asyncio.wait({get, eof_box[0]},
                               return_when=asyncio.FIRST_COMPLETED)
            if get.done():
                return get.result()
            try:
                data = eof_box[0].result()
            except (ConnectionError, OSError):
                data = b""
            if not data:
                get.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await get
                return None
            eof_box[0] = asyncio.ensure_future(reader.read(1))

    async def _stream_sse(self, creq, q, eids, reader, writer) -> None:
        writer.write(_SSE_HEADERS)
        eof_box = [asyncio.ensure_future(reader.read(1))]
        try:
            await writer.drain()
            remaining = creq.n
            while remaining:
                event = await self._next_event(q, reader, eof_box)
                if event is None:       # client went away mid-stream:
                    await self._abort(eids)   # abort the whole group
                    return
                kind, rid, payload = event
                if kind == "token":
                    writer.write(sse_event({"index": rid,
                                            "token": payload}))
                else:
                    remaining -= 1
                    writer.write(sse_event(
                        {"index": rid,
                         "finish_reason": _finish_reason(
                             payload, self.engine.eos_id),
                         "n_tokens": len(payload.out_tokens)}))
                await writer.drain()
            writer.write(sse_done())
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            await self._abort(eids)
        finally:
            eof_box[0].cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await eof_box[0]

    async def _respond_json(self, creq, q, eids, reqs, writer) -> None:
        """Non-streaming mode: there is nothing incremental to deliver,
        so the client socket is NOT watched for EOF — a legal HTTP
        half-close (shutdown of the write side after the request) must
        not abort the work.  A truly-gone client surfaces as a failed
        response write instead."""
        try:
            remaining = creq.n
            while remaining:
                kind, _, payload = await q.get()
                if kind == "done":
                    remaining -= 1
            choices = [{"index": r.rid, "tokens": list(r.out_tokens),
                        "finish_reason": _finish_reason(
                            r, self.engine.eos_id)} for r in reqs]
            writer.write(json_response(200, "OK", {
                "choices": choices,
                "usage": {"prompt_tokens": len(creq.prompt),
                          "completion_tokens": sum(
                              len(r.out_tokens) for r in reqs)}}))
            await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            await self._abort(eids)

    # -- /metrics -------------------------------------------------------
    async def _metrics(self) -> Dict:
        if not self.driver.alive:
            return {"gateway": dict(self.counters), "engine": None,
                    "error": "engine driver not running"}
        snap = await asyncio.wrap_future(self.driver.call(
            lambda eng: {"engine": eng.summary(),
                         "histograms": eng.telemetry.histograms(),
                         "n_running": eng.n_running,
                         "n_queued": eng.scheduler.n_queued,
                         "kv_pages_free": eng.cache.allocator.n_free}))
        snap["gateway"] = {**self.counters, "inflight": self._inflight,
                           "max_pending": self.max_pending}
        return snap
