"""Async streaming gateway over a fleet of `PagedServeEngine` replicas.

This is the online front door the offline runtime was missing: traffic
arrives asynchronously, tokens stream back as they decode, and clients
disconnect whenever they like — the regime where edge-inference
latency/energy trade-offs actually bite.

Threading model: the asyncio event loop owns sockets, parsing, and
ROUTING; each replica's `EngineDriver` thread owns its engine.  A
request crosses over exactly twice — submission (a driver job on the
replica the router picked) and per-token fan-out
(`loop.call_soon_threadsafe` into the request's asyncio.Queue) — so
every engine stays lock-free and the event loop never blocks on jax.

The gateway itself holds no engine state: it speaks only to a
`repro.fleet.FleetRouter` (a single engine is wrapped in a one-replica
fleet, which keeps the classic `Gateway(engine)` construction — and
its semantics — unchanged).  Scale-out is `Gateway(FleetRouter([...]))`
with a dispatch policy; see repro/fleet/.

Endpoints:
  POST /v1/completions   token-id prompt -> SSE token stream (or one
                         JSON body with stream=false).  `n > 1` samples
                         share the prompt's KV pages via
                         `PagedKVCache.fork` (copy-on-write tails) and
                         always land on ONE replica.  `logprobs=true`
                         adds per-token logprob + entropy.
  GET  /metrics          fleet-aggregated engine summary + latency
                         histograms + per-replica breakdown + gateway
                         counters, strict JSON.
  GET  /healthz          liveness: 200 while >= 1 replica serves, 503
                         only when the whole fleet is down.  With SLOs
                         configured, `degraded: true` while any alert
                         state machine sits at `page` — orchestrators
                         distinguish "up" from "meeting objectives"
                         without killing a serving replica.
  GET  /debug/slo        SLO objectives + burn-rate alert states +
                         recent transitions + per-replica drift audit
                         (obs/slo.py, obs/drift.py).  The gateway runs
                         the evaluation loop (`FleetRouter.poll_slo`)
                         as a background task while serving.

Overload: admission is fleet-level load shedding — a request is 429'd
(honest Retry-After from the least-loaded replica's measured decode
rate) only when EVERY live replica is at its per-replica pending cap,
so open-loop arrivals cannot OOM any paged pool from the outside.

Cancellation: a client that disconnects mid-stream (or mid-prefill)
aborts its samples on whichever replica currently owns them, which
frees KV pages and lanes and decrefs (never frees) shared prefix pages.
"""
from __future__ import annotations

import asyncio
import contextlib
import json
import time
from typing import Dict, List, Optional, Tuple
from urllib.parse import parse_qs

import numpy as np

from repro.obs.export import chrome_trace, prometheus_text
from repro.obs.trace import get_tracer

from .protocol import (CompletionRequest, ProtocolError, error_response,
                       http_response, json_response, parse_completion,
                       read_http_request, sse_done, sse_event)

_SSE_HEADERS = (b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n"
                b"Connection: close\r\n\r\n")

# bumped whenever the /metrics JSON payload changes shape, so
# check_bench.py and external scrapers can detect format drift instead
# of misreading renamed keys.  v2: added schema_version itself, the
# sim_* energy metrics, and the fleet aggregation of both.  v3: fleet
# percentiles recomputed from merged quantile sketches (empty metrics
# now ABSENT instead of NaN), per-replica `drift` audit blocks, and the
# optional top-level `slo` section.
METRICS_SCHEMA_VERSION = 3


def _finish_reason(req, eos_id: Optional[int]) -> str:
    if req.cancelled:
        return "cancelled"
    if req.rejected:
        return "rejected"
    if req.truncated:
        return "truncated"
    if (eos_id is not None and req.out_tokens
            and req.out_tokens[-1] == eos_id):
        return "stop"
    return "length"


class Gateway:
    """Serve an already-built engine or fleet.  The gateway takes
    ownership of stepping: nothing else may call `engine.step()`/`run()`
    on any replica while the gateway is running."""

    def __init__(self, engine_or_router, *, max_pending: Optional[int] = None,
                 max_n: int = 8, access_log=None, slos=None,
                 slo_policy=None, slo_poll_s: float = 0.25):
        """`slos`: optional SLO spec strings / `SLOSpec`s (obs/slo.py)
        installed on the router; the gateway then runs the burn-rate +
        drift evaluation loop every `slo_poll_s` while serving.
        `slo_policy` overrides the `BurnRatePolicy` (timescale!)."""
        assert (max_pending is None or max_pending >= 0) and max_n >= 1
        # deferred: repro.fleet pulls in repro.api.driver, whose package
        # __init__ imports this module — a top-level import would cycle
        from repro.fleet import FleetRouter
        if isinstance(engine_or_router, FleetRouter):
            self.router = engine_or_router
        else:       # classic single-engine construction: a fleet of one
            # max_pending None defers to the engine's ServeConfig
            self.router = FleetRouter([engine_or_router],
                                      policy="least-loaded",
                                      max_pending=max_pending)
        self.max_n = max_n
        # n>1 rides PagedKVCache.fork, an attention-only capability;
        # recurrent-state families serve n independent lanes instead
        self._can_fork = self.engine.model.supports_paged()
        self.counters: Dict[str, int] = {
            "http_requests": 0, "accepted_samples": 0, "rejected_429": 0,
            "bad_requests": 0, "disconnects": 0, "completed_samples": 0}
        self._server: Optional[asyncio.AbstractServer] = None
        self.tracer = get_tracer()
        self.slo_poll_s = slo_poll_s
        self._slo_task: Optional[asyncio.Task] = None
        if slos:
            self.router.set_slos(slos, policy=slo_policy)
        # structured access log: one JSON line per /v1/completions
        # request (path string or an open file-like); None = silent
        self._access_log = None
        self._access_log_own = False
        if access_log is not None:
            if hasattr(access_log, "write"):
                self._access_log = access_log
            else:
                self._access_log = open(access_log, "a")
                self._access_log_own = True

    def _log_access(self, **fields) -> None:
        if self._access_log is None:
            return
        self._access_log.write(
            json.dumps(fields, separators=(",", ":")) + "\n")
        self._access_log.flush()

    # -- single-engine compatibility surface ---------------------------
    @property
    def engine(self):
        """Replica 0's engine: model metadata (vocab, max_seq, eos) is
        identical fleet-wide by FleetRouter's construction contract."""
        return self.router.replicas[0].engine

    @property
    def driver(self):
        """Replica 0's driver (the classic one-engine handle; fleet
        code should address `router.replicas[i].driver`)."""
        return self.router.replicas[0].driver

    @property
    def _inflight(self) -> int:
        return sum(rep.pending for rep in self.router.replicas)

    @property
    def max_pending(self) -> int:
        """Fleet admission capacity in samples (sum of per-replica
        caps)."""
        return sum(rep.max_pending for rep in self.router.replicas)

    # -- lifecycle ------------------------------------------------------
    async def start(self, host: str = "127.0.0.1", port: int = 0
                    ) -> Tuple[str, int]:
        self.router.start()
        self._server = await asyncio.start_server(self._handle, host,
                                                  port)
        # evaluation heartbeat: drift audit always, burn-rate alerting
        # when SLOs are configured.  A poll reads only lock-free
        # published snapshots, so this costs microseconds per tick.
        self._slo_task = asyncio.get_running_loop().create_task(
            self._slo_loop())
        sock = self._server.sockets[0].getsockname()
        return sock[0], sock[1]

    async def _slo_loop(self) -> None:
        while True:
            await asyncio.sleep(self.slo_poll_s)
            try:
                self.router.poll_slo()
            except Exception:
                # observability must never take down serving; the next
                # tick retries
                pass

    async def stop(self) -> None:
        if self._slo_task is not None:
            self._slo_task.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await self._slo_task
            self._slo_task = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        # router.stop() joins every engine thread (a mid-flight jitted
        # step can take seconds): keep it off the event loop
        await asyncio.get_running_loop().run_in_executor(
            None, self.router.stop)
        if self._access_log_own and self._access_log is not None:
            self._access_log.close()
            self._access_log = None

    async def serve_forever(self, host: str = "127.0.0.1",
                            port: int = 8151) -> None:
        h, p = await self.start(host, port)
        print(f"[api] gateway listening on http://{h}:{p} "
              f"(POST /v1/completions, GET /metrics; "
              f"{len(self.router.replicas)} replica(s), "
              f"policy={self.router.policy.name})")
        try:
            await self._server.serve_forever()
        finally:
            await self.stop()

    # -- connection handling -------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        self.counters["http_requests"] += 1
        try:
            try:
                method, path, _, body = await read_http_request(reader)
            except ProtocolError as e:
                self.counters["bad_requests"] += 1
                writer.write(error_response(400, "Bad Request", e.message))
                return
            except (ConnectionError, asyncio.IncompleteReadError,
                    asyncio.LimitOverrunError):
                return
            route, _, query = path.partition("?")
            qs = parse_qs(query) if query else {}
            if method == "POST" and route == "/v1/completions":
                await self._completions(body, reader, writer)
            elif method == "GET" and route in ("/metrics", "/v1/metrics"):
                payload = await self._metrics()
                if qs.get("format", [""])[0] == "prometheus":
                    text = prometheus_text(payload)
                    writer.write(http_response(
                        200, "OK",
                        {"Content-Type":
                         "text/plain; version=0.0.4; charset=utf-8"},
                        text.encode()))
                else:
                    writer.write(json_response(200, "OK", payload))
            elif method == "GET" and route == "/debug/trace":
                # Chrome trace-event JSON of everything the process
                # tracer holds — load the body directly in Perfetto.
                # 404 (not an empty trace) when tracing is off, so a
                # misconfigured capture fails loudly.
                if not self.tracer.enabled:
                    writer.write(error_response(
                        404, "Not Found",
                        "tracing disabled: start with --trace or "
                        "REPRO_TRACE=1"))
                else:
                    writer.write(json_response(
                        200, "OK", chrome_trace(self.tracer)))
            elif method == "GET" and route == "/debug/slo":
                # objectives, burn rates, alert states, transitions,
                # per-replica drift audit — always 200: with no SLOs
                # configured the body says so (worst "ok", empty specs)
                # rather than 404ing a legitimate health question
                writer.write(json_response(
                    200, "OK", self.router.slo_payload()))
            elif method == "GET" and path == "/healthz":
                # fleet liveness: 200 while any replica serves (a probe
                # must not kill a gateway that is degraded, not down);
                # 503 — never 200-with-false — once the whole fleet is
                # dead, so a status-code probe sees the failure
                alive = self.router.alive
                errors = {str(rep.id): repr(rep.error)
                          for rep in self.router.replicas
                          if rep.error is not None}
                # degraded: serving, but some SLO state machine sits at
                # `page` — still 200 (a liveness probe must not kill a
                # slow-but-serving fleet); orchestrators that care read
                # the flag or /debug/slo
                worst = self.router.worst_alert_level()
                body = {"ok": alive,
                        "degraded": bool(alive and worst == "page"),
                        "slo_worst": worst,
                        "n_live": self.router.n_live,
                        "n_replicas": len(self.router.replicas),
                        "error": errors or None}
                writer.write(json_response(200 if alive else 503,
                                           "OK" if alive
                                           else "Service Unavailable",
                                           body))
            else:
                writer.write(error_response(404, "Not Found",
                                            f"no route {method} {path}"))
        except (ConnectionResetError, BrokenPipeError):
            self.counters["disconnects"] += 1
        finally:
            with contextlib.suppress(Exception):
                if not writer.is_closing():
                    await writer.drain()
                writer.close()
                await writer.wait_closed()

    # -- /v1/completions -----------------------------------------------
    def _build_requests(self, creq: CompletionRequest, q: asyncio.Queue,
                        loop) -> List:
        from repro.serve import SamplingParams, ServeRequest
        sampling = SamplingParams(temperature=creq.temperature,
                                  top_k=creq.top_k, top_p=creq.top_p)
        reqs_by_rid: Dict[int, object] = {}

        if creq.logprobs:
            def on_token(rid: int, tok: int) -> None:  # driver thread
                # _emit appended this token's (logprob, entropy) just
                # before calling us, so the tail entry is ours — capture
                # it NOW (driver thread), not when the queue drains
                lp, ent = reqs_by_rid[rid].out_logprobs[-1]
                loop.call_soon_threadsafe(q.put_nowait,
                                          ("token", rid, (tok, lp, ent)))
        else:
            def on_token(rid: int, tok: int) -> None:  # driver thread
                loop.call_soon_threadsafe(q.put_nowait,
                                          ("token", rid, tok))

        prompt = np.asarray(creq.prompt, np.int32)
        primary = ServeRequest(prompt=prompt,
                               max_new_tokens=creq.max_tokens, rid=0,
                               priority=creq.priority,
                               deadline_s=creq.deadline_s,
                               sampling=sampling, spec=creq.spec,
                               logprobs=creq.logprobs,
                               on_token=on_token)
        reqs = [primary]
        for i in range(1, creq.n):
            reqs.append(ServeRequest(
                prompt=prompt.copy(), max_new_tokens=creq.max_tokens,
                rid=i, priority=creq.priority, deadline_s=creq.deadline_s,
                sampling=sampling, spec=creq.spec,
                logprobs=creq.logprobs, on_token=on_token,
                fork_from=primary if self._can_fork else None))
        for r in reqs:
            reqs_by_rid[r.rid] = r
        return reqs

    async def _completions(self, body: bytes,
                           reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        t_arrive = time.monotonic()
        try:
            creq = parse_completion(body, vocab=self.engine.model.cfg.vocab,
                                    max_n=self.max_n,
                                    max_prompt_len=self.engine.max_seq)
        except ProtocolError as e:
            self.counters["bad_requests"] += 1
            self._log_access(rid=None, status=400, reason=e.message)
            writer.write(error_response(400, "Bad Request", e.message))
            return
        if not self.router.alive:
            # fail fast: submitting to a dead fleet would hang this
            # handler forever and leak the admission budget
            self._log_access(rid=None, status=503,
                             reason="engine driver not running")
            writer.write(error_response(
                503, "Service Unavailable", "engine driver not running"))
            return

        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()

        def on_done(req) -> None:                     # driver thread
            loop.call_soon_threadsafe(self._sample_done, q, req)

        prompt = np.asarray(creq.prompt, np.int32)
        reqs = self._build_requests(creq, q, loop)
        # process-unique tracing ids, assigned before submission so the
        # engine's span events carry them; reqs[0]'s id labels the
        # whole group in the access log and the gateway lifecycle span
        for r in reqs:
            r.trace_id = self.tracer.next_request_id()
        rid0 = reqs[0].trace_id
        if self.tracer.enabled:
            self.tracer.instant("request_arrive", cat="gateway",
                                rid=rid0,
                                rids=[r.trace_id for r in reqs],
                                n=creq.n, prompt_len=len(creq.prompt),
                                stream=creq.stream)
        # route -> dispatch, retrying on a replica that died between the
        # pick and the submit; accounting (pending + accepted_samples)
        # moves BEFORE the await so a burst of concurrent arrivals sees
        # each other's reservations — admission is event-loop-side state
        while True:
            rep = self.router.route(prompt, creq.n)
            if rep is None:     # every live replica saturated: shed
                self.counters["rejected_429"] += 1
                retry = self.router.retry_after_s()
                if self.tracer.enabled:
                    self.tracer.instant("request_shed", cat="gateway",
                                        rid=rid0, retry_after_s=retry)
                self._log_access(rid=rid0, status=429,
                                 reason="fleet saturated",
                                 retry_after_s=retry)
                writer.write(error_response(
                    429, "Too Many Requests",
                    f"{self._inflight} samples in flight of "
                    f"{self.max_pending} allowed fleet-wide; retry "
                    f"shortly", {"Retry-After": str(retry)}))
                return
            self.counters["accepted_samples"] += creq.n
            fut = self.router.dispatch(rep, reqs, on_done)
            try:
                eids = await asyncio.wrap_future(fut)
                break
            except RuntimeError:    # replica died before the job ran:
                self.router.dispatch_failed(rep, reqs)      # roll back
                self.counters["accepted_samples"] -= creq.n
                if not self.router.alive:
                    self._log_access(rid=rid0, status=503,
                                     reason="engine driver not running")
                    writer.write(error_response(
                        503, "Service Unavailable",
                        "engine driver not running"))
                    return
                # survivors exist: re-route the same group
        del eids    # engine ids are replica-local; aborts go by request
        ctx = {"first": None, "tokens": 0}
        if creq.stream:
            status = await self._stream_sse(creq, q, reqs, reader,
                                            writer, ctx)
        else:
            status = await self._respond_json(creq, q, reqs, writer, ctx)
        t_done = time.monotonic()
        ttft = (ctx["first"] - t_arrive
                if ctx["first"] is not None else None)
        if self.tracer.enabled:
            self.tracer.complete("request", t_arrive, t_done - t_arrive,
                                 cat="gateway", rid=rid0,
                                 replica=rep.id, status=status,
                                 tokens=ctx["tokens"])
        self._log_access(rid=rid0, replica=rep.id,
                         policy=self.router.policy.name, status=status,
                         n=creq.n, prompt_len=len(creq.prompt),
                         ttft_s=ttft, tokens=ctx["tokens"],
                         dur_s=t_done - t_arrive)

    def _sample_done(self, q: asyncio.Queue, req) -> None:
        self.router.release(req)
        self.counters["completed_samples"] += 1
        q.put_nowait(("done", req.rid, req))

    async def _abort(self, reqs: List) -> None:
        self.counters["disconnects"] += 1
        await self.router.cancel(reqs)

    async def _next_event(self, q: asyncio.Queue,
                          reader: asyncio.StreamReader,
                          eof_box: List) -> Optional[Tuple]:
        """Next fan-out event, or None when the client went away.
        `eof_box[0]` is the pending 1-byte read watching the client
        socket: a read error or b'' (EOF — SSE clients hold their write
        side open for the connection's life, so EOF means gone) is a
        disconnect, while a stray trailing byte (e.g. a CRLF after the
        body) just re-arms the watch instead of killing the stream."""
        get = asyncio.ensure_future(q.get())
        while True:
            await asyncio.wait({get, eof_box[0]},
                               return_when=asyncio.FIRST_COMPLETED)
            if get.done():
                return get.result()
            try:
                data = eof_box[0].result()
            except (ConnectionError, OSError):
                data = b""
            if not data:
                get.cancel()
                with contextlib.suppress(asyncio.CancelledError):
                    await get
                return None
            eof_box[0] = asyncio.ensure_future(reader.read(1))

    def _token_event(self, creq, rid: int, payload) -> Dict:
        if creq.logprobs:
            tok, lp, ent = payload
            return {"index": rid, "token": tok,
                    "logprob": lp, "entropy": ent}
        return {"index": rid, "token": payload}

    async def _stream_sse(self, creq, q, reqs, reader, writer,
                          ctx: Dict) -> str:
        writer.write(_SSE_HEADERS)
        eof_box = [asyncio.ensure_future(reader.read(1))]
        try:
            await writer.drain()
            remaining = creq.n
            while remaining:
                event = await self._next_event(q, reader, eof_box)
                if event is None:       # client went away mid-stream:
                    await self._abort(reqs)   # abort the whole group
                    return "disconnect"
                kind, rid, payload = event
                if kind == "token":
                    if ctx["first"] is None:
                        ctx["first"] = time.monotonic()
                    ctx["tokens"] += 1
                    writer.write(sse_event(
                        self._token_event(creq, rid, payload)))
                else:
                    remaining -= 1
                    writer.write(sse_event(
                        {"index": rid,
                         "finish_reason": _finish_reason(
                             payload, self.engine.eos_id),
                         "n_tokens": len(payload.out_tokens)}))
                await writer.drain()
            writer.write(sse_done())
            await writer.drain()
            return "ok"
        except (ConnectionResetError, BrokenPipeError):
            await self._abort(reqs)
            return "disconnect"
        finally:
            eof_box[0].cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await eof_box[0]

    async def _respond_json(self, creq, q, reqs, writer,
                            ctx: Dict) -> str:
        """Non-streaming mode: there is nothing incremental to deliver,
        so the client socket is NOT watched for EOF — a legal HTTP
        half-close (shutdown of the write side after the request) must
        not abort the work.  A truly-gone client surfaces as a failed
        response write instead."""
        try:
            remaining = creq.n
            while remaining:
                kind, _, payload = await q.get()
                if kind == "token":
                    if ctx["first"] is None:
                        ctx["first"] = time.monotonic()
                    ctx["tokens"] += 1
                if kind == "done":
                    remaining -= 1
            choices = []
            for r in reqs:
                choice = {"index": r.rid, "tokens": list(r.out_tokens),
                          "finish_reason": _finish_reason(
                              r, self.engine.eos_id)}
                if creq.logprobs:
                    choice["logprobs"] = [
                        {"logprob": lp, "entropy": ent}
                        for lp, ent in r.out_logprobs]
                choices.append(choice)
            writer.write(json_response(200, "OK", {
                "choices": choices,
                "usage": {"prompt_tokens": len(creq.prompt),
                          "completion_tokens": sum(
                              len(r.out_tokens) for r in reqs)}}))
            await writer.drain()
            return "ok"
        except (ConnectionResetError, BrokenPipeError):
            await self._abort(reqs)
            return "disconnect"

    # -- /metrics -------------------------------------------------------
    async def _metrics(self) -> Dict:
        """Fleet rollup + per-replica breakdown.  Top-level "engine" /
        "histograms" keep the classic single-engine schema (aggregated
        across live replicas); "fleet" carries the per-replica truth —
        including entries for drained and dead replicas, which aggregate
        as absent, never as a KeyError."""
        payload = await self.router.fleet_metrics()
        payload["schema_version"] = METRICS_SCHEMA_VERSION
        if payload["engine"] is None:
            payload.setdefault("error", "engine driver not running")
        payload["gateway"] = {**self.counters,
                              "inflight": self._inflight,
                              "max_pending": self.max_pending}
        return payload
