"""Async streaming serving gateway over the paged runtime (stdlib-only).

driver  (EngineDriver)  — the one thread that owns the engine; jobs +
                          done-watchers cross the thread boundary
gateway (Gateway)       — asyncio HTTP front door: SSE token streaming,
                          n>1 parallel sampling via KV fork,
                          cancellation on disconnect, 429 backpressure,
                          /metrics
protocol                — request schema + SSE / minimal HTTP framing

See `repro/serve/README.md` ("Gateway") for the endpoint schema and
semantics; `benchmarks/api_bench.py` drives it under open-loop load.
"""
from .driver import EngineDriver
from .gateway import Gateway
from .protocol import (CompletionRequest, ProtocolError, iter_sse,
                       parse_completion, sse_event)

__all__ = ["EngineDriver", "Gateway", "CompletionRequest",
           "ProtocolError", "iter_sse", "parse_completion", "sse_event"]
