"""EngineDriver: the one thread that owns a PagedServeEngine.

`PagedServeEngine` is synchronous and single-threaded by contract —
its step loop mutates block tables, lane lists, and device pools with
no locking.  The gateway therefore never touches the engine from the
asyncio event loop: everything crosses this boundary as a JOB — a
callable executed on the driver thread between engine steps — and
results come back on `concurrent.futures.Future`s.  Submissions,
cancellations, and metrics snapshots are all jobs, so they serialize
with `step()` for free and the engine needs no locks at all.

The driver also closes the one gap the engine's callback API leaves
for async callers: `ServeRequest.on_token` fires per token, but
nothing fires on completion.  `watch(req, on_done)` registers a
request; after every step (and every job drain) the driver sweeps its
watchlist and invokes `on_done(req)` exactly once when `req.done`
flips — cancellations, rejections, and clean finishes all land there.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs.trace import get_tracer


class EngineDriver:
    def __init__(self, engine, idle_wait_s: float = 0.05, tap=None):
        """`tap(engine)`, when given, runs on the driver thread once per
        loop iteration (after the step / job drain): the fleet replica
        uses it to publish an occupancy + prefix-fingerprint snapshot
        that the router reads lock-free per dispatch.  A tap exception
        never kills the serve loop."""
        self.engine = engine
        self._tap = tap
        self._jobs: "queue.Queue[Tuple[Callable, Future]]" = queue.Queue()
        self._watch: List[Tuple[Any, Callable]] = []
        self._wake = threading.Event()
        self._stop = threading.Event()
        # guards the dead flag vs. job enqueue: without it a job could
        # land in the queue after the thread's final drain and leave
        # its Future unresolved forever
        self._lock = threading.Lock()
        self._dead = False
        self._idle_wait_s = idle_wait_s
        self._thread = threading.Thread(target=self._run,
                                        name="engine-driver", daemon=True)
        self.steps = 0
        self.error: Optional[BaseException] = None   # fatal step failure
        self.tracer = get_tracer()
        self.flight_path: Optional[str] = None   # postmortem dump, set
        #   when a fatal step error makes the engine's flight recorder
        #   write its ring to disk

    # -- lifecycle ------------------------------------------------------
    def start(self) -> "EngineDriver":
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    # -- cross-thread API ----------------------------------------------
    def call(self, fn: Callable[[Any], Any]) -> Future:
        """Schedule `fn(engine)` on the driver thread (between steps);
        returns a Future with its result or exception.  A job sent to a
        driver that already died (fatal step error / stopped) fails
        immediately instead of hanging its caller forever."""
        fut: Future = Future()
        with self._lock:
            if self._dead:
                fut.set_exception(RuntimeError(
                    f"engine driver not running"
                    f"{f' ({self.error!r})' if self.error else ''}"))
                return fut
            self._jobs.put((fn, fut))
        self._wake.set()
        return fut

    def submit(self, reqs: List, on_done: Callable) -> Future:
        """Submit requests in order on the engine thread (fork children
        must follow their parent) and watch each for completion;
        resolves to the engine-assigned eids."""
        def job(engine):
            eids = []
            for r in reqs:
                engine.submit(r)
                self._watch.append((r, on_done))
                eids.append(r.eid)
            return eids
        return self.call(job)

    def cancel(self, eids: List[int]) -> Future:
        """Cancel by engine id; resolves to the number actually
        cancelled (watchers fire via the normal done sweep)."""
        return self.call(
            lambda engine: sum(bool(engine.cancel(e)) for e in eids))

    def extract_queued(self) -> Future:
        """Fleet drain: pull every not-yet-started request out of the
        engine's scheduler queue AND this driver's watchlist, so the
        router can resubmit them (with their original on_done watchers)
        on a healthy replica.  Runs as a job, so it serializes with
        step() like everything else.  The pulled requests' telemetry
        traces are forgotten here — they re-enqueue (and count) where
        they land — and any fork link is severed: engine ids are
        per-engine, so adopting parent KV across replicas would adopt
        an unrelated sequence's pages.  Resolves to [(req, on_done)]."""
        def job(engine):
            pulled = engine.scheduler.drain_queue()
            by_id = {id(r): r for r in pulled}
            out, still = [], []
            for req, cb in self._watch:
                if id(req) in by_id:
                    out.append((req, cb))
                else:
                    still.append((req, cb))
            self._watch = still
            watched = {id(r) for r, _ in out}
            for req in pulled:
                engine.telemetry.forget(req.eid)
                req.eid = -1
                req.fork_from = None
                req.forked_tokens = 0
                if id(req) not in watched:      # submitted without a
                    out.append((req, None))     # watcher: still re-home
            return out
        return self.call(job)

    # -- loop -----------------------------------------------------------
    def _drain_jobs(self) -> None:
        while True:
            try:
                fn, fut = self._jobs.get_nowait()
            except queue.Empty:
                return
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                with self.tracer.span("driver_job", cat="driver",
                                      thread=self._thread.name):
                    fut.set_result(fn(self.engine))
            except BaseException as e:   # the loop must survive any job
                fut.set_exception(e)

    def _sweep_done(self) -> None:
        if not self._watch:
            return
        still = []
        for req, on_done in self._watch:
            if req.done:
                try:
                    on_done(req)
                except Exception:       # a dead client callback must
                    pass                # never kill the serve loop
            else:
                still.append((req, on_done))
        self._watch = still

    def _run_tap(self) -> None:
        if self._tap is None:
            return
        try:
            self._tap(self.engine)
        except Exception:       # a broken snapshot publisher must
            pass                # never take the engine down

    def _run(self) -> None:
        engine = self.engine
        while not self._stop.is_set():
            self._drain_jobs()
            self._sweep_done()
            if engine.busy:
                try:
                    engine.step()
                except BaseException as e:
                    # the engine's host/device state may be corrupt:
                    # stop serving rather than limp on.  The recorded
                    # error surfaces through /healthz (503), so a
                    # liveness probe restarts the instance.  Dump the
                    # engine's flight recorder first: the dead-replica
                    # eviction that follows needs a postmortem, not
                    # silence.
                    self.error = e
                    recorder = getattr(engine, "recorder", None)
                    if recorder is not None:
                        recorder.record("fatal", error=repr(e))
                        self.flight_path = recorder.dump(reason=repr(e))
                    break
                self.steps += 1
                # publish AFTER the step but BEFORE the next sweep
                # fires done-watchers: by the time a client sees its
                # completion, the fleet snapshot (incl. any prefix
                # pages this step committed) is already visible
                self._run_tap()
            else:
                self._run_tap()
                self._wake.wait(self._idle_wait_s)
                self._wake.clear()
        # shutdown / fatal error: mark dead under the lock (new call()s
        # now fail fast), drain whatever was already queued, and fail
        # every request still in flight — a watcher left un-notified
        # would hang its gateway handler forever and pin its inflight
        # budget slot
        with self._lock:
            self._dead = True
            self._drain_jobs()
        for req, _ in self._watch:
            if not req.done:
                req.done = True
                req.cancelled = True
        self._sweep_done()
