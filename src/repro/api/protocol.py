"""Wire schema for the streaming gateway: request parsing, SSE framing.

The gateway speaks an OpenAI-style completions dialect over token ids —
this repo has no tokenizer, so `prompt` is a list of int token ids (the
same currency every benchmark and test in the repo trades in).  Parsing
is strict and total: every malformed field maps to a `ProtocolError`
with a client-usable message, never a traceback through the engine.

SSE framing follows the EventSource spec's `data:` lines.  The stream
carries one JSON event per sampled token (`{"index", "token"}`), one
finish event per sample index (`{"index", "finish_reason"}`), and a
final `[DONE]` sentinel — byte-parseable with `iter_sse` below, which
the load benchmark and the e2e tests both use.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple


class ProtocolError(ValueError):
    """Client error: maps to HTTP 400 with `.message` as the body."""

    def __init__(self, message: str):
        super().__init__(message)
        self.message = message


@dataclass
class CompletionRequest:
    """Validated `POST /v1/completions` body."""
    prompt: List[int]
    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    n: int = 1                       # parallel samples (KV fork-shared)
    stream: bool = True
    priority: int = 0
    deadline_s: Optional[float] = None
    spec: bool = True                # opt out of speculative decoding
    logprobs: bool = False           # per-token logprob + entropy in the
    #   stream / response (host-side O(vocab) per token when on)


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ProtocolError(msg)


def parse_completion(body: bytes, *, vocab: Optional[int] = None,
                     max_n: int = 8,
                     max_prompt_len: Optional[int] = None
                     ) -> CompletionRequest:
    """Parse + validate a completions body; raises ProtocolError with a
    message safe to return to the client."""
    try:
        obj = json.loads(body.decode("utf-8") if body else "")
    except (ValueError, UnicodeDecodeError):
        raise ProtocolError("body is not valid JSON")
    _require(isinstance(obj, dict), "body must be a JSON object")

    prompt = obj.get("prompt")
    _require(isinstance(prompt, list) and len(prompt) > 0,
             "'prompt' must be a non-empty list of int token ids")
    _require(all(isinstance(t, int) and not isinstance(t, bool)
                 and t >= 0 for t in prompt),
             "'prompt' tokens must be non-negative ints")
    if vocab is not None:
        _require(all(t < vocab for t in prompt),
                 f"'prompt' token id out of range (vocab={vocab})")
    if max_prompt_len is not None:
        _require(len(prompt) < max_prompt_len,
                 f"'prompt' longer than max_seq-1 ({max_prompt_len - 1})")

    def _num(key, default, lo, hi, cast, kind):
        v = obj.get(key, default)
        _require(isinstance(v, (int, float)) and not isinstance(v, bool)
                 and math.isfinite(v) and lo <= v <= hi,
                 f"'{key}' must be a {kind} in [{lo}, {hi}]")
        return cast(v)

    req = CompletionRequest(
        prompt=list(prompt),
        max_tokens=_num("max_tokens", 16, 1, 1 << 20, int, "int"),
        temperature=_num("temperature", 0.0, 0.0, 1e3, float, "number"),
        top_k=_num("top_k", 0, 0, 1 << 20, int, "int"),
        top_p=_num("top_p", 1.0, 0.0, 1.0, float, "number"),
        n=_num("n", 1, 1, max_n, int, "int"),
        priority=_num("priority", 0, -(1 << 16), 1 << 16, int, "int"),
    )
    for key, default in (("stream", True), ("spec", True),
                         ("logprobs", False)):
        v = obj.get(key, default)       # strict bools: a JS client's
        _require(isinstance(v, bool),   # "false" string must 400, not
                 f"'{key}' must be a bool")     # silently invert its
        setattr(req, key, v)                    # meaning
    dl = obj.get("deadline_s")
    if dl is not None:
        _require(isinstance(dl, (int, float)) and not isinstance(dl, bool)
                 and math.isfinite(dl) and dl > 0,
                 "'deadline_s' must be a positive number")
        req.deadline_s = float(dl)
    return req


# ----------------------------------------------------------------------------
# SSE framing
# ----------------------------------------------------------------------------
DONE_SENTINEL = "[DONE]"


def sanitize(obj):
    """NaN/inf -> None recursively: the /metrics and SSE payloads must
    stay strict-JSON parseable for non-Python clients (json.dumps would
    happily emit bare NaN)."""
    if isinstance(obj, dict):
        return {k: sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def sse_event(obj: Dict) -> bytes:
    return b"data: " + json.dumps(sanitize(obj),
                                  separators=(",", ":")).encode() + b"\n\n"


def sse_done() -> bytes:
    return f"data: {DONE_SENTINEL}\n\n".encode()


def iter_sse(payload: bytes) -> Iterator[Dict]:
    """Parse a complete SSE byte stream into its JSON events (the
    `[DONE]` sentinel is consumed, not yielded).  Shared by the load
    generator and the e2e tests so both exercise the real framing."""
    for block in payload.split(b"\n\n"):
        line = block.strip()
        if not line.startswith(b"data: "):
            continue
        data = line[len(b"data: "):]
        if data.decode("utf-8", "replace") == DONE_SENTINEL:
            return
        yield json.loads(data)


# ----------------------------------------------------------------------------
# minimal HTTP/1.1 framing (stdlib-only; shared by server and clients)
# ----------------------------------------------------------------------------
def http_response(status: int, reason: str, headers: Dict[str, str],
                  body: bytes = b"") -> bytes:
    lines = [f"HTTP/1.1 {status} {reason}"]
    hdrs = dict(headers)
    hdrs.setdefault("Connection", "close")
    if body:
        hdrs.setdefault("Content-Length", str(len(body)))
    lines += [f"{k}: {v}" for k, v in hdrs.items()]
    return ("\r\n".join(lines) + "\r\n\r\n").encode() + body


def json_response(status: int, reason: str, obj: Dict,
                  headers: Optional[Dict[str, str]] = None) -> bytes:
    body = json.dumps(sanitize(obj), indent=1).encode()
    hdrs = {"Content-Type": "application/json"}
    hdrs.update(headers or {})
    return http_response(status, reason, hdrs, body)


def error_response(status: int, reason: str, message: str,
                   headers: Optional[Dict[str, str]] = None) -> bytes:
    return json_response(status, reason,
                         {"error": {"message": message,
                                    "type": reason.lower().replace(" ",
                                                                   "_")}},
                         headers)


async def read_http_request(reader) -> Tuple[str, str, Dict[str, str],
                                             bytes]:
    """Read one HTTP/1.1 request from an asyncio StreamReader:
    (method, path, headers, body).  Raises ProtocolError on framing it
    cannot serve; raises asyncio.IncompleteReadError / ConnectionError
    on a socket that died mid-request (callers treat that as a
    disconnect, not a client error)."""
    try:
        request_line = await reader.readline()
    except ValueError:      # StreamReader limit overrun: line too long
        raise ProtocolError("request line too long")
    if not request_line:
        raise ConnectionError("client closed before sending a request")
    parts = request_line.decode("latin-1").split()
    if len(parts) != 3:
        raise ProtocolError("malformed request line")
    method, path = parts[0].upper(), parts[1]
    headers: Dict[str, str] = {}
    for _ in range(256):            # cap header LINES: an endless (or
        try:                        # colon-less) header stream must not
            line = await reader.readline()      # be read forever
        except ValueError:
            raise ProtocolError("header line too long")
        if line in (b"\r\n", b"\n", b""):
            break
        if b":" in line:
            k, v = line.decode("latin-1").split(":", 1)
            headers[k.strip().lower()] = v.strip()
    else:
        raise ProtocolError("too many headers")
    length = headers.get("content-length", "0")
    try:
        n = int(length)
    except ValueError:
        raise ProtocolError("bad Content-Length")
    if n < 0 or n > (1 << 22):
        raise ProtocolError("bad Content-Length")
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body
