"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 64 routed experts top-6
+ 2 shared, d_ff/expert=1408 [arXiv:2405.04434; hf].  27L d_model=2048 16H
vocab=102400; layer 0 is a dense FFN (d_ff=10944) per the HF config.
NOTE: the assignment line self-conflicts (64e top-6 vs "160 routed"); we
follow the leading spec = the actual V2-Lite (64 routed)."""
from repro.models import MLAConfig, MoEConfig, ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=10944, vocab=102400, head_dim=128,
        attn_kind="mla",
        mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                      qk_rope_head_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2,
                      d_ff_expert=1408, first_dense_layers=1,
                      first_dense_d_ff=10944, dispatch="onehot"),
        tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        attn_kind="mla",
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=1,
                      d_ff_expert=32, first_dense_layers=1,
                      first_dense_d_ff=128, capacity_factor=2.5),
        tie_embeddings=False)


register("deepseek-v2-lite-16b", full, smoke, long_ok=False)
