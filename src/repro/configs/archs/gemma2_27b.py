"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — 1:1 local(4096):global alternation, attn/final logit
softcaps, pre+post block norms, head_dim=128 [arXiv:2408.00118; hf].
long_500k runs: local layers are sub-quadratic (bounded window); global
layers decode against a split-K sharded cache (DESIGN.md SS4)."""
from repro.models import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense", n_layers=46, d_model=4608,
        n_heads=32, n_kv_heads=16, d_ff=36864, vocab=256000, head_dim=128,
        ffn_act="gelu_tanh", local_window=4096, local_pattern=2,
        attn_softcap=50.0, final_softcap=30.0, post_block_norm=True,
        rms_scale_plus_one=True, embed_scale=True, tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128, head_dim=16,
        ffn_act="gelu_tanh", local_window=8, local_pattern=2,
        attn_softcap=50.0, final_softcap=30.0, post_block_norm=True,
        rms_scale_plus_one=True, embed_scale=True, tie_embeddings=True)


register("gemma2-27b", full, smoke, long_ok=True)
