"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA with QKV bias [hf:Qwen/Qwen2.5-3B].
kv=2 < model-axis width -> decode KV shards on sequence (split-K)."""
from repro.models import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936, head_dim=128,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        qkv_bias=True, tie_embeddings=True)


register("qwen2.5-3b", full, smoke, long_ok=False)
