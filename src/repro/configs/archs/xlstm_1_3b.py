"""xlstm-1.3b [ssm]: 48L d_model=2048 4H — sLSTM + mLSTM blocks at 7:1
[arXiv:2405.04517].  Recurrent state, O(1)/token decode -> long_500k runs.
d_ff=0 per the assignment: mLSTM blocks carry their own up/down projection
(factor 2); sLSTM blocks carry a gated FFN (factor 4/3)."""
from repro.models import ModelConfig, SSMConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="xlstm", n_layers=48, d_model=2048,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
        ssm=SSMConfig(mlstm_heads=4, slstm_every=8, proj_factor_mlstm=2.0,
                      proj_factor_slstm=4.0 / 3.0, conv_width=4),
        tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="xlstm", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=128,
        ssm=SSMConfig(mlstm_heads=4, slstm_every=4, proj_factor_mlstm=2.0,
                      proj_factor_slstm=4.0 / 3.0, conv_width=4),
        tie_embeddings=False)


register("xlstm-1.3b", full, smoke, long_ok=True)
