"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — Mistral-NeMo-style text decoder [hf:mistralai/Pixtral-12B].
Backbone only: the Pixtral-ViT frontend is a stub; `input_specs()` feeds
precomputed patch+text embeddings."""
from repro.models import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, head_dim=128,
        rope_theta=1e6, embed_inputs=False, tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        embed_inputs=False, tie_embeddings=False)


register("pixtral-12b", full, smoke, long_ok=False)
