"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336
ssm_state=64 — Mamba2 backbone + SHARED attention+MLP block invoked every
6 mamba layers with per-site LoRA (13 invocations + 3 trailing mamba
layers; 13*6+3 = 81) [arXiv:2411.15242].  Hybrid recurrence -> long_500k
runs."""
from repro.models import ModelConfig, SSMConfig, ZambaConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="zamba", n_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000, head_dim=112,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      chunk=256),
        zamba=ZambaConfig(shared_every=6, lora_rank=64, shared_d_ff=14336),
        tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="zamba", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      chunk=16),
        zamba=ZambaConfig(shared_every=2, lora_rank=8, shared_d_ff=128),
        tie_embeddings=False)


register("zamba2-7b", full, smoke, long_ok=True)
