"""Per-arch config modules — importing this package registers all archs."""
from . import (musicgen_medium, deepseek_v2_lite_16b, qwen3_moe_235b_a22b,
               phi3_medium_14b, gemma2_27b, gemma3_4b, qwen2_5_3b,
               pixtral_12b, xlstm_1_3b, zamba2_7b)  # noqa: F401
