"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local(1024):global, QK-norm, head_dim=256, dual rope
theta (10k local / 1M global), 128k+ context [hf:google/gemma-3-4b-pt]."""
from repro.models import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
        n_heads=8, n_kv_heads=4, d_ff=10240, vocab=262144, head_dim=256,
        ffn_act="gelu_tanh", local_window=1024, local_pattern=6,
        qk_norm=True, rope_theta=1e6, rope_theta_local=10000.0,
        post_block_norm=True, rms_scale_plus_one=True, embed_scale=True,
        tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        ffn_act="gelu_tanh", local_window=8, local_pattern=3,
        qk_norm=True, rope_theta=1e6, rope_theta_local=10000.0,
        post_block_norm=True, rms_scale_plus_one=True, embed_scale=True,
        tie_embeddings=True)


register("gemma3-4b", full, smoke, long_ok=True)
