"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) 128 experts
top-8, d_ff/expert=1536, vocab=151936 [hf:Qwen/Qwen3-30B-A3B scaled; hf].
QK-norm per the Qwen3 recipe; no shared experts."""
from repro.models import MoEConfig, ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
        n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, n_shared_experts=0,
                      d_ff_expert=1536,
                      # grouped one-hot dispatch: 6.3x lower collective
                      # bytes than sort/gather at pod scale (SSPerf b2/b3)
                      dispatch="onehot"),
        tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=2, d_ff=96, vocab=128, head_dim=16,
        qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared_experts=0,
                      d_ff_expert=96, capacity_factor=2.5),
        tie_embeddings=False)


register("qwen3-moe-235b-a22b", full, smoke, long_ok=False)
