"""musicgen-medium [audio]: decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  48L d_model=1536 24H (MHA) d_ff=6144 vocab=2048.
Backbone only — the EnCodec frontend is a stub: `input_specs()` feeds
precomputed frame embeddings.  LayerNorm + (non-gated) GELU FFN per the
original transformer recipe."""
from repro.models import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="dense", n_layers=48, d_model=1536,
        n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
        norm_kind="layer", ffn_act="gelu", ffn_gated=False,
        embed_inputs=False, tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
        norm_kind="layer", ffn_act="gelu", ffn_gated=False,
        embed_inputs=False, tie_embeddings=False)


register("musicgen-medium", full, smoke, long_ok=False)
