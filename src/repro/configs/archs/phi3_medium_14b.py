"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.models import ModelConfig
from repro.configs.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352, head_dim=128,
        tie_embeddings=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128, head_dim=16,
        tie_embeddings=False)


register("phi3-medium-14b", full, smoke, long_ok=False)
