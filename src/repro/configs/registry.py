"""Architecture registry (populated by the per-arch config modules)."""
from __future__ import annotations

from typing import Callable, Dict

_REGISTRY: Dict[str, Callable] = {}
_SMOKE: Dict[str, Callable] = {}
_LONG_OK: Dict[str, bool] = {}

SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

# (seq_len, global_batch, kind) per assigned shape
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def register(arch_id: str, full: Callable, smoke: Callable,
             long_ok: bool = False) -> None:
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke
    _LONG_OK[arch_id] = long_ok


def supports_long(arch_id: str) -> bool:
    _ensure_loaded()
    return _LONG_OK[arch_id]


def shapes_for(arch_id: str):
    """Shape ids applicable to this arch (long_500k needs sub-quadratic
    attention — skipped for pure full-attention archs, DESIGN.md SS4)."""
    _ensure_loaded()
    ids = ["train_4k", "prefill_32k", "decode_32k"]
    if _LONG_OK[arch_id]:
        ids.append("long_500k")
    return tuple(ids)


def _ensure_loaded() -> None:
    # import all per-arch modules (they call register() at import time)
    from . import archs  # noqa: F401


def get_config(arch_id: str):
    _ensure_loaded()
    return _REGISTRY[arch_id]()


def get_smoke_config(arch_id: str):
    _ensure_loaded()
    return _SMOKE[arch_id]()


@property
def _arch_ids():
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


class _ArchIds:
    """Lazy tuple-like view over registered arch ids."""

    def __iter__(self):
        _ensure_loaded()
        return iter(sorted(_REGISTRY))

    def __contains__(self, x):
        _ensure_loaded()
        return x in _REGISTRY

    def __len__(self):
        _ensure_loaded()
        return len(_REGISTRY)

    def __repr__(self):
        _ensure_loaded()
        return repr(tuple(sorted(_REGISTRY)))


ARCH_IDS = _ArchIds()


def input_specs(arch_id: str, shape_id: str, multi_pod: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of (arch, shape).

    Defined in launch.dryrun's support module to keep jax imports out of the
    registry; re-exported here for convenience.
    """
    from repro.launch.specs import input_specs as _impl
    return _impl(arch_id, shape_id, multi_pod=multi_pod)
