"""Config registry: assigned architectures + paper SLM suite.

`get_config(arch_id)` returns the full-scale ModelConfig for an assigned
architecture; `get_smoke_config(arch_id)` a reduced same-family variant.
`PAPER_SLMS` maps the paper's 12 benchmark SLMs to core.SLMSpec objects.
"""
from .registry import (ARCH_IDS, get_config, get_smoke_config, register,
                       input_specs, SHAPE_IDS)
from .paper_slms import PAPER_SLMS, paper_slm

__all__ = ["ARCH_IDS", "SHAPE_IDS", "get_config", "get_smoke_config",
           "register", "input_specs", "PAPER_SLMS", "paper_slm"]
