"""The paper's 12-SLM benchmark suite (Sec. V, Fig. 9) as core.SLMSpec.

Architecture numbers from the public HF configs of each model.  These feed
the EdgeCIM analytical simulator / DSE — they are the *workload* side of
the co-design and are deliberately lightweight (no JAX model needed for the
paper's own evaluation; the JAX models cover the assigned architectures).
"""
from __future__ import annotations

from typing import Dict

from repro.core.workload import SLMSpec

PAPER_SLMS: Dict[str, SLMSpec] = {
    "tinyllama-1.1b": SLMSpec(
        name="tinyllama-1.1b", n_layers=22, d_model=2048, n_heads=32,
        n_kv_heads=4, d_ff=5632, vocab=32000, head_dim=64),
    "llama3.2-1b": SLMSpec(
        name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32,
        n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=64),
    "llama3.2-3b": SLMSpec(
        name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=128),
    "phi3.5-mini-3.8b": SLMSpec(
        name="phi3.5-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064, head_dim=96),
    "qwen2.5-0.5b": SLMSpec(
        name="qwen2.5-0.5b", n_layers=24, d_model=896, n_heads=14,
        n_kv_heads=2, d_ff=4864, vocab=151936, head_dim=64, qkv_bias=True),
    "qwen2.5-1.5b": SLMSpec(
        name="qwen2.5-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_ff=8960, vocab=151936, head_dim=128, qkv_bias=True),
    "qwen2.5-3b": SLMSpec(
        name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16,
        n_kv_heads=2, d_ff=11008, vocab=151936, head_dim=128, qkv_bias=True),
    "smollm2-1.7b": SLMSpec(
        name="smollm2-1.7b", n_layers=24, d_model=2048, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=49152, head_dim=64),
    "smollm3-3b": SLMSpec(
        name="smollm3-3b", n_layers=36, d_model=2048, n_heads=16,
        n_kv_heads=4, d_ff=11008, vocab=128256, head_dim=128),
    "qwen3-0.6b": SLMSpec(
        name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
        n_kv_heads=8, d_ff=3072, vocab=151936, head_dim=128),
    "qwen3-1.7b": SLMSpec(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=6144, vocab=151936, head_dim=128),
    "qwen3-4b": SLMSpec(
        name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32,
        n_kv_heads=8, d_ff=9728, vocab=151936, head_dim=128),
}


def paper_slm(name: str) -> SLMSpec:
    return PAPER_SLMS[name]
