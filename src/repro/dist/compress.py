"""Inter-pod gradient compression: INT8 quantization with error feedback.

Between pods only gradients move (params are replicated per pod, FSDP
within).  Quantizing that traffic to INT8 cuts the inter-pod bytes 4x;
the residual (quantization error) is carried forward and added to the
next step's gradient, so the accumulated update is unbiased — the
standard error-feedback trick.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor INT8 quantization -> (int8 codes, f32 scale)."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    safe = jnp.where(scale > 0, scale, 1.0)
    return q.astype(jnp.float32) * jnp.where(scale > 0, safe, 0.0)


def compress_decompress_roundtrip(x: jax.Array) -> jax.Array:
    """What the receiving pod reconstructs from one tensor's gradient."""
    return _dq8(*_q8(x))


def init_error_state(grads: Any) -> Any:
    """Zero error-feedback residual matching a gradient pytree."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_feedback(grads: Any, err: Any) -> Tuple[Any, Any]:
    """(grads, residual) -> (decoded grads as the far pod sees them,
    updated residual).  Applied leaf-wise over the gradient pytree."""

    def per_leaf(g, e):
        gf = g.astype(jnp.float32) + e
        dec = compress_decompress_roundtrip(gf)
        return dec.astype(g.dtype), gf - dec

    flat = jax.tree_util.tree_map(per_leaf, grads, err)
    dec = jax.tree_util.tree_map(lambda t: t[0], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return dec, new_err
