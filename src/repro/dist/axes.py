"""Logical-axis -> mesh-axis rule tables.

Model code declares LOGICAL axes on every parameter / activation
(`batch`, `fsdp`, `tp`, `expert`, `kv_seq`, `seq`, `layers` — see
models/common.py); a `MeshRules` table maps those names onto the
physical mesh axes of a given topology.  The same model definition then
lowers on a single pod (data x model), a multi-pod super-mesh
(pod x data x model), or a host mesh (1 x 1) without edits.

`sanitize_pspec` drops mesh axes that do not divide the corresponding
array dimension (ragged vocab rows, tiny norm vectors): XLA requires
even sharding, and an un-shardable dim is simply replicated.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

from jax.sharding import PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class MeshRules:
    """Mapping from logical axis name to mesh axis (or axes, or None)."""
    table: Dict[str, AxisEntry] = field(default_factory=dict)

    def get(self, name: Optional[str]) -> AxisEntry:
        if name is None:
            return None
        return self.table.get(name)

    def pspec(self, axes: Tuple[Optional[str], ...]) -> P:
        return P(*[self.get(a) for a in axes])

    def replace(self, **kw: AxisEntry) -> "MeshRules":
        return MeshRules({**self.table, **kw})


SINGLE_POD_RULES = MeshRules({
    "batch": "data", "fsdp": "data", "tp": "model", "expert": "model",
    "kv_seq": "model", "seq": "data", "layers": None,
})

# Multi-pod: activations batch-shard over (pod, data); params stay
# FSDP-sharded within a pod (each pod holds a full copy -> inter-pod
# traffic is gradients only, which dist/compress.py quantizes to INT8).
MULTI_POD_RULES = MeshRules({
    "batch": ("pod", "data"), "fsdp": "data", "tp": "model",
    "expert": "model", "kv_seq": "model", "seq": "data", "layers": None,
})

# Serving: one engine = one 1-D ("model",) mesh of `tp` devices.  Only
# TP-marked dims shard — attention heads / KV-head groups (and their
# INT8 scale pools), FFN width, the vocab dim of embed/head, and the
# head-split dims of StateArena cells.  Everything page- or lane-wise
# (batch lanes, the page axis, block tables, sequence positions) stays
# replicated: block tables live host-side and must be per-shard
# identical, so COW/fork/trim/prefix adoption patch every shard's pools
# the same way.  fsdp/kv_seq/seq map to None (no data axis at serve
# time); the contraction after the O / w_down projections becomes the
# GSPMD all-reduce.
SERVE_RULES = MeshRules({
    "batch": None, "fsdp": None, "tp": "model", "expert": "model",
    "kv_seq": None, "seq": None, "layers": None,
})


def rules_for_mesh(mesh) -> MeshRules:
    return MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def _axis_size(mesh, entry: AxisEntry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    n = 1
    for a in names:
        n *= int(mesh.shape[a])
    return n


def sanitize_pspec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Replicate any dim the mesh axes cannot evenly divide."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return P(*out)
