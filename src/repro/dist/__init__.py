"""Distribution substrate: logical-axis mesh rules, sharding helpers, and
inter-pod gradient compression."""
from .axes import (MeshRules, MULTI_POD_RULES, SERVE_RULES,
                   SINGLE_POD_RULES, rules_for_mesh, sanitize_pspec)
from .compress import compress_decompress_roundtrip, init_error_state
from .shard import (constrain, qtree_shardings, replicated, serve_mesh,
                    tree_shardings, use_mesh_rules)

__all__ = [
    "MeshRules", "MULTI_POD_RULES", "SERVE_RULES", "SINGLE_POD_RULES",
    "rules_for_mesh",
    "sanitize_pspec", "compress_decompress_roundtrip", "init_error_state",
    "constrain", "qtree_shardings", "replicated", "serve_mesh",
    "tree_shardings", "use_mesh_rules",
]
