"""Sharding helpers: logical-axis constraints + spec-tree -> NamedSharding.

`constrain` is the boundary-hint primitive model code calls between
blocks (`constrain(x, "batch", None, "tp")`).  It is a no-op unless a
`use_mesh_rules(mesh, rules)` context is active — smoke tests and the
single-host serve engine run the very same model code with zero SPMD
overhead, while the dry-run/pjit path gets real with_sharding_constraint
hints.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .axes import MeshRules, sanitize_pspec

_ctx = threading.local()


def _current() -> Optional[Tuple[Mesh, MeshRules]]:
    return getattr(_ctx, "mesh_rules", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: MeshRules):
    prev = _current()
    _ctx.mesh_rules = (mesh, rules)
    try:
        yield
    finally:
        _ctx.mesh_rules = prev


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Sharding-constrain `x` by logical axis names (no-op w/o context)."""
    cur = _current()
    if cur is None:
        return x
    mesh, rules = cur
    spec = sanitize_pspec(rules.pspec(tuple(axes)), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------------
# spec trees -> sharding trees
# ----------------------------------------------------------------------------
def _leaf_sharding(axes, shape, mesh, rules) -> NamedSharding:
    return NamedSharding(mesh, sanitize_pspec(rules.pspec(axes), shape, mesh))


def tree_shardings(spec_tree: Any, mesh: Mesh, rules: MeshRules) -> Any:
    """ParamSpec pytree -> NamedSharding pytree (same structure)."""
    from repro.models.common import is_spec
    return jax.tree_util.tree_map(
        lambda s: _leaf_sharding(s.axes, s.shape, mesh, rules),
        spec_tree, is_leaf=is_spec)


def qtree_shardings(spec_tree: Any, qtree: Any, mesh: Mesh,
                    rules: MeshRules) -> Any:
    """Shardings for a (possibly quantized) param tree.

    `qtree` mirrors `spec_tree` except eligible weights are QTensor nodes
    (packed data + scales); both QTensor fields shard by the dense
    weight's logical axes, re-sanitized against their own (packed /
    grouped) shapes.
    """
    from repro.models.common import is_spec
    from repro.quant.qarray import QTensor

    def per_leaf(spec, q):
        if isinstance(q, QTensor):
            return QTensor(
                data=_leaf_sharding(spec.axes, q.data.shape, mesh, rules),
                scales=_leaf_sharding(spec.axes, q.scales.shape, mesh,
                                      rules),
                bits=q.bits, group=q.group, axis=q.axis,
                orig_shape=q.orig_shape)
        return _leaf_sharding(spec.axes, q.shape, mesh, rules)

    return jax.tree_util.tree_map(
        per_leaf, spec_tree, qtree,
        is_leaf=lambda x: is_spec(x))
