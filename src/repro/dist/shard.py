"""Sharding helpers: logical-axis constraints + spec-tree -> NamedSharding.

`constrain` is the boundary-hint primitive model code calls between
blocks (`constrain(x, "batch", None, "tp")`).  It is a no-op unless a
`use_mesh_rules(mesh, rules)` context is active — smoke tests and the
single-host serve engine run the very same model code with zero SPMD
overhead, while the dry-run/pjit path gets real with_sharding_constraint
hints.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .axes import MeshRules, _axis_size, sanitize_pspec

_ctx = threading.local()


def serve_mesh(tp: int) -> Mesh:
    """1-D ("model",) mesh over the first `tp` local devices — the mesh
    one TP-sharded serve engine runs on.  Replicas may share the same
    devices (data parallelism is the fleet's job, not the mesh's).
    Raises with the host-mesh escape hatch when the platform exposes
    fewer devices than `tp`."""
    import numpy as np
    devs = jax.devices()
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if len(devs) < tp:
        raise ValueError(
            f"tp={tp} needs {tp} devices but the {devs[0].platform} "
            f"backend exposes {len(devs)}; on CPU force a host mesh "
            f"with XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={tp}")
    return Mesh(np.asarray(devs[:tp]), ("model",))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated NamedSharding (host-fed tokens/tables/lengths
    and the gathered logits)."""
    return NamedSharding(mesh, P())


def _current() -> Optional[Tuple[Mesh, MeshRules]]:
    return getattr(_ctx, "mesh_rules", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: MeshRules):
    prev = _current()
    _ctx.mesh_rules = (mesh, rules)
    try:
        yield
    finally:
        _ctx.mesh_rules = prev


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Sharding-constrain `x` by logical axis names (no-op w/o context)."""
    cur = _current()
    if cur is None:
        return x
    mesh, rules = cur
    spec = sanitize_pspec(rules.pspec(tuple(axes)), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------------
# spec trees -> sharding trees
# ----------------------------------------------------------------------------
def _leaf_sharding(axes, shape, mesh, rules) -> NamedSharding:
    return NamedSharding(mesh, sanitize_pspec(rules.pspec(axes), shape, mesh))


def tree_shardings(spec_tree: Any, mesh: Mesh, rules: MeshRules) -> Any:
    """ParamSpec pytree -> NamedSharding pytree (same structure)."""
    from repro.models.common import is_spec
    return jax.tree_util.tree_map(
        lambda s: _leaf_sharding(s.axes, s.shape, mesh, rules),
        spec_tree, is_leaf=is_spec)


def qtree_shardings(spec_tree: Any, qtree: Any, mesh: Mesh,
                    rules: MeshRules) -> Any:
    """Shardings for a (possibly quantized) param tree.

    `qtree` mirrors `spec_tree` except eligible weights are QTensor
    nodes (packed data + scales).  Both QTensor fields shard by the
    dense weight's logical axes, but a dim is sharded only when the
    mesh axis divides it in EVERY materialization — orig_shape, the
    packed data (int4 halves the quant axis), and the group-scale array
    (quant-axis dim is K/group).  Sanitizing data and scales
    independently against the dense axes could shard the data while
    replicating (or raggedly splitting) its scales, silently
    misaligning the per-group dequant — so one pspec is computed across
    all three shapes and applied to both fields."""
    from repro.models.common import is_spec
    from repro.quant.qarray import QTensor

    def per_leaf(spec, q):
        if isinstance(q, QTensor):
            entries = tuple(rules.pspec(spec.axes)) + (None,) * len(
                q.orig_shape)
            out = []
            for i, entry in enumerate(entries[:len(q.orig_shape)]):
                n = _axis_size(mesh, entry)
                if entry is not None and any(
                        shape[i] % n != 0 for shape in
                        (q.orig_shape, q.data.shape, q.scales.shape)):
                    entry = None
                out.append(entry)
            spec_p = P(*out)
            return QTensor(
                data=NamedSharding(mesh, spec_p),
                scales=NamedSharding(mesh, spec_p),
                bits=q.bits, group=q.group, axis=q.axis,
                orig_shape=q.orig_shape)
        return _leaf_sharding(spec.axes, q.shape, mesh, rules)

    return jax.tree_util.tree_map(
        per_leaf, spec_tree, qtree,
        is_leaf=lambda x: is_spec(x))
