"""Speculative decoding over the paged-KV runtime.

drafter -> paged_verify_step (multi-query attention over the page pool)
-> accept/reject (target-distribution-preserving) -> multi-token append
+ rollback (`PagedKVCache.trim`).  See repro/serve/README.md.
"""
from .decode import SpecConfig, SpecDecoder
from .drafter import Drafter, DraftModelDrafter, DraftProposal, NGramDrafter
from .verify import accept_draft

__all__ = ["SpecConfig", "SpecDecoder", "Drafter", "DraftModelDrafter",
           "DraftProposal", "NGramDrafter", "accept_draft"]
