"""Speculative accept/reject sampling — provably target-preserving.

Given the target model's logits over a draft window (one verify pass =
`DecoderLM.paged_verify_step`), walk the window left to right:

  greedy lanes     accept draft token j iff it IS the target argmax at
                   position j — the emitted stream is byte-identical to
                   plain decode, speculation only changes how many
                   tokens each step yields;
  sampling lanes   accept draft x_j ~ q_j with probability
                   min(1, p_j(x_j) / q_j(x_j)); on the first rejection
                   emit one token from the residual
                   norm(max(p_j - q_j, 0)) and stop.

The stochastic rule is the standard speculative-sampling identity
(Leviathan et al. / Chen et al.): accepted-or-residual output is an
exact sample from p_j, so the served distribution equals the target's
regardless of how bad the drafter is — drafter quality moves only the
acceptance RATE.  A model-free drafter (prompt-lookup n-gram) is the
degenerate q = point-mass case: accept with probability p_j(x_j),
residual = p_j with x_j zeroed out.

Every step emits the accepted prefix PLUS one token sampled from the
position after it (the "bonus" — on zero acceptance this is exactly a
plain decode step), so progress is always >= 1 token/step.

All math runs host-side in float64 on the (v,) rows `processed_probs`
derives with the SAME truncation rules the engine samples with — using
raw softmax here would silently disable a lane's top-k/top-p.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.serve.sampling import SamplingParams, processed_probs


def _residual_draw(p: np.ndarray, q: np.ndarray,
                   rng: np.random.Generator) -> int:
    """Sample from norm(max(p - q, 0)); degenerates to p when p == q."""
    res = np.maximum(p - q, 0.0)
    z = res.sum()
    if z <= 0.0:
        return int(rng.choice(p.shape[0], p=p / p.sum()))
    return int(rng.choice(p.shape[0], p=res / z))


def accept_draft(p_logits: np.ndarray, draft: np.ndarray,
                 q_probs: Optional[np.ndarray], sampling: SamplingParams,
                 rng: np.random.Generator) -> Tuple[int, List[int]]:
    """One lane's accept/reject walk over a verified draft window.

    p_logits: (n_draft + 1, v) target logits — row j conditions on the
    prefix plus draft[:j]; draft: (n_draft,) proposed tokens; q_probs:
    (n_draft, v) draft distributions, or None for a point-mass drafter.
    Returns (n_accepted, emitted) where emitted carries the accepted
    prefix plus the bonus/residual token (len == n_accepted + 1).
    """
    n_draft = int(len(draft))
    assert p_logits.shape[0] >= n_draft + 1

    if sampling.temperature <= 0.0:                      # greedy: exact match
        emitted: List[int] = []
        for j in range(n_draft):
            top = int(np.argmax(p_logits[j]))
            if int(draft[j]) != top:
                return j, emitted + [top]
            emitted.append(top)
        return n_draft, emitted + [int(np.argmax(p_logits[n_draft]))]

    emitted = []
    for j in range(n_draft):
        p = processed_probs(p_logits[j], sampling.temperature,
                            sampling.top_k, sampling.top_p)
        x = int(draft[j])
        if q_probs is None:                              # point-mass drafter
            q = np.zeros_like(p)
            q[x] = 1.0
        else:
            q = np.asarray(q_probs[j], np.float64)
        accept_p = 1.0 if q[x] <= 0.0 else min(1.0, p[x] / q[x])
        # q[x] == 0 means the drafter reports a distribution it did not
        # actually sample x from (shouldn't happen); accepting p-side
        # keeps the walk defined
        if p[x] > 0.0 and rng.random() < accept_p:
            emitted.append(x)
            continue
        return j, emitted + [_residual_draw(p, q, rng)]
    p_last = processed_probs(p_logits[n_draft], sampling.temperature,
                             sampling.top_k, sampling.top_p)
    return n_draft, emitted + [int(rng.choice(p_last.shape[0],
                                              p=p_last / p_last.sum()))]
