"""Drafters: cheap token-proposal sources behind one batched interface.

Two extremes of the draft-cost spectrum:

  NGramDrafter       model-free prompt-lookup (a.k.a. prompt-lookup /
                     assisted decoding): the longest recent suffix that
                     re-occurs earlier in (prompt + generated) predicts
                     its historical continuation.  Zero FLOPs, point-mass
                     q — ideal for the repetitive/extractive workloads
                     edge SLMs actually serve.
  DraftModelDrafter  a small `DecoderLM` running the same paged runtime
                     (`paged_step` + its own `PagedKVCache`).  Its cache
                     only ever holds target-verified tokens at round
                     boundaries: proposals are drafted ahead, then the
                     draft cache is rolled back (`trim`) and re-fed the
                     accepted prefix next round — rejection never leaves
                     phantom state behind.

The engine drives `propose(histories, k, sampling)` once per decode
step with the FULL lane vector (inactive lanes None), so a model-backed
drafter can batch its own forward passes shape-stably.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.paged_cache import PagedKVCache
from repro.serve.sampling import processed_probs, sample_tokens


@dataclass
class DraftProposal:
    """tokens: (b, k) int32 right-padded proposals; n: (b,) proposals
    per lane; probs: (b, k, v) draft distributions for the stochastic
    acceptance rule, or None for point-mass drafters."""
    tokens: np.ndarray
    n: np.ndarray
    probs: Optional[np.ndarray] = None


class Drafter:
    """Interface: `propose` every step; `release(lane)` when the engine
    finishes/preempts a lane so stateful drafters can drop its state."""

    def propose(self, histories: List[Optional[np.ndarray]], k: int,
                sampling: List) -> DraftProposal:
        raise NotImplementedError

    def release(self, lane: int) -> None:
        pass


# ----------------------------------------------------------------------------
# model-free: prompt-lookup n-gram
# ----------------------------------------------------------------------------
class NGramDrafter(Drafter):
    """Propose the continuation of the most recent earlier occurrence of
    the current suffix (longest match wins, `ngram_max` down to
    `ngram_min` tokens)."""

    def __init__(self, ngram_max: int = 3, ngram_min: int = 1,
                 lookback: int = 1024):
        assert 1 <= ngram_min <= ngram_max
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min
        self.lookback = lookback     # bounds the per-step scan to O(lookback)

    def _lookup(self, h: np.ndarray, k: int) -> np.ndarray:
        if len(h) > self.lookback:
            h = h[-self.lookback:]
        L = len(h)
        for n in range(min(self.ngram_max, L - 1), self.ngram_min - 1, -1):
            suffix = h[L - n:]
            # match only within h[:L-n]: the continuation starts before
            # the suffix begins, so at least one proposed token exists
            windows = np.lib.stride_tricks.sliding_window_view(
                h[:L - n], n) if L - n >= n else np.zeros((0, n), h.dtype)
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            if len(hits):
                start = int(hits[-1]) + n        # most recent match
                return h[start:start + k]
        return h[:0]

    def propose(self, histories: List[Optional[np.ndarray]], k: int,
                sampling: List) -> DraftProposal:
        b = len(histories)
        tokens = np.zeros((b, k), np.int32)
        n = np.zeros(b, np.int32)
        for i, h in enumerate(histories):
            if h is None or len(h) < self.ngram_min + 1:
                continue
            cont = self._lookup(np.asarray(h, np.int32), k)
            n[i] = len(cont)
            tokens[i, :len(cont)] = cont
        return DraftProposal(tokens=tokens, n=n, probs=None)


# ----------------------------------------------------------------------------
# small-model drafter on the paged runtime
# ----------------------------------------------------------------------------
class DraftModelDrafter(Drafter):
    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 kv_dtype=None, chunk: int = 16, seed: int = 0):
        assert model.supports_paged(), model.cfg.family
        assert max_seq % page_size == 0, (max_seq, page_size)
        self.model, self.params = model, params
        self.max_batch, self.max_seq = max_batch, max_seq
        self.chunk = min(chunk, max_seq)
        if n_pages is None:              # worst case: drafting never OOMs
            n_pages = max_batch * (max_seq // page_size)
        self.cache = PagedKVCache(model, n_pages, page_size, max_seq,
                                  kv_dtype or jnp.bfloat16)
        self._step = jax.jit(model.paged_step, donate_argnums=(1,))
        self._key = jax.random.PRNGKey(seed)
        # verified tokens materialized in the draft cache, per lane
        self._fed: List[np.ndarray] = [np.zeros(0, np.int32)
                                       for _ in range(max_batch)]

    def release(self, lane: int) -> None:
        self._fed[lane] = np.zeros(0, np.int32)
        if lane in self.cache.seqs:
            self.cache.release(lane)

    # ------------------------------------------------------------------
    def _run(self, tokens: np.ndarray, n_new: np.ndarray):
        tab = np.zeros((self.max_batch, self.cache.max_pages), np.int32)
        ln = np.zeros(self.max_batch, np.int32)
        for i in range(self.max_batch):
            if i in self.cache.seqs:
                tab[i] = self.cache.table_for(i)
                ln[i] = self.cache.seqs[i].length
        logits, self.cache.pools = self._step(
            self.params, self.cache.pools, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(tab), jnp.asarray(ln), jnp.asarray(n_new))
        for i in range(self.max_batch):
            if n_new[i]:
                self.cache.seqs[i].length += int(n_new[i])
        return logits

    def _catch_up(self, histories: List[Optional[np.ndarray]]) -> None:
        """Materialize each lane's verified prefix h[:-1] in the draft
        cache (h[-1] is the first DRAFT input, fed by `propose`).  Lanes
        whose cached prefix diverged (preemption, lane reuse) reset."""
        pending = {}
        for i, h in enumerate(histories):
            if h is None:
                continue
            want = h[:len(h) - 1]
            fed = self._fed[i]
            if len(fed) > len(want) or not np.array_equal(
                    fed, want[:len(fed)]):
                self.release(i)
                fed = self._fed[i]
            if i not in self.cache.seqs:
                if len(h) > self.max_seq:
                    continue                 # too long to draft: skip lane
                self.cache.admit(i, 0)       # alloc grows via ensure_room
            if len(want) > len(fed):
                pending[i] = want
        while pending:
            tokens = np.zeros((self.max_batch, self.chunk), np.int32)
            n_new = np.zeros(self.max_batch, np.int32)
            for i, want in list(pending.items()):
                done = len(self._fed[i])
                q = min(self.chunk, len(want) - done)
                if not self.cache.ensure_room(i, q):
                    pending.pop(i)           # lane too long for the pool:
                    self.release(i)          # no draft this round
                    continue
                tokens[i, :q] = want[done:done + q]
                n_new[i] = q
            if not n_new.any():
                break
            self._run(tokens, n_new)
            for i in list(pending):
                q = int(n_new[i])
                self._fed[i] = np.concatenate(
                    [self._fed[i], pending[i][len(self._fed[i]):
                                              len(self._fed[i]) + q]])
                if len(self._fed[i]) == len(pending[i]):
                    pending.pop(i)

    def propose(self, histories: List[Optional[np.ndarray]], k: int,
                sampling: List) -> DraftProposal:
        self._catch_up(histories)
        b = self.max_batch
        vocab = self.model.cfg.vocab
        tokens = np.zeros((b, k), np.int32)
        n = np.zeros(b, np.int32)
        active = [i for i, h in enumerate(histories)
                  if h is not None and i in self.cache.seqs
                  and len(self._fed[i]) == len(h) - 1]
        if not active:
            return DraftProposal(tokens=tokens, n=n, probs=None)
        stochastic = any(sampling[i] is not None
                         and sampling[i].temperature > 0.0 for i in active)
        probs = np.zeros((b, k, vocab), np.float32) if stochastic else None
        base_len = {i: self.cache.seqs[i].length for i in active}

        cur = np.zeros(b, np.int32)
        for i in active:
            cur[i] = histories[i][-1]
        temp = np.zeros(b, np.float32)
        topk = np.zeros(b, np.int32)
        topp = np.ones(b, np.float32)
        for i in active:
            sp = sampling[i]
            if sp is not None:
                temp[i], topk[i], topp[i] = (sp.temperature, sp.top_k,
                                             sp.top_p)

        alive = set(active)
        for step in range(k):
            step_tokens = np.zeros((b, 1), np.int32)
            n_new = np.zeros(b, np.int32)
            for i in list(alive):
                if not self.cache.ensure_room(i, 1):
                    alive.discard(i)
                    continue
                step_tokens[i, 0] = cur[i]
                n_new[i] = 1
            if not alive:
                break
            logits = self._run(step_tokens, n_new)
            rows = logits[:, 0, :]
            self._key, sub = jax.random.split(self._key)
            nxt = np.asarray(sample_tokens(sub, rows, jnp.asarray(temp),
                                           jnp.asarray(topk),
                                           jnp.asarray(topp)))
            rows_np = np.asarray(rows) if stochastic else None
            for i in list(alive):
                if stochastic and temp[i] > 0.0:
                    probs[i, step] = processed_probs(
                        rows_np[i], float(temp[i]), int(topk[i]),
                        float(topp[i]))
                tokens[i, step] = cur[i] = int(nxt[i])
                n[i] += 1

        # roll the speculative rows back: the draft cache keeps only
        # target-verified tokens across rounds
        for i in active:
            if i in self.cache.seqs:
                self.cache.trim(i, base_len[i])
        return DraftProposal(tokens=tokens, n=n, probs=probs)
