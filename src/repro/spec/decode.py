"""SpecDecoder: the engine-facing bundle — drafter + jitted verify fn +
acceptance RNG.

EdgeCIM frames decode as memory-bound GEMV: every emitted token
re-streams the full weight set.  Speculative decoding amortizes that
stream over a k-token window — `paged_verify_step` scores the whole
window in ONE pass (small-batch GEMM, the same arithmetic-intensity
lever as the paper's tile pipeline), and the accept/reject walk keeps
the served distribution exactly the target's.  The engine stays
shape-stable: every verify call is (max_batch, k + 1) regardless of how
many lanes drafted, so jit never retraces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.serve.sampling import SamplingParams

from .drafter import Drafter, DraftModelDrafter, NGramDrafter
from .verify import accept_draft


@dataclass
class SpecConfig:
    """Engine-level speculation knobs (per-request opt-out via
    `ServeRequest.spec = False`)."""
    k: int = 4                       # draft window (tokens per verify)
    drafter: str = "ngram"           # "ngram" | "model"
    ngram_max: int = 3
    ngram_min: int = 1
    draft_model: Any = None          # DecoderLM, drafter == "model"
    draft_params: Any = None
    draft_page_size: int = 16
    draft_chunk: int = 16            # draft-cache catch-up chunk width
    seed: int = 0
    # drafter-k autotuning: an EMA of the measured acceptance rate
    # scales how much the drafter proposes each step, between 1 and k.
    # The verify graph stays (b, k + 1) — autok never retraces jit, it
    # only stops paying draft cost speculation isn't earning back.
    autok: bool = False
    autok_beta: float = 0.3          # EMA weight of the newest step


class SpecDecoder:
    def __init__(self, model, spec_cfg: SpecConfig, *, max_batch: int,
                 max_seq: int, kv_dtype=None):
        assert spec_cfg.k >= 1
        self.cfg = spec_cfg
        self.verify_fn = jax.jit(model.paged_verify_step,
                                 donate_argnums=(1,))
        self.rng = np.random.default_rng(spec_cfg.seed)
        # autok state: start the EMA mid-range so the first steps draft
        # a middling window, then let measurement pull it either way
        self._accept_ema = 0.5
        if spec_cfg.drafter == "ngram":
            self.drafter: Drafter = NGramDrafter(spec_cfg.ngram_max,
                                                 spec_cfg.ngram_min)
        elif spec_cfg.drafter == "model":
            assert spec_cfg.draft_model is not None, \
                "drafter='model' needs draft_model/draft_params"
            dm = spec_cfg.draft_model
            assert dm.cfg.vocab == model.cfg.vocab, \
                "draft and target models must share a vocabulary"
            page = spec_cfg.draft_page_size
            while max_seq % page:
                page //= 2
            self.drafter = DraftModelDrafter(
                dm, spec_cfg.draft_params, max_batch=max_batch,
                max_seq=max_seq, page_size=page, kv_dtype=kv_dtype,
                chunk=spec_cfg.draft_chunk, seed=spec_cfg.seed)
        else:
            raise ValueError(spec_cfg.drafter)

    def accept(self, p_logits: np.ndarray, draft: np.ndarray,
               q_probs: Optional[np.ndarray], sampling: SamplingParams
               ) -> Tuple[int, List[int]]:
        """Delegate one lane's walk to the acceptance rule with the
        decoder's RNG (one stream for the whole engine, seeded)."""
        return accept_draft(p_logits, draft, q_probs, sampling, self.rng)

    # -- drafter-k autotuning ------------------------------------------
    def current_k(self) -> int:
        """Tokens the drafter should propose this step: cfg.k when
        autok is off, else 1..cfg.k scaled by the acceptance EMA (a
        drafter being accepted everywhere earns the full window; one
        being rejected stops burning draft compute on dead tokens)."""
        if not self.cfg.autok or self.cfg.k == 1:
            return self.cfg.k
        return 1 + int(round(self._accept_ema * (self.cfg.k - 1)))

    def observe(self, drafted: int, accepted: int) -> None:
        """Fold one verify step's measured acceptance into the EMA
        (steps that drafted nothing carry no signal and are skipped)."""
        if not self.cfg.autok or drafted == 0:
            return
        beta = self.cfg.autok_beta
        self._accept_ema = ((1.0 - beta) * self._accept_ema
                            + beta * accepted / drafted)
