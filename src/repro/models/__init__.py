"""Composable JAX model substrate (all assigned architecture families)."""
from .config import (MLAConfig, MoEConfig, ModelConfig, SSMConfig,
                     ZambaConfig)
from .common import (ParamSpec, init_params, param_count, spec_structs,
                     spec_axes, stack_specs, cross_entropy_loss)
from .model import DecoderLM

__all__ = [
    "MLAConfig", "MoEConfig", "ModelConfig", "SSMConfig", "ZambaConfig",
    "ParamSpec", "init_params", "param_count", "spec_structs", "spec_axes",
    "stack_specs", "cross_entropy_loss", "DecoderLM",
]
