"""Feed-forward layers: gated dense (SwiGLU/GeGLU) and Mixture-of-Experts.

MoE uses a drop-on-overflow gather/scatter dispatch by default: tokens are
sorted by expert, packed into (E, capacity) buffers, processed by a batched
expert GEMM with the expert dim sharded over the `model` mesh axis (EP),
and combined with router weights.  FLOPs stay honest (no one-hot dispatch
matmuls polluting the roofline); a GShard-style one-hot einsum variant is
kept for the §Perf ablation (`cfg.moe.dispatch = "onehot"`).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ACTIVATIONS, EXPERT, FSDP, NONE, TP, ParamSpec
from repro.kernels.ops import qmatmul_xla as qmm
from repro.quant.qarray import maybe_dequantize as deq
from .config import ModelConfig

Params = Dict[str, jax.Array]


# ----------------------------------------------------------------------------
# dense gated FFN
# ----------------------------------------------------------------------------
def dense_ffn_specs(cfg: ModelConfig, d_ff: int = 0) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    sp = {
        "w_up": ParamSpec((d, f), axes=(FSDP, TP)),
        "w_down": ParamSpec((f, d), axes=(TP, FSDP)),
    }
    if cfg.ffn_gated:
        sp["w_gate"] = ParamSpec((d, f), axes=(FSDP, TP))
    return sp


def dense_ffn(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.ffn_gated and cfg.ffn_act == "silu":
        # fused SwiGLU: one pass over the packed gate/up weights
        # (swiglu_qgemv Pallas kernel on TPU, fused grouped einsum on CPU)
        from repro.kernels.ops import swiglu
        h = swiglu(x, p["w_gate"], p["w_up"])
        return qmm(h, p["w_down"])
    act = ACTIVATIONS[cfg.ffn_act]
    up = qmm(x, p["w_up"])
    if cfg.ffn_gated:
        h = act(qmm(x, p["w_gate"])) * up
    else:
        h = act(up)
    return qmm(h, p["w_down"])


# ----------------------------------------------------------------------------
# MoE
# ----------------------------------------------------------------------------
def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_ff_expert
    sp: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, m.n_experts), axes=(FSDP, NONE),
                            scale=1.0 / math.sqrt(d)),
        # FSDP on the f dim (not the contracted d). NOTE: measured
        # byte-identical to d-dim FSDP at 256 devices (SSPerf cell b4,
        # refuted — GSPMD propagation picks its own expert resharding
        # either way); kept for the clearer annotation.
        "we_gate": ParamSpec((m.n_experts, d, fe), axes=(EXPERT, NONE, FSDP)),
        "we_up": ParamSpec((m.n_experts, d, fe), axes=(EXPERT, NONE, FSDP)),
        "we_down": ParamSpec((m.n_experts, fe, d), axes=(EXPERT, FSDP, NONE)),
    }
    if m.n_shared_experts > 0:
        fs = fe * m.n_shared_experts
        sp["ws_gate"] = ParamSpec((d, fs), axes=(FSDP, TP))
        sp["ws_up"] = ParamSpec((d, fs), axes=(FSDP, TP))
        sp["ws_down"] = ParamSpec((fs, d), axes=(TP, FSDP))
    return sp


def _router(p: Params, cfg: ModelConfig, xf: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """xf: (T, d) -> (weights (T,k), expert ids (T,k))."""
    m = cfg.moe
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids


def _expert_ffn(p: Params, cfg: ModelConfig, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) -> (E, C, d), batched over the expert dim.  Quantized
    expert stacks go through the fused grouped contraction (lead dim E),
    so packed experts stay integer on the serve path too."""
    from repro.kernels.ref import ref_qmatmul_fused
    from repro.quant.qarray import QTensor

    def mm(x, w):
        if isinstance(w, QTensor):
            return ref_qmatmul_fused(x, w, out_dtype=x.dtype)
        return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))

    act = ACTIVATIONS[cfg.ffn_act]
    h = act(mm(xe, p["we_gate"])) * mm(xe, p["we_up"])
    return mm(h, p["we_down"])


def _moe_gather(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Sort-based dispatch with per-expert capacity (drop on overflow)."""
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    k = m.top_k
    E = m.n_experts
    cap = max(8, int(math.ceil(T * k / E * m.capacity_factor)))

    xf = x.reshape(T, d)
    w, ids = _router(p, cfg, xf)                  # (T,k)

    flat_ids = ids.reshape(T * k)                 # expert id per slot
    order = jnp.argsort(flat_ids)                 # stable, groups by expert
    sorted_ids = flat_ids[order]
    # rank of each sorted slot within its expert group
    pos = jnp.arange(T * k, dtype=jnp.int32)
    first = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    rank_sorted = pos - first
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)  # unsorted

    keep = rank < cap
    slot = jnp.where(keep, flat_ids * cap + rank, E * cap)  # drop -> sentinel

    token_of_slot = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[token_of_slot], mode="drop")
    xe = buf[:E * cap].reshape(E, cap, d)

    ye = _expert_ffn(p, cfg, xe).reshape(E * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)

    gathered = ye[slot]                           # (T*k, d); dropped -> 0
    weighted = gathered * w.reshape(T * k, 1).astype(x.dtype)
    out = jnp.sum(weighted.reshape(T, k, d), axis=1)
    return out.reshape(b, s, d)


GROUP_TOKENS = 512      # GShard grouping: bounds the (G,S,E,C) dispatch
                        # tensor (SSPerf cell b2: ungrouped one-hot at 1M
                        # tokens built a (1M,128,82k) dispatch = refuted)


def _moe_onehot(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """GShard-style grouped one-hot einsum dispatch."""
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    k, E = m.top_k, m.n_experts
    if T > GROUP_TOKENS and T % GROUP_TOKENS == 0:
        return _moe_onehot_grouped(p, cfg, x)
    cap = max(8, int(math.ceil(T * k / E * m.capacity_factor)))

    xf = x.reshape(T, d)
    w, ids = _router(p, cfg, xf)

    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)         # (T,k,E)
    # rank over the flattened (T*k) slot order so slots never collide on
    # the same capacity column (matches the gather dispatch ordering)
    flat_oh = onehot.reshape(T * k, E)
    pos_flat = jnp.cumsum(flat_oh, axis=0) - flat_oh           # (T*k,E)
    pos_in_e = jnp.sum(pos_flat.reshape(T, k, E) * onehot, axis=-1)  # (T,k)
    keep = pos_in_e < cap
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, cap), cap,
                            dtype=jnp.float32)                 # (T,k,C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot, cap_oh)      # (T,E,C)
    combine = jnp.einsum("tk,tke,tkc->tec", w, onehot, cap_oh)

    xe = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), xf)
    ye = _expert_ffn(p, cfg, xe)
    out = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), ye)
    return out.reshape(b, s, d)


def _moe_onehot_grouped(p: Params, cfg: ModelConfig, x: jax.Array
                        ) -> jax.Array:
    """Grouped GShard dispatch: tokens split into groups of GROUP_TOKENS,
    capacity per group — the dispatch/combine tensors stay
    (G, S_g, E, C_g) with C_g ~ S_g*k/E, and every einsum partitions
    cleanly (G over batch/data, E over model)."""
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    k, E = m.top_k, m.n_experts
    Sg = GROUP_TOKENS
    G = T // Sg
    cap = max(8, int(math.ceil(Sg * k / E * m.capacity_factor)))

    xg = x.reshape(G, Sg, d)
    w, ids = _router(p, cfg, xg.reshape(T, d))
    w = w.reshape(G, Sg, k)
    ids = ids.reshape(G, Sg, k)

    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)      # (G,Sg,k,E)
    flat_oh = onehot.reshape(G, Sg * k, E)
    pos = jnp.cumsum(flat_oh, axis=1) - flat_oh
    pos_in_e = jnp.sum(pos.reshape(G, Sg, k, E) * onehot, axis=-1)
    keep = pos_in_e < cap
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, cap), cap,
                            dtype=jnp.float32)              # (G,Sg,k,C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, cap_oh)
    combine = jnp.einsum("gsk,gske,gskc->gsec", w, onehot, cap_oh)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    act = ACTIVATIONS[cfg.ffn_act]
    gme = jnp.einsum("gecd,edf->gecf", xe, deq(p["we_gate"]).astype(x.dtype))
    ume = jnp.einsum("gecd,edf->gecf", xe, deq(p["we_up"]).astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", act(gme) * ume,
                    deq(p["we_down"]).astype(x.dtype))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    return out.reshape(b, s, d)


def moe_ffn(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    m = cfg.moe
    if m.dispatch == "onehot":
        out = _moe_onehot(p, cfg, x)
    else:
        out = _moe_gather(p, cfg, x)
    if m.n_shared_experts > 0:
        act = ACTIVATIONS[cfg.ffn_act]
        shared = qmm(act(qmm(x, p["ws_gate"])) * qmm(x, p["ws_up"]),
                     p["ws_down"])
        out = out + shared
    return out


def ffn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    if cfg.moe is not None:
        return moe_specs(cfg)
    return dense_ffn_specs(cfg)


def ffn_forward(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.moe is not None:
        return moe_ffn(p, cfg, x)
    return dense_ffn(p, cfg, x)
